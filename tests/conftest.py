# NOTE: deliberately no XLA_FLAGS here — smoke tests must see ONE device.
# Multi-device tests spawn subprocesses (tests/util.py) that set the flag
# before importing jax.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption("--skip-slow", action="store_true", default=False,
                     help="skip multi-device/training integration tests")


def pytest_collection_modifyitems(config, items):
    if not config.getoption("--skip-slow"):
        return
    skip = pytest.mark.skip(reason="--skip-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
