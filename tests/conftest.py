# NOTE: deliberately no XLA_FLAGS here — smoke tests must see ONE device.
# Multi-device tests spawn subprocesses (tests/util.py) that set the flag
# before importing jax.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption("--skip-slow", action="store_true", default=False,
                     help="skip multi-device/training integration tests")


def pytest_collection_modifyitems(config, items):
    if not config.getoption("--skip-slow"):
        return
    skip = pytest.mark.skip(reason="--skip-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


def pytest_pyfunc_call(pyfuncitem):
    """Minimal asyncio test runner: a coroutine test function (the
    ``asyncio``-marked frontend suite) is executed to completion on a
    fresh event loop.  This keeps CI's dependency set at
    jax/numpy/pytest/hypothesis — no pytest-asyncio — while letting the
    async serving tests be plain ``async def`` functions."""
    import inspect

    fn = pyfuncitem.obj
    if not inspect.iscoroutinefunction(fn):
        return None
    import asyncio

    kwargs = {name: pyfuncitem.funcargs[name]
              for name in pyfuncitem._fixtureinfo.argnames}
    asyncio.run(fn(**kwargs))
    return True
