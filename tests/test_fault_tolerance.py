"""Serving fault tolerance: chaos injection, drain/rebuild, migration.

Policy units (no model): EWMA straggler flagging with mu0 seeding and
cooldown, heartbeat liveness transitions on a ManualClock, deterministic
exactly-once chaos firing, chaos-spec parsing, the analytic step-time
prior, and the scheduler's structured admission rejection.

End-to-end (tiny model): a 2-ring host fleet under injected chaos (ring
failure, stalled window, NaN logits, corrupted pool block) must finish
the trace with every surviving greedy stream bit-identical to the
chaos-off fleet and zero leaked pool blocks; exhausted-retry requests
surface ``failed=True`` + ``error`` instead of an engine crash.
"""
import jax
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.core.latency_model import LPU_FPGA, step_time_prior
from repro.models.registry import build_model
from repro.serving.config import EngineConfig
from repro.serving.engine import LPUEngine, MultiRingEngine, Request
from repro.serving.ft import (ChaosEvent, FailureInjector,
                              HeartbeatTracker, ManualClock,
                              StragglerMonitor, parse_chaos)
from repro.serving.kv_cache import BlockPool
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


# -- policy units ------------------------------------------------------


def test_straggler_mu0_arms_detection_immediately():
    # without a prior the FIRST sample becomes the baseline — a 2 s
    # first step would be silently normalized; mu0 from the latency
    # model judges it against the expected step time instead
    cold = StragglerMonitor(warmup=0, cooldown=0)
    assert cold.record(1, 2.0) is None          # becomes the baseline
    warm = StragglerMonitor(warmup=0, cooldown=0, mu0=0.1)
    ev = warm.record(1, 2.0)
    assert ev is not None and ev.kind == "straggler"
    assert ev.detail["dt"] == 2.0


def test_straggler_flag_respects_cooldown_and_warmup():
    mon = StragglerMonitor(warmup=3, cooldown=10, mu0=None)
    for s in range(1, 6):
        assert mon.record(s, 0.1) is None       # warmup + steady state
    assert mon.record(6, 2.0) is not None       # outlier flagged
    assert mon.record(7, 2.0) is None           # inside cooldown
    for s in range(8, 17):
        mon.record(s, 0.1)                      # back to steady state
    assert mon.record(17, 3.0) is not None      # cooldown elapsed
    # the flagged outliers were excluded from the EWMA: mu stays near
    # the steady-state mode (the unflagged cooldown sample does count)
    assert mon.mu < 0.3


def test_heartbeat_failure_and_revive_on_manual_clock():
    clk = ManualClock()
    hb = HeartbeatTracker(2, timeout_s=5.0, clock=clk)
    clk.advance(4.0)
    hb.beat(1)
    clk.advance(2.0)                  # worker 0 is now 6 s stale
    assert hb.check() == [0]
    assert hb.check() == []           # reported exactly once
    hb.revive(0)                      # rebuilt: fresh beat, back in rotation
    assert hb.check() == []
    clk.advance(6.0)                  # both stale again
    assert sorted(hb.check()) == [0, 1]


def test_parse_chaos_specs():
    evs = parse_chaos("ring@3,stall@5:1, nan@7 ,corrupt@9:0")
    assert evs == [ChaosEvent("ring", 3, 0), ChaosEvent("stall", 5, 1),
                   ChaosEvent("nan", 7, 0), ChaosEvent("corrupt", 9, 0)]
    assert parse_chaos("") == []
    for bad in ("explode@3", "ring@0", "ring@3:-1", "ring", "@3"):
        with pytest.raises(ValueError):
            parse_chaos(bad)
    # EngineConfig validates the spec at construction, not mid-run
    with pytest.raises(ValueError):
        EngineConfig(chaos="explode@3")


def test_failure_injector_fires_exactly_once_per_ring():
    inj = FailureInjector(chaos=parse_chaos("nan@3,ring@3:1,stall@5"))
    assert inj.fire(1, ring=0) == []
    assert [e.kind for e in inj.fire(3, ring=0)] == ["nan"]
    assert inj.fire(3, ring=0) == []            # never re-fires
    assert [e.kind for e in inj.fire(3, ring=1)] == ["ring"]
    assert [e.kind for e in inj.fire(5, ring=0)] == ["stall"]
    # legacy training-driver contract unchanged
    legacy = FailureInjector(fail_at_steps=[2])
    legacy.maybe_fail(1)
    with pytest.raises(RuntimeError):
        legacy.maybe_fail(2)
    legacy.maybe_fail(2)                        # raises only once


def test_step_time_prior_scales_with_window():
    cfg = get_config("smollm-135m").reduced()
    one = step_time_prior(cfg, 1, LPU_FPGA, kv_len=256)
    assert one > 0
    assert step_time_prior(cfg, 1, LPU_FPGA, kv_len=256,
                           steps_per_sync=4) == pytest.approx(4 * one)
    with pytest.raises(ValueError):
        step_time_prior(cfg, 1, LPU_FPGA, steps_per_sync=0)


def test_scheduler_rejects_never_fitting_request():
    # a request whose RESUME state (prompt + generated) outgrew the pool
    # is popped with a reason, not raised over: the co-tenant behind it
    # in the queue must still admit in the same call
    pool = BlockPool(num_blocks=3, block_size=16)   # 2 allocatable
    sched = Scheduler(slots=2, max_seq=64, pool=pool)
    big = Request(0, list(range(1, 11)), 50)
    big.out = list(range(100, 145))                 # resume needs 4 blocks
    ok = Request(1, [1, 2, 3], 8)
    sched.submit(big)
    sched.submit(ok)
    seq = sched.admit_next()
    assert seq is not None and seq.req is ok
    rej = sched.take_rejected()
    assert len(rej) == 1 and rej[0][0] is big
    assert "blocks" in rej[0][1]
    assert sched.take_rejected() == []              # handed off once


# -- engine + fleet end-to-end -----------------------------------------


def test_engine_surfaces_rejection_as_failed_request(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, EngineConfig(
        slots=2, max_seq=64, paged=True, block_size=16, num_blocks=3))
    big = Request(7, list(range(1, 11)), 50)
    big.out = list(range(100, 145))     # resume state can never fit
    eng.submit(big)
    eng.submit([1, 2, 3], max_new_tokens=4)
    results = eng.drain()               # must not raise
    assert big.failed and "blocks" in big.error
    assert results[7] == big.out        # partial stream surfaced
    assert len(results[8]) == 4         # co-tenant unaffected
    assert eng.stats.rejected_requests == 1
    assert any(e.kind == "request_rejected" for e in eng.events)


CHAOS_ALL = "ring@2,stall@3:1,nan@5,corrupt@8"


def _fleet(tiny_model, **overrides):
    model, params = tiny_model
    kw = dict(slots=2, max_seq=64, paged=True, block_size=16,
              heartbeat_timeout_s=4.0)
    kw.update(overrides)
    return MultiRingEngine(model, params, None, rings=2,
                           config=EngineConfig(**kw))


def test_fleet_chaos_parity_bit_exact(tiny_model):
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10, 11], [12, 13, 14],
               [15, 16]]
    base = _fleet(tiny_model).generate(prompts, max_new_tokens=8)
    fleet = _fleet(tiny_model, chaos=CHAOS_ALL)
    assert isinstance(fleet._clock, ManualClock)   # chaos => virtual time
    rids = [fleet.submit(p, 8) for p in prompts]
    results = fleet.drain()                        # never raises
    fc = fleet.fleet_counters()
    # the ISSUE's three required faults all fired; corrupt@8 is a
    # best-effort extra (migration may leave ring 0 idle before step 8
    # — its kind has a dedicated test below), hence the >= 3 floor:
    # ring@2, the heartbeat-drained stall, nan@5
    fired = {e.detail["kind"] for eng in fleet.engines
             for e in eng.events if e.kind == "chaos"}
    assert {"ring", "stall", "nan"} <= fired
    assert fc["ring_failures"] >= 3
    assert fc["retries"] >= 1
    assert any(e.kind == "ring_rebuilt" for e in fleet.events)
    survivors = [i for i, r in enumerate(rids) if r not in fleet.failed]
    assert survivors                               # chaos left survivors
    for i in survivors:
        assert results[rids[i]] == base[i], \
            f"survivor {i} diverged after recovery"
    for rid, req in fleet.failed.items():
        assert req.failed and "retries exhausted" in req.error
        assert results[rid] == req.out             # partial stream kept
    assert len(survivors) + len(fleet.failed) == len(prompts)
    for eng in fleet.engines:                      # zero leaked blocks
        eng.check_pool_balanced()


def test_corrupted_pool_block_is_detected_and_recovered(tiny_model):
    # a NaN'd resident KV block must surface through the finite-logits
    # guard on the NEXT decode (never silently poison the stream), and
    # recompute-recovery must restore the bit-exact greedy tokens
    base = _fleet(tiny_model).generate([[1, 2, 3], [4, 5]],
                                       max_new_tokens=8)
    fleet = _fleet(tiny_model, chaos="corrupt@4")
    rids = [fleet.submit(p, 8) for p in [[1, 2, 3], [4, 5]]]
    results = fleet.drain()
    assert fleet.engines[0].stats.ring_failures == 1
    nan_fails = [e for e in fleet.events if e.kind == "ring_failed"]
    assert nan_fails and nan_fails[0].detail["reason"] == "nan_logits"
    assert [results[r] for r in rids] == base
    for eng in fleet.engines:
        eng.check_pool_balanced()


def test_fleet_retry_exhaustion_is_structured(tiny_model):
    # max_migrations=0: the first ring failure's orphans fail in place —
    # structured status, no exception, pool still balanced
    fleet = _fleet(tiny_model, chaos="ring@2", max_migrations=0)
    rids = [fleet.submit(p, 6) for p in [[1, 2, 3], [4, 5]]]
    results = fleet.drain()
    assert len(fleet.failed) >= 1
    for rid, req in fleet.failed.items():
        assert req.failed and "retries exhausted" in req.error
        assert rid in results
    assert fleet.fleet_counters()["migrated_requests"] == 0
    for eng in fleet.engines:
        eng.check_pool_balanced()


def test_fleet_idle_ring_heartbeats_while_other_stalls(tiny_model):
    # ONE request: ring 0 serves it and wedges; ring 1 stays idle the
    # whole run.  Idle rings beat for free — only the stalled ring may
    # be drained, and the request still completes after recovery
    fleet = _fleet(tiny_model, chaos="stall@2")
    base = _fleet(tiny_model).generate([[1, 2, 3, 4]], max_new_tokens=6)
    rid = fleet.submit([1, 2, 3, 4], 6)
    results = fleet.drain()
    assert results[rid] == base[0]
    assert fleet.engines[0].stats.ring_failures == 1
    assert fleet.engines[1].stats.ring_failures == 0
    failed_rings = {e.detail["ring"] for e in fleet.events
                    if e.kind == "ring_failed"}
    assert failed_rings == {0}
    assert not fleet.failed
