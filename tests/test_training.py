"""Training integration: loss decreases; checkpoint/restart is lossless;
elastic restart under a different dp width consumes the same stream."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.ft import FailureInjector
from repro.launch.train import run_training


def _cfg():
    return get_config("smollm-135m").reduced()


@pytest.mark.slow
def test_loss_decreases():
    _, _, losses = run_training(cfg=_cfg(), steps=30, global_batch=8,
                                seq_len=64, log_every=100)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


@pytest.mark.slow
def test_checkpoint_restart_losslessness(tmp_path):
    # uninterrupted run
    _, _, ref_losses = run_training(
        cfg=_cfg(), steps=20, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path / "a"), ckpt_every=8, log_every=100)
    # crash at step 12 then auto-resume
    try:
        run_training(cfg=_cfg(), steps=20, global_batch=4, seq_len=32,
                     ckpt_dir=str(tmp_path / "b"), ckpt_every=8,
                     injector=FailureInjector([12]), log_every=100)
        raise AssertionError("injector did not fire")
    except RuntimeError:
        pass
    _, _, resumed = run_training(
        cfg=_cfg(), steps=20, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path / "b"), ckpt_every=8, log_every=100)
    # the resumed tail must match the uninterrupted run bit-for-bit-ish
    np.testing.assert_allclose(resumed[-4:], ref_losses[-4:], rtol=1e-4)


@pytest.mark.slow
def test_wsd_schedule_trains():
    _, _, losses = run_training(cfg=_cfg(), steps=15, global_batch=4,
                                seq_len=32, schedule="wsd", log_every=100)
    assert losses[-1] < losses[0]
