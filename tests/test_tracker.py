"""Tracker protocol units + the EngineStats delta-accounting contract.

The schema tests need no model; the end-to-end delta tests drive a real
engine (and a chaos fleet) and lock the property the tracker seam
depends on: cumulative ``EngineStats`` counters are MONOTONE — even
across ``reset()``/ring rebuilds, where the engine banks subsystem
counter bases (the regression this PR fixes: preemptions, evictions and
the prefix counters used to restart from zero after a migration, so
per-window deltas went negative).
"""
import json
import math

import jax
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.config import EngineConfig
from repro.serving.engine import EngineStats, LPUEngine, MultiRingEngine
from repro.serving.tracker import (CompositeTracker, EngineTap,
                                   JsonlTracker, NullTracker,
                                   RequestTimeline, RingBufferTracker,
                                   counter_fields, read_jsonl,
                                   snapshot_stats, stats_delta,
                                   validate_record)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


# -- schema / sinks (no model) -----------------------------------------


WINDOW_REC = {"kind": "engine_window", "t": 1.0, "ring": 0, "step": 3,
              "dt_ms": 2.5, "delta": {"steps": 1, "tokens": 2}}
REQ_REC = {"kind": "request", "t": 2.0, "rid": 7, "status": "completed",
           "tokens": 5, "ttft_ms": 12.0, "ms_per_token": 3.0}


def test_validate_record_rejects_malformed():
    validate_record(WINDOW_REC)
    validate_record(REQ_REC)
    validate_record({"kind": "event", "t": 0.0, "name": "x"})
    for bad in (
        {"kind": "nope", "t": 0.0},
        {"kind": "engine_window", "t": float("nan"), "ring": 0,
         "step": 0, "dt_ms": 0.0, "delta": {}},
        {**WINDOW_REC, "delta": {"steps": -1}},      # regressed counter
        {k: v for k, v in REQ_REC.items() if k != "ttft_ms"},
        {**REQ_REC, "status": "exploded"},
        "not a dict",
    ):
        with pytest.raises(ValueError):
            validate_record(bad)


def test_jsonl_sink_round_trips(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with JsonlTracker(path) as tr:
        tr.log(WINDOW_REC)
        tr.log(REQ_REC)
        with pytest.raises(ValueError):
            tr.log({"kind": "request", "t": 0.0})   # invalid: not written
    assert tr.written == 2
    back = read_jsonl(path)
    assert back == [WINDOW_REC, REQ_REC]
    # every line is standalone JSON (the artifact contract)
    with open(path) as fh:
        for line in fh:
            json.loads(line)


def test_ring_buffer_windows_correctly():
    tr = RingBufferTracker(capacity=3)
    for i in range(5):
        tr.log({"kind": "event", "t": float(i), "name": f"e{i}"})
    assert tr.seen == 5
    assert [r["name"] for r in tr.records()] == ["e2", "e3", "e4"]
    assert [r["name"] for r in tr.window(2)] == ["e3", "e4"]
    assert [r["name"] for r in tr.window(99)] == ["e2", "e3", "e4"]
    assert tr.window(0) == []
    with pytest.raises(ValueError):
        RingBufferTracker(0)


def test_composite_fans_out():
    a, b = RingBufferTracker(8), RingBufferTracker(8)
    CompositeTracker([a, b]).log(WINDOW_REC)
    assert a.records() == b.records() == [WINDOW_REC]
    NullTracker().log(WINDOW_REC)                   # silently fine


def test_request_timeline_ttft_monotone():
    tl = RequestTimeline(1, t_submit=10.0, tenant="a")
    ts = [10.4, 10.5, 10.7, 11.0]
    for t in ts:
        tl.on_token(t)
    assert tl.ttft_ms == pytest.approx(400.0)
    # ms/token averages the post-first-token gaps only
    assert tl.ms_per_token == pytest.approx((11.0 - 10.4) * 1e3 / 3)
    rec = tl.record("completed", 11.0)
    validate_record(rec)
    assert rec["tenant"] == "a" and rec["tokens"] == 4
    # TTFT can never exceed total latency
    assert rec["ttft_ms"] <= (11.0 - 10.0) * 1e3
    # a tokenless (cancelled-before-prefill) timeline stays schema-valid
    empty = RequestTimeline(2, 0.0).record("cancelled", 1.0)
    validate_record(empty)
    assert empty["ttft_ms"] == -1.0 and empty["tokens"] == 0


def test_stats_delta_monotone_contract():
    a = {"steps": 1, "tokens": 4}
    b = {"steps": 3, "tokens": 9}
    assert stats_delta(a, b) == {"steps": 2, "tokens": 5}
    with pytest.raises(ValueError):
        stats_delta(b, a)                           # regression must raise
    # gauges are excluded from the counter set
    names = counter_fields(EngineStats())
    assert "peak_pool_blocks" not in names and "wall" not in names
    assert "tokens" in names and "preemptions" in names


# -- delta accounting against a real engine ----------------------------


def _run_with_tap(engine_or_fleet, engines, prompts, max_new):
    """Step to drain, emitting per-window deltas; returns per-engine
    delta sums keyed like the snapshots."""
    sink = RingBufferTracker(4096)
    taps = [EngineTap(e, ring=i) for i, e in enumerate(engines)]
    sums = [dict.fromkeys(counter_fields(e.stats), 0) for e in engines]
    for p in prompts:
        engine_or_fleet.submit(list(p), max_new)
    while engine_or_fleet.has_work():
        engine_or_fleet.step()
        for tap, acc in zip(taps, sums):
            rec = tap.emit(sink, t=0.0)
            if rec is not None:
                for k, v in rec["delta"].items():
                    acc[k] += v
    engine_or_fleet.drain()
    # one final emit catches counters drain() itself touched
    for tap, acc in zip(taps, sums):
        rec = tap.emit(sink, t=0.0)
        if rec is not None:
            for k, v in rec["delta"].items():
                acc[k] += v
    return sums, sink


def test_deltas_sum_to_cumulative_single_engine(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, EngineConfig(
        slots=2, max_seq=64, paged=True, block_size=16,
        prefix_cache=True))
    sums, sink = _run_with_tap(eng, [eng],
                               [[1, 2, 3], [4, 5], [1, 2, 3, 9]], 6)
    final = snapshot_stats(eng.stats)
    assert sums[0] == final
    assert eng.stats.tokens > 0
    assert all(r["dt_ms"] >= 0 for r in sink.records())


def test_deltas_survive_reset_regression(tiny_model):
    # THE regression test: chaos kills ring 0 mid-flight; the rebuilt
    # scheduler/pool/prefix restart their counters at zero, but the
    # banked bases must keep cumulative EngineStats monotone — every
    # emitted delta >= 0 (EngineTap raises otherwise) and the sums
    # still equal the final cumulative counters
    model, params = tiny_model
    shared = list(range(1, 33))
    prompts = [shared + [50], shared + [51], [4, 5, 6], shared + [52]]
    fleet = MultiRingEngine(model, params, None, rings=2,
                            config=EngineConfig(
                                slots=2, max_seq=64, paged=True,
                                block_size=16, prefix_cache=True,
                                chaos="ring@2"))
    sums, _ = _run_with_tap(fleet, fleet.engines, prompts, 8)
    assert sum(e.stats.ring_failures for e in fleet.engines) >= 1
    for eng, acc in zip(fleet.engines, sums):
        assert acc == snapshot_stats(eng.stats)
    # the prefix counters kept counting across the rebuild: lookups on
    # the failed ring resume from the banked base, never below it
    hit = [e for e in fleet.engines if e.stats.ring_failures]
    assert hit and all(e.stats.prefix_lookups >= 0 for e in hit)


def test_cumulative_counters_never_regress_across_reset(tiny_model):
    # direct unit on the engine fix, no fleet: preempt + evict + prefix
    # traffic, snapshot, reset(), then verify no assigned counter went
    # backwards on the next step
    model, params = tiny_model
    eng = LPUEngine(model, params, EngineConfig(
        slots=2, max_seq=64, paged=True, block_size=16,
        prefix_cache=True))
    eng.generate([[1, 2, 3, 4] * 4, [1, 2, 3, 4] * 4 + [9]], 8)
    before = snapshot_stats(eng.stats)
    assert before["prefix_lookups"] > 0
    eng.reset()                                     # rebuild mid-life
    eng.generate([[7, 8, 9]], 4)
    after = snapshot_stats(eng.stats)
    stats_delta(before, after)                      # raises on regression
    assert after["prefix_lookups"] >= before["prefix_lookups"]
    assert after["preemptions"] >= before["preemptions"]
    assert after["evicted_blocks"] >= before["evicted_blocks"]


def test_engine_tap_skips_quiet_windows(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, EngineConfig(slots=2, max_seq=64,
                                                paged=True,
                                                block_size=16))
    sink = RingBufferTracker(64)
    tap = EngineTap(eng)
    assert tap.emit(sink, t=0.0) is None            # nothing happened
    assert sink.seen == 0
    eng.generate([[1, 2, 3]], 4)
    rec = tap.emit(sink, t=1.0)
    # the first generated token comes out of prefill, the other three
    # out of decode steps: the delta mirrors the cumulative counter
    assert rec is not None and rec["delta"]["tokens"] == eng.stats.tokens
    assert math.isfinite(rec["dt_ms"])
