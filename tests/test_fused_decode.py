"""Fused on-device sampling + multi-step decode windows (engine level).

The engine's default decode program now samples in-jit and can run
``steps_per_sync`` decode steps per host readback (one lax.scan window
with on-device stop masking).  These tests pin the PR's contract:

* token streams are IDENTICAL to the pre-fusion host-sampled engine —
  greedy bit-for-bit (dense, paged stream/gather, steps_per_sync 1 and
  4, under preemption and eos), and stochastic runs with a fixed rng,
  including mixed per-slot SamplingParams and mid-window finishes;
* only O(slots) bytes cross to the host per token (the logits row
  never does), and multi-step windows cut host syncs ~Sx;
* speculative lookahead never preempts resident work.
"""
import jax
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.kernels.decode_attention.ops import plan_block_s
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine
from repro.serving.sampler import SamplingParams

PROMPTS = [[1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 12], [13, 14, 15],
           [16, 17, 18, 19]]


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def greedy_ref(tiny_model):
    model, params = tiny_model
    return LPUEngine(model, params, slots=3, max_seq=64, paged=False,
                     sampling="host").generate(PROMPTS, max_new_tokens=10)


# -- greedy bit-parity with the pre-fusion engine ----------------------

@pytest.mark.parametrize("steps", [1, 4])
@pytest.mark.parametrize("kern", ["dense", "stream", "gather"])
def test_fused_greedy_matches_host(tiny_model, greedy_ref, kern, steps):
    model, params = tiny_model
    kw = (dict(paged=False) if kern == "dense"
          else dict(paged=True, block_size=16, paged_kernel=kern))
    eng = LPUEngine(model, params, slots=3, max_seq=64,
                    steps_per_sync=steps, **kw)
    assert eng.generate(PROMPTS, max_new_tokens=10) == greedy_ref
    # the fused engine never reads a logits row: O(slots) bytes/token
    assert eng.stats.bytes_to_host_per_token <= 8 * eng.slots + 16


def test_fused_multistep_parity_under_preemption(tiny_model, greedy_ref):
    """A pool too small for the working set: windows must degrade to
    single steps (reserve_lookahead never preempts) and recompute
    preemption must still reproduce the dense streams exactly."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                    block_size=8, num_blocks=4, steps_per_sync=4)
    assert eng.generate(PROMPTS, max_new_tokens=10) == greedy_ref
    assert eng.stats.preemptions > 0, "pool was meant to force preemption"


def test_fused_eos_mid_window(tiny_model, greedy_ref):
    """EOS inside a 4-step window: the device masks the slot, the host
    discards its overrun tokens, and the streams match the single-step
    host engine exactly."""
    model, params = tiny_model
    base = greedy_ref[0]
    k = next((i for i in range(1, len(base)) if base[i] not in base[:i]),
             None)
    if k is None:
        pytest.skip("degenerate greedy output: no unique mid-flight token")
    eos = base[k]
    ref = LPUEngine(model, params, slots=2, max_seq=64, eos_id=eos,
                    sampling="host").generate(PROMPTS[:3],
                                              max_new_tokens=10)
    eng = LPUEngine(model, params, slots=2, max_seq=64, eos_id=eos,
                    steps_per_sync=4)
    assert eng.generate(PROMPTS[:3], max_new_tokens=10) == ref
    assert ref[0] == base[:k + 1]


# -- stochastic parity (fixed rng) -------------------------------------

def _run_mixed(model, params, sampling, steps):
    """Mixed per-slot SamplingParams with staggered budgets so slots
    finish mid-window; requests <= slots so the rng-split schedule is
    admission-order independent."""
    eng = LPUEngine(model, params, slots=3, max_seq=64,
                    rng=jax.random.PRNGKey(11), sampling=sampling,
                    steps_per_sync=steps)
    spec = [(PROMPTS[0], 9, SamplingParams(0.0, 0, 1.0)),
            (PROMPTS[1], 5, SamplingParams(0.8, 7, 1.0)),
            (PROMPTS[2], 12, SamplingParams(1.1, 0, 0.9))]
    rids = [eng.submit(p, n, sp) for p, n, sp in spec]
    res = eng.drain()
    return [res[r] for r in rids]


@pytest.mark.parametrize("steps", [1, 4])
def test_fused_stochastic_mixed_params_matches_host(tiny_model, steps):
    model, params = tiny_model
    want = _run_mixed(model, params, "host", 1)
    got = _run_mixed(model, params, "fused", steps)
    assert got == want
    assert [len(o) for o in got] == [9, 5, 12]   # mid-window finishes


def test_fused_stochastic_reproducible(tiny_model):
    model, params = tiny_model
    a = _run_mixed(model, params, "fused", 4)
    b = _run_mixed(model, params, "fused", 4)
    assert a == b


# -- host-sync / bytes accounting --------------------------------------

def test_sync_accounting_fused_vs_host(tiny_model):
    model, params = tiny_model
    host = LPUEngine(model, params, slots=3, max_seq=64, sampling="host")
    host.generate(PROMPTS, max_new_tokens=8)
    fused = LPUEngine(model, params, slots=3, max_seq=64,
                      steps_per_sync=4)
    fused.generate(PROMPTS, max_new_tokens=8)
    v = model.cfg.vocab_size
    # host path ships >= one fp32 logits row per decode token
    assert host.stats.bytes_to_host_per_token >= 4 * v
    # fused path ships O(slots) int32 ids (+ window slack), not O(vocab)
    assert fused.stats.bytes_to_host_per_token <= 8 * fused.slots + 16
    assert fused.stats.bytes_to_host_per_token * 50 < \
        host.stats.bytes_to_host_per_token
    # multi-step windows sync strictly less often
    assert fused.stats.host_syncs < host.stats.host_syncs
    assert fused.stats.tokens == host.stats.tokens


def test_reserve_lookahead_never_preempts(tiny_model):
    """Window reservation is all-or-nothing and preemption-free."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                    block_size=8, num_blocks=5, pipeline=False)
    eng.submit(PROMPTS[0], max_new_tokens=40)
    eng.step()                          # admit + prefill + one decode
    sched = eng.sched
    assert sched.num_active() == 1
    free0, pre0 = sched.pool.num_free, sched.preemptions
    ok = sched.reserve_lookahead(1000)            # cannot possibly fit
    assert not ok
    assert sched.pool.num_free == free0, "failed reserve must not alloc"
    assert sched.preemptions == pre0, "reserve must never preempt"
    assert sched.reserve_lookahead(1)             # the next step still fits


# -- configuration validation ------------------------------------------

def test_engine_rejects_invalid_dispatch_configs(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError):
        LPUEngine(model, params, sampling="turbo")
    with pytest.raises(ValueError):
        LPUEngine(model, params, steps_per_sync=0)
    with pytest.raises(ValueError):
        LPUEngine(model, params, sampling="host", steps_per_sync=4)
    with pytest.raises(ValueError):
        # streamed paged tile IS the pool block size
        LPUEngine(model, params, max_seq=64, paged=True, block_size=16,
                  paged_kernel="stream", block_s=32)


# -- block_s override (--block-s) --------------------------------------

def test_plan_block_s_override():
    assert plan_block_s(4096, 128, 4) == 4096
    assert plan_block_s(4096, 128, 4, override=512) == 512
    assert plan_block_s(256, 128, 4, override=1024) == 256  # clamped
    with pytest.raises(ValueError):
        plan_block_s(4096, 128, 4, override=100)            # not a tile
    with pytest.raises(ValueError):
        plan_block_s(256, 128, 4, override=8)    # tiles, but not LANE-ok
    assert plan_block_s(64, 128, 4, override=64) == 64  # full span exempt
    assert plan_block_s(4096, 128, 4, override=0) == 4096   # 0 = planned


def test_engine_block_s_override_still_serves(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64, paged=False,
                    block_s=32)
    outs = eng.generate(PROMPTS[:2], max_new_tokens=5)
    assert all(len(o) == 5 for o in outs)
    assert eng.decode_block_s() == 32
    assert eng.planned_block_s() >= 1
    # default engines report the planned/structural tile
    deflt = LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                      block_size=16)
    assert deflt.decode_block_s() == 16      # stream tile == pool block


# -- the measured no-copy gate survives the fused program --------------

def test_fused_window_program_view_tensor_gate(tiny_model):
    """The per-request contiguous KV view must not appear in the fused
    streamed window program (and must appear in the gather oracle's)."""
    model, params = tiny_model
    a = model.plan.attn
    sig = f"tensor<2x64x{a.gp}x{a.d_head}xf32>"
    kw = dict(slots=2, max_seq=64, paged=True, block_size=16)
    stream = LPUEngine(model, params, paged_kernel="stream", **kw)
    gather = LPUEngine(model, params, paged_kernel="gather", **kw)
    assert stream.lower_decode_text().count(sig) == 0
    assert gather.lower_decode_text().count(sig) > 0


# -- ring parallelism: fused tp=2 == host tp=1 -------------------------

@pytest.mark.slow
def test_ring_fused_sampling_matches_dense_tp1():
    """tp=2 shard_map engine with fused in-ring sampling
    (sample_sharded_batched: only (tp x k) candidates are gathered, the
    full vocab row never leaves the ranks) must match the tp=1 dense
    host-sampled engine bit-for-bit, for steps_per_sync 1 and 4."""
    from tests.util import run_multidevice
    out = run_multidevice("""
    import jax
    from repro.compiler.mapper import plan_model
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.registry import build_model
    from repro.serving.engine import LPUEngine

    cfg = get_config('smollm-135m').reduced()
    plan1 = plan_model(cfg, None, (1,), 'serve', esl_overlap=False,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m1 = build_model(cfg, plan1)
    p1, _ = m1.init(jax.random.PRNGKey(0))
    plan2 = plan_model(cfg, ('model',), (2,), 'serve', esl_overlap=True,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m2 = build_model(cfg, plan2)
    p2, _ = m2.init(jax.random.PRNGKey(0))
    prompts = [[1,2,3,4,5,6,7],[8,9,10,11,12],[13,14,15],[16,17,18,19]]
    ref = LPUEngine(m1, p1, slots=3, max_seq=64, paged=False,
                    sampling='host').generate(prompts, max_new_tokens=10)
    mesh = make_serving_mesh(tp=2, rings=1)
    for S in (1, 4):
        eng = LPUEngine(m2, p2, slots=3, max_seq=64, paged=True,
                        block_size=16, mesh=mesh, steps_per_sync=S)
        got = eng.generate(prompts, max_new_tokens=10)
        assert got == ref, (S, got, ref)
        assert eng.stats.bytes_to_host_per_token <= 8 * 3 + 16
    engd = LPUEngine(m2, p2, slots=3, max_seq=64, paged=False, mesh=mesh,
                     steps_per_sync=4)
    assert engd.generate(prompts, max_new_tokens=10) == ref
    print('PASS')
    """, n_devices=2)
    assert "PASS" in out
