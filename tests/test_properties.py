"""Hypothesis property tests on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.compiler.plan import plan_attention
from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.kernels.gemv import gemv, gemv_ref
from repro.serving.sampler import SamplingParams, sample_local


# ---------------------------------------------------------------------------
# mapper invariants
# ---------------------------------------------------------------------------

@given(h_ratio=st.integers(1, 8), g=st.integers(1, 64),
       tp=st.sampled_from([1, 2, 4, 8, 16, 32]))
@settings(max_examples=200, deadline=None)
def test_attention_plan_invariants(h_ratio, g, tp):
    h = g * h_ratio
    a = plan_attention(h, g, 64, tp)
    # stored layout divides evenly across ranks
    assert a.hp == a.q_per_rank * tp
    assert a.gp == a.kv_per_rank * tp
    assert a.hp >= h and a.gp >= g
    # every original q head appears exactly once
    reals = sorted(o for o in a.q_orig if o >= 0)
    assert reals == list(range(h))
    # the local map never crosses ranks
    loc = a.q_to_kv_local
    assert loc.min() >= 0 and loc.max() < a.kv_per_rank
    # every real q head maps to its true kv group
    gs = max(1, h // g)
    for j, (orig, kv_stored) in enumerate(zip(a.q_orig, a.q_to_kv)):
        if orig >= 0:
            assert a.kv_orig[kv_stored] == orig // gs


@given(tp=st.sampled_from([1, 2, 4, 8, 16]),
       name=st.sampled_from(["smollm-135m", "deepseek-coder-33b",
                             "granite-moe-3b-a800m", "qwen1.5-4b"]))
@settings(max_examples=40, deadline=None)
def test_plan_padded_dims_divisible(tp, name):
    cfg = get_config(name)
    axes = ("data", "model") if tp > 1 else None
    plan = plan_model(cfg, axes, (2, tp) if tp > 1 else (1,), "train")
    assert plan.d_ff_padded % max(plan.tp, 1) == 0
    assert plan.d_ff_padded >= cfg.d_ff
    assert plan.vocab_padded % max(plan.tp, 1) == 0
    assert plan.vocab_padded >= cfg.vocab_size


# ---------------------------------------------------------------------------
# sampler invariants
# ---------------------------------------------------------------------------

@given(b=st.integers(1, 4), v=st.integers(8, 200),
       temp=st.floats(0.1, 2.0), k=st.integers(0, 16),
       p=st.floats(0.1, 1.0), seed=st.integers(0, 10_000))
@settings(max_examples=100, deadline=None)
def test_sampler_in_support(b, v, temp, k, p, seed):
    rng = jax.random.PRNGKey(seed)
    logits = jax.random.normal(rng, (b, v))
    tok = sample_local(logits, rng, SamplingParams(temp, min(k, v), p))
    assert tok.shape == (b,)
    assert int(tok.min()) >= 0 and int(tok.max()) < v
    if k:
        # sampled token must be within the top-k set
        topk = jax.lax.top_k(logits, min(k, v))[1]
        for i in range(b):
            assert int(tok[i]) in np.asarray(topk[i])


@given(seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_sampler_greedy_is_argmax(seed):
    rng = jax.random.PRNGKey(seed)
    logits = jax.random.normal(rng, (3, 50))
    tok = sample_local(logits, rng, SamplingParams(0.0, 0, 1.0))
    np.testing.assert_array_equal(np.asarray(tok),
                                  np.asarray(jnp.argmax(logits, -1)))


# ---------------------------------------------------------------------------
# data pipeline invariants (elastic determinism)
# ---------------------------------------------------------------------------

@given(step=st.integers(0, 50), gb=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 5))
@settings(max_examples=50, deadline=None)
def test_data_shard_invariance(step, gb, seed):
    """Concatenated shards == unsharded batch, for any worker count."""
    ds = SyntheticLM(vocab_size=997, seq_len=32, seed=seed)
    full = ds.batch(step, gb, (0, 1))
    for n_hosts in (2, 4):
        if gb % n_hosts:
            continue
        parts = [ds.batch(step, gb, (h, n_hosts))["tokens"]
                 for h in range(n_hosts)]
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])


@given(seed=st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_data_tokens_in_vocab(seed):
    ds = SyntheticLM(vocab_size=313, seq_len=16, seed=seed)
    b = ds.batch(0, 4)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 313
    # labels are next-token shifted
    ex = ds.example(0)
    np.testing.assert_array_equal(b["tokens"][0], ex[:-1])
    np.testing.assert_array_equal(b["labels"][0], ex[1:])


# ---------------------------------------------------------------------------
# kernel property: gemv == ref on random aligned shapes
# ---------------------------------------------------------------------------

@given(b=st.integers(1, 8),
       k=st.sampled_from([128, 256, 384]),
       n=st.sampled_from([128, 512, 640]),
       seed=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_gemv_matches_ref(b, k, n, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    np.testing.assert_allclose(np.asarray(gemv(x, w)),
                               np.asarray(gemv_ref(x, w)),
                               rtol=1e-4, atol=1e-4)
