"""Serving engine: HF-like generate, continuous batching, streaming."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine
from repro.serving.sampler import SamplingParams


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_generate_continuous_batching(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=3, max_seq=64)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10, 11]]
    outs = eng.generate(prompts, max_new_tokens=8)
    assert len(outs) == 4
    assert all(len(o) == 8 for o in outs)
    assert eng.stats.tokens > 0
    # more requests than slots => requeuing happened
    assert eng.stats.occupancy <= 1.0


def test_generate_deterministic_greedy(tiny_model):
    model, params = tiny_model
    o1 = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=6)
    o2 = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=6)
    assert o1 == o2


def test_generate_streaming_callback(tiny_model):
    model, params = tiny_model
    seen = []
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    outs = eng.generate([[1, 2, 3]], max_new_tokens=5,
                        stream_cb=lambda rid, tok: seen.append((rid, tok)))
    assert [t for _, t in seen] == outs[0]


def test_sampled_generation_valid_tokens(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64,
                    rng=jax.random.PRNGKey(7))
    outs = eng.generate([[1, 2], [3, 4]], max_new_tokens=6,
                        params=SamplingParams(0.9, 10, 0.95))
    v = model.cfg.vocab_size
    for o in outs:
        assert all(0 <= t < v for t in o)


def test_prompt_isolation(tiny_model):
    """A slot freed by one request must not leak state into the next."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=1, max_seq=64)
    outs = eng.generate([[1, 2, 3], [1, 2, 3]], max_new_tokens=5)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# continuous serving API: submit / step / drain
# ---------------------------------------------------------------------------

def test_submit_step_drain_nonblocking(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    r0 = eng.submit([1, 2, 3], max_new_tokens=4)
    r1 = eng.submit([4, 5], max_new_tokens=6)
    assert r0 != r1
    # stepping by hand: nothing finishes before its token budget
    finished = eng.step()
    assert finished == []
    # submit mid-flight (continuous serving)
    r2 = eng.submit([6, 7, 8], max_new_tokens=2)
    results = eng.drain()
    assert set(results) == {r0, r1, r2}
    assert len(results[r0]) == 4
    assert len(results[r1]) == 6
    assert len(results[r2]) == 2
    # results are handed off exactly once (no unbounded history)
    assert eng.drain() == {}


def test_step_matches_generate(tiny_model):
    """Hand-stepped serving produces the same tokens as generate()."""
    model, params = tiny_model
    ref = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=5)
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    r0 = eng.submit([1, 2, 3], max_new_tokens=5)
    r1 = eng.submit([4, 5], max_new_tokens=5)
    done = {}
    for _ in range(50):
        for req in eng.step():
            done[req.rid] = req.out
        if len(done) == 2:
            break
    assert [done[r0], done[r1]] == ref


def test_eos_mid_flight(tiny_model):
    """EOS truncates one request mid-flight; the other slots keep going
    and the freed slot is re-used by the queue."""
    model, params = tiny_model
    base = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3]], max_new_tokens=8)[0]
    # pick an eos id at its FIRST occurrence past the first token, so the
    # truncation point is unambiguous (greedy decode repeats tokens)
    k = next((i for i in range(1, len(base)) if base[i] not in base[:i]),
             None)
    if k is None:
        pytest.skip("degenerate greedy output: no unique mid-flight token")
    eos = base[k]
    eng = LPUEngine(model, params, slots=2, max_seq=64, eos_id=eos)
    outs = eng.generate([[1, 2, 3], [4, 5], [6, 7, 8, 9]],
                        max_new_tokens=8)
    assert outs[0] == base[:k + 1]              # truncated at eos
    assert outs[0][-1] == eos
    assert all(len(o) <= 8 for o in outs)


def test_slot_release_readmission_order(tiny_model):
    """Queued requests are admitted FIFO as slots free up, and early
    finishers release their slot mid-flight."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    order = []
    rids = []
    # 5 requests on 2 slots; first two finish fast
    for i, (p, n) in enumerate([([1, 2], 2), ([3, 4], 2), ([5, 6], 4),
                                ([7, 8], 4), ([9, 10], 4)]):
        rids.append(eng.submit(p, max_new_tokens=n))
    while eng.sched.has_work():
        for req in eng.step():
            order.append(req.rid)
    # the two short requests finish first, and every request completes
    assert set(order) == set(rids)
    assert set(order[:2]) == set(rids[:2])
    assert eng.stats.occupancy > 0.5


def test_submit_rejects_invalid_prompts(tiny_model):
    """Over-long / empty prompts fail synchronously at submit(), not
    mid-step after a slot has been claimed."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=32)
    with pytest.raises(ValueError):
        eng.submit(list(range(1, 40)), max_new_tokens=4)
    with pytest.raises(ValueError):
        eng.submit([], max_new_tokens=4)
    # engine still serves normally afterwards
    outs = eng.generate([[1, 2, 3]], max_new_tokens=3)
    assert len(outs[0]) == 3


def test_recurrent_family_prefill_not_bucketed():
    """Pow2 bucket padding must NOT be applied to recurrent-state
    families: mamba/rwkv fold every prefill position into their state,
    so padded tokens would change the generated continuation.  Outputs
    must be invariant to min_bucket."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    e1 = LPUEngine(model, params, slots=2, max_seq=64, min_bucket=4)
    e2 = LPUEngine(model, params, slots=2, max_seq=64, min_bucket=32)
    assert not e1.paged and not e1.bucketed
    prompts = [[1, 2, 3, 4, 5], [6, 7]]       # off-bucket lengths
    assert e1.generate(prompts, max_new_tokens=4) == \
        e2.generate(prompts, max_new_tokens=4)


def test_engine_stats_monitoring(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    eng.generate([[1, 2, 3], [4, 5], [6, 7]], max_new_tokens=4)
    st = eng.stats
    assert st.tokens > 0 and st.steps > 0
    assert 0 < st.occupancy <= 1.0
    assert st.prefills == 3
    assert 1 <= st.prefill_traces <= 7          # log2(64)+1 buckets max
    assert eng.kv_cache_bytes() > 0
