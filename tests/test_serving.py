"""Serving engine: HF-like generate, continuous batching, streaming."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine
from repro.serving.sampler import SamplingParams


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_generate_continuous_batching(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=3, max_seq=64)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10, 11]]
    outs = eng.generate(prompts, max_new_tokens=8)
    assert len(outs) == 4
    assert all(len(o) == 8 for o in outs)
    assert eng.stats.tokens > 0
    # more requests than slots => requeuing happened
    assert eng.stats.occupancy <= 1.0


def test_generate_deterministic_greedy(tiny_model):
    model, params = tiny_model
    o1 = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=6)
    o2 = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=6)
    assert o1 == o2


def test_generate_streaming_callback(tiny_model):
    model, params = tiny_model
    seen = []
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    outs = eng.generate([[1, 2, 3]], max_new_tokens=5,
                        stream_cb=lambda rid, tok: seen.append((rid, tok)))
    assert [t for _, t in seen] == outs[0]


def test_sampled_generation_valid_tokens(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64,
                    rng=jax.random.PRNGKey(7))
    outs = eng.generate([[1, 2], [3, 4]], max_new_tokens=6,
                        params=SamplingParams(0.9, 10, 0.95))
    v = model.cfg.vocab_size
    for o in outs:
        assert all(0 <= t < v for t in o)


def test_prompt_isolation(tiny_model):
    """A slot freed by one request must not leak state into the next."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=1, max_seq=64)
    outs = eng.generate([[1, 2, 3], [1, 2, 3]], max_new_tokens=5)
    assert outs[0] == outs[1]
