"""Serving engine: HF-like generate, continuous batching, streaming."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine
from repro.serving.sampler import SamplingParams


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_generate_continuous_batching(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=3, max_seq=64)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10, 11]]
    outs = eng.generate(prompts, max_new_tokens=8)
    assert len(outs) == 4
    assert all(len(o) == 8 for o in outs)
    assert eng.stats.tokens > 0
    # more requests than slots => requeuing happened
    assert eng.stats.occupancy <= 1.0


def test_generate_deterministic_greedy(tiny_model):
    model, params = tiny_model
    o1 = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=6)
    o2 = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=6)
    assert o1 == o2


def test_generate_streaming_callback(tiny_model):
    model, params = tiny_model
    seen = []
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    outs = eng.generate([[1, 2, 3]], max_new_tokens=5,
                        stream_cb=lambda rid, tok: seen.append((rid, tok)))
    assert [t for _, t in seen] == outs[0]


def test_sampled_generation_valid_tokens(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64,
                    rng=jax.random.PRNGKey(7))
    outs = eng.generate([[1, 2], [3, 4]], max_new_tokens=6,
                        params=SamplingParams(0.9, 10, 0.95))
    v = model.cfg.vocab_size
    for o in outs:
        assert all(0 <= t < v for t in o)


def test_prompt_isolation(tiny_model):
    """A slot freed by one request must not leak state into the next."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=1, max_seq=64)
    outs = eng.generate([[1, 2, 3], [1, 2, 3]], max_new_tokens=5)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# continuous serving API: submit / step / drain
# ---------------------------------------------------------------------------

def test_submit_step_drain_nonblocking(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    r0 = eng.submit([1, 2, 3], max_new_tokens=4)
    r1 = eng.submit([4, 5], max_new_tokens=6)
    assert r0 != r1
    # stepping by hand: nothing finishes before its token budget
    finished = eng.step()
    assert finished == []
    # submit mid-flight (continuous serving)
    r2 = eng.submit([6, 7, 8], max_new_tokens=2)
    results = eng.drain()
    assert set(results) == {r0, r1, r2}
    assert len(results[r0]) == 4
    assert len(results[r1]) == 6
    assert len(results[r2]) == 2
    # results are handed off exactly once (no unbounded history)
    assert eng.drain() == {}


def test_step_matches_generate(tiny_model):
    """Hand-stepped serving produces the same tokens as generate()."""
    model, params = tiny_model
    ref = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3], [4, 5]], max_new_tokens=5)
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    r0 = eng.submit([1, 2, 3], max_new_tokens=5)
    r1 = eng.submit([4, 5], max_new_tokens=5)
    done = {}
    for _ in range(50):
        for req in eng.step():
            done[req.rid] = req.out
        if len(done) == 2:
            break
    assert [done[r0], done[r1]] == ref


def test_eos_mid_flight(tiny_model):
    """EOS truncates one request mid-flight; the other slots keep going
    and the freed slot is re-used by the queue."""
    model, params = tiny_model
    base = LPUEngine(model, params, slots=2, max_seq=64).generate(
        [[1, 2, 3]], max_new_tokens=8)[0]
    # pick an eos id at its FIRST occurrence past the first token, so the
    # truncation point is unambiguous (greedy decode repeats tokens)
    k = next((i for i in range(1, len(base)) if base[i] not in base[:i]),
             None)
    if k is None:
        pytest.skip("degenerate greedy output: no unique mid-flight token")
    eos = base[k]
    eng = LPUEngine(model, params, slots=2, max_seq=64, eos_id=eos)
    outs = eng.generate([[1, 2, 3], [4, 5], [6, 7, 8, 9]],
                        max_new_tokens=8)
    assert outs[0] == base[:k + 1]              # truncated at eos
    assert outs[0][-1] == eos
    assert all(len(o) <= 8 for o in outs)


def test_slot_release_readmission_order(tiny_model):
    """Queued requests are admitted FIFO as slots free up, and early
    finishers release their slot mid-flight."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    order = []
    rids = []
    # 5 requests on 2 slots; first two finish fast
    for i, (p, n) in enumerate([([1, 2], 2), ([3, 4], 2), ([5, 6], 4),
                                ([7, 8], 4), ([9, 10], 4)]):
        rids.append(eng.submit(p, max_new_tokens=n))
    while eng.sched.has_work():
        for req in eng.step():
            order.append(req.rid)
    # the two short requests finish first, and every request completes
    assert set(order) == set(rids)
    assert set(order[:2]) == set(rids[:2])
    assert eng.stats.occupancy > 0.5


def test_submit_rejects_invalid_prompts(tiny_model):
    """Over-long / empty prompts fail synchronously at submit(), not
    mid-step after a slot has been claimed."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=32)
    with pytest.raises(ValueError):
        eng.submit(list(range(1, 40)), max_new_tokens=4)
    with pytest.raises(ValueError):
        eng.submit([], max_new_tokens=4)
    # engine still serves normally afterwards
    outs = eng.generate([[1, 2, 3]], max_new_tokens=3)
    assert len(outs[0]) == 3


def test_recurrent_family_prefill_not_bucketed():
    """Pow2 bucket padding must NOT be applied to recurrent-state
    families: mamba/rwkv fold every prefill position into their state,
    so padded tokens would change the generated continuation.  Outputs
    must be invariant to min_bucket."""
    cfg = get_config("jamba-v0.1-52b").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    e1 = LPUEngine(model, params, slots=2, max_seq=64, min_bucket=4)
    e2 = LPUEngine(model, params, slots=2, max_seq=64, min_bucket=32)
    assert not e1.paged and not e1.bucketed
    prompts = [[1, 2, 3, 4, 5], [6, 7]]       # off-bucket lengths
    assert e1.generate(prompts, max_new_tokens=4) == \
        e2.generate(prompts, max_new_tokens=4)


# ---------------------------------------------------------------------------
# ring-parallel serving (C2/C3): tp=2 shard_map engine == tp=1 dense engine
# ---------------------------------------------------------------------------

RING_PREAMBLE = """
    import jax, numpy as np
    from repro.compiler.mapper import plan_model
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.registry import build_model
    from repro.serving.engine import LPUEngine, MultiRingEngine

    cfg = get_config('smollm-135m').reduced()
    plan1 = plan_model(cfg, None, (1,), 'serve', esl_overlap=False,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m1 = build_model(cfg, plan1)
    p1, _ = m1.init(jax.random.PRNGKey(0))
    plan2 = plan_model(cfg, ('model',), (2,), 'serve', esl_overlap=True,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m2 = build_model(cfg, plan2)
    p2, _ = m2.init(jax.random.PRNGKey(0))
    prompts = [[1,2,3,4,5,6,7],[8,9,10,11,12],[13,14,15],[16,17,18,19]]
    ref = LPUEngine(m1, p1, slots=3, max_seq=64, paged=False).generate(
        prompts, max_new_tokens=10)
"""


@pytest.mark.slow
def test_ring_sharded_paged_engine_matches_dense_tp1():
    """tp=2 shard_map engine (ESL overlap, paged per-rank pools) must
    produce bit-identical token streams to the tp=1 dense engine, and
    each rank must hold exactly half the pool bytes."""
    from tests.util import run_multidevice
    out = run_multidevice(RING_PREAMBLE + """
    mesh = make_serving_mesh(tp=2, rings=1)
    eng = LPUEngine(m2, p2, slots=3, max_seq=64, paged=True,
                    block_size=16, mesh=mesh)
    got = eng.generate(prompts, max_new_tokens=10)
    assert got == ref, (got, ref)
    assert eng.per_rank_kv_bytes() * 2 == eng.kv_cache_bytes()
    # dense ring cache too (the contiguous fast path under tp)
    engd = LPUEngine(m2, p2, slots=3, max_seq=64, paged=False, mesh=mesh)
    assert engd.generate(prompts, max_new_tokens=10) == ref
    print('PASS')
    """, n_devices=2)
    assert "PASS" in out


@pytest.mark.slow
def test_ring_sharded_engine_parity_under_preemption():
    """A pool too small for the working set forces recompute preemption
    on the ring engine; the token streams must STILL match the tp=1
    dense engine (recompute is exact)."""
    from tests.util import run_multidevice
    out = run_multidevice(RING_PREAMBLE + """
    mesh = make_serving_mesh(tp=2, rings=1)
    eng = LPUEngine(m2, p2, slots=3, max_seq=64, paged=True,
                    block_size=8, num_blocks=4, mesh=mesh)
    got = eng.generate(prompts, max_new_tokens=10)
    assert eng.stats.preemptions > 0, 'pool was meant to force preemption'
    assert got == ref, (got, ref)
    print('PASS', eng.stats.preemptions)
    """, n_devices=2)
    assert "PASS" in out


@pytest.mark.slow
def test_multi_ring_engine_isolated_and_balanced():
    """2 x (tp=2) sub-ring fleet: disjoint device groups, least-loaded
    routing, and the merged token streams equal the tp=1 reference."""
    from tests.util import run_multidevice
    out = run_multidevice(RING_PREAMBLE + """
    mesh = make_serving_mesh(tp=2, rings=2)
    fleet = MultiRingEngine(m2, p2, mesh, ring_size=2, slots=2,
                            max_seq=64, paged=True, block_size=16)
    assert fleet.n_rings == 2
    assert fleet.ring_cfg.validate_disjoint()
    devs = [set(d.id for d in e.mesh.devices.flat) for e in fleet.engines]
    assert not (devs[0] & devs[1]), devs
    got = fleet.generate(prompts, max_new_tokens=10)
    assert got == ref, (got, ref)
    assert sorted(fleet.router.routed) == [2, 2]
    # stats count decode tokens; each request's first token is prefill's
    assert sum(s.tokens for s in fleet.per_ring_stats()) == 4 * (10 - 1)
    print('PASS')
    """, n_devices=4)
    assert "PASS" in out


def test_engine_stats_monitoring(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64)
    eng.generate([[1, 2, 3], [4, 5], [6, 7]], max_new_tokens=4)
    st = eng.stats
    assert st.tokens > 0 and st.steps > 0
    assert 0 < st.occupancy <= 1.0
    assert st.prefills == 3
    assert 1 <= st.prefill_traces <= 7          # log2(64)+1 buckets max
    assert eng.kv_cache_bytes() > 0
