"""Prefix caching + copy-on-write (--prefix-cache): parity + accounting.

A shared system prompt's KV blocks are prefilled once, registered in a
block-aligned hash index, and mapped (refcounted) into every later
request's block table — only the un-cached tail prefills.  These tests
pin the contract:

* **Parity** — cache-hit token streams are bit-identical to cold-start
  streams for greedy decoding: monolithic and chunked prefill, under
  copy-on-write divergence, recompute preemption, LRU eviction, and the
  tp=2 ring engine.
* **Accounting** — refcounts, LRU parking/revival, eviction-driven
  index invalidation, and the EngineStats counters the serving bench
  gates on (``prefix_hit_blocks``, ``prefill_tokens_saved``,
  ``evicted_blocks``, ``cow_blocks``).
"""
import jax
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine
from repro.serving.kv_cache import BlockPool, PrefixCache

VOCAB = 512     # smollm reduced()


def _shared_prompts(seed, sys_len, tails):
    """A seeded shared system prompt + per-request random tails.

    Like test_chunked_prefill._prompts, seeds are picked for robust
    greedy top-2 margins so bit-identity comparisons don't flake under
    XLA CPU's thread-dependent GEMM blocking.  The final prompt is the
    bare system prompt itself — an exact block-multiple duplicate, so
    the n-1 cache cap forces a tail prefill into a shared block.
    """
    rng = np.random.RandomState(seed)
    sysp = list(map(int, rng.randint(1, VOCAB, size=sys_len)))
    return [sysp + list(map(int, rng.randint(1, VOCAB, size=n)))
            for n in tails] + [list(sysp)]


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


# ---------------------------------------------------------------------------
# pool accounting: refcounts, LRU parking, eviction
# ---------------------------------------------------------------------------

def test_block_pool_share_refcount_lru_and_eviction():
    """A cached block parks in the LRU at ref 0 (still counted free),
    revives on share, and is only recycled after the plain free list
    drains — firing on_evict exactly once, LRU-oldest first."""
    evicted = []
    pool = BlockPool(num_blocks=5, block_size=8)
    pool.on_evict = evicted.append
    a, b = pool.alloc(2)
    pool.mark_cached(a)
    pool.mark_cached(b)
    pool.share([a])                     # second table maps block a
    assert pool.ref[a] == 2
    pool.free([a])
    assert pool.ref[a] == 1             # still live, not parked
    pool.free([a, b])                   # ref 0 -> LRU, oldest = a
    assert pool.num_free == 4           # parked blocks stay allocatable
    pool.share([b])                     # revive b from the LRU
    assert pool.ref[b] == 1 and pool.num_free == 3
    pool.free([b])                      # park again; LRU order a, b
    got = pool.alloc(4)                 # 2 from free list, then evict a, b
    assert evicted == [a, b]
    assert pool.evicted_blocks == 2
    assert a in got and b in got
    with pytest.raises(ValueError, match="share of free"):
        pool2 = BlockPool(num_blocks=4, block_size=8)
        pool2.share([2])                # never allocated, never cached


def test_prefix_cache_match_register_eviction():
    """Register/match roundtrip over the chained block hashes: full
    blocks hit in order, the cap leaves >= 1 tail token, a diverging
    block breaks the chain, and pool eviction invalidates the index."""
    pool = BlockPool(num_blocks=6, block_size=4)
    cache = PrefixCache(pool)
    toks = list(range(100, 111))                  # 11 tokens = 2 full blocks
    blocks = pool.alloc(3)
    cache.register(toks, blocks)
    # exact-multiple prompt: cap at n-1 keeps one tail token uncached
    shared, cached = cache.match(toks[:8])
    assert shared == blocks[:2] and cached == 7
    # longer prompt with the same prefix: both full blocks hit
    shared, cached = cache.match(toks + [1, 2])
    assert shared == blocks[:2] and cached == 8
    # divergence inside block 1 breaks the chain after block 0
    shared, cached = cache.match(toks[:4] + [9, 9, 9, 9, 9])
    assert shared == blocks[:1] and cached == 4
    assert cache.match([1, 2, 3]) == ([], 0)      # cold miss
    # evicting a block drops its index entry -> chain stops there
    pool.free(blocks)       # 2 registered blocks park in the LRU; the
    #                         partial 3rd joins the 2-entry free list
    pool.alloc(4)           # drains the free list, then evicts LRU-oldest
    shared, cached = cache.match(toks)
    assert pool.evicted_blocks == 1
    assert shared == [] and cached == 0           # chain head evicted


# ---------------------------------------------------------------------------
# parity: prefix-cache hits are invisible in the token streams
# ---------------------------------------------------------------------------

def test_prefix_on_matches_off(tiny_model):
    """Shared 3-block system prompt across 4 requests (incl. an exact
    block-multiple duplicate): on/off streams are bit-identical while
    the on-engine demonstrably skips resident prefill work."""
    model, params = tiny_model
    prompts = _shared_prompts(7, 48, (7, 5, 3))
    kw = dict(slots=3, max_seq=64, paged=True, block_size=16)
    ref_eng = LPUEngine(model, params, **kw)
    ref = ref_eng.generate(prompts, max_new_tokens=8)
    eng = LPUEngine(model, params, prefix_cache=True, **kw)
    assert eng.generate(prompts, max_new_tokens=8) == ref
    st = eng.stats
    assert st.prefix_hits >= 3 and st.prefix_hit_blocks >= 9
    assert st.prefill_tokens_saved >= 3 * 48 - 1
    off = ref_eng.stats
    assert off.prefix_hits == off.prefill_tokens_saved == 0


def test_cow_on_concurrent_divergence(tiny_model):
    """Two identical prompts in flight at once share their blocks; the
    first divergent decode append must copy-on-write, not corrupt the
    sibling — streams stay identical to the prefix-off run."""
    model, params = tiny_model
    rng = np.random.RandomState(13)
    p = list(map(int, rng.randint(1, VOCAB, size=32)))
    prompts = [list(p), list(p)]
    kw = dict(slots=2, max_seq=64, paged=True, block_size=16)
    ref = LPUEngine(model, params, **kw).generate(prompts,
                                                  max_new_tokens=8)
    eng = LPUEngine(model, params, prefix_cache=True, **kw)
    assert eng.generate(prompts, max_new_tokens=8) == ref
    assert eng.stats.cow_blocks >= 1, \
        "concurrent identical prompts were meant to force copy-on-write"
    assert eng.stats.prefill_tokens_saved > 0


def test_chunked_prefill_composes_with_prefix(tiny_model):
    """--prefill-chunk + --prefix-cache: only the un-cached tail is
    chunk-prefilled, and streams still match the monolithic cold run."""
    model, params = tiny_model
    prompts = _shared_prompts(3, 48, (7, 5, 3))
    kw = dict(slots=3, max_seq=64, paged=True, block_size=16)
    ref = LPUEngine(model, params, **kw).generate(prompts,
                                                  max_new_tokens=8)
    eng = LPUEngine(model, params, prefix_cache=True, prefill_chunk=16,
                    **kw)
    assert eng.generate(prompts, max_new_tokens=8) == ref
    assert eng.stats.prefill_tokens_saved > 0


def test_preemption_with_shared_blocks(tiny_model):
    """Recompute preemption while shared blocks are mapped into several
    tables: victims drop only their own references, survivors' KV stays
    intact, and every stream matches the dense reference.  The pool is
    sized so decode growth forces both preemption and LRU eviction of
    cold cached blocks; afterwards no reference leaks."""
    model, params = tiny_model
    prompts = _shared_prompts(21, 16, (3, 5, 2, 4))
    ref = LPUEngine(model, params, slots=3, max_seq=64,
                    paged=False).generate(prompts, max_new_tokens=20)
    eng = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                    block_size=8, num_blocks=6, prefix_cache=True)
    got = eng.generate(prompts, max_new_tokens=20)
    st = eng.stats
    assert st.preemptions > 0, "pool was meant to force preemption"
    assert st.prefix_hits > 0 and st.prefill_tokens_saved > 0
    assert st.evicted_blocks > 0
    assert got == ref
    pool = eng.sched.pool
    assert all(r == 0 for r in pool.ref[1:]), "leaked block references"
    assert pool.num_free == pool.num_blocks - 1


def test_prefix_cache_requires_paged(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError, match="paged"):
        LPUEngine(model, params, slots=2, max_seq=64, paged=False,
                  prefix_cache=True)


# ---------------------------------------------------------------------------
# ring tp: prefix hits inside the shard_map engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ring_prefix_matches_dense_tp1():
    """tp=2 shard_map engine with prefix caching (shared blocks mapped
    into per-rank head-sharded pools, CoW via the sharded block-copy
    program) must produce bit-identical streams to the tp=1 dense
    engine while actually hitting the cache."""
    from tests.util import run_multidevice
    out = run_multidevice("""
    import jax, numpy as np
    from repro.compiler.mapper import plan_model
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.registry import build_model
    from repro.serving.engine import LPUEngine

    cfg = get_config('smollm-135m').reduced()
    plan1 = plan_model(cfg, None, (1,), 'serve', esl_overlap=False,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m1 = build_model(cfg, plan1)
    p1, _ = m1.init(jax.random.PRNGKey(0))
    plan2 = plan_model(cfg, ('model',), (2,), 'serve', esl_overlap=True,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m2 = build_model(cfg, plan2)
    p2, _ = m2.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)      # margin-robust shared-prefix
    sysp = list(map(int, rng.randint(1, 512, size=48)))   # trace, see
    prompts = [sysp + list(map(int, rng.randint(1, 512, size=n)))
               for n in (7, 5, 3)] + [list(sysp)]   # _shared_prompts
    ref = LPUEngine(m1, p1, slots=3, max_seq=64, paged=False).generate(
        prompts, max_new_tokens=8)
    mesh = make_serving_mesh(tp=2, rings=1)
    eng = LPUEngine(m2, p2, slots=3, max_seq=64, paged=True,
                    block_size=16, mesh=mesh, prefix_cache=True)
    got = eng.generate(prompts, max_new_tokens=8)
    assert got == ref, (got, ref)
    assert eng.stats.prefix_hits >= 3
    assert eng.stats.prefill_tokens_saved > 0
    print('PASS')
    """, n_devices=2)
    assert "PASS" in out
