"""Config sanity: every assigned arch matches its published dims."""
import pytest

from repro.configs import ASSIGNED, REGISTRY, SHAPES, assigned_cells, \
    get_config


EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "whisper-tiny": (4, 384, 6, 6, 1536, 51_865),
    "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151_936),
    "deepseek-coder-33b": (62, 7168, 56, 8, 19_200, 32_256),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122_753),
    "smollm-135m": (30, 576, 9, 3, 1536, 49_152),
    "llava-next-34b": (60, 7168, 56, 8, 20_480, 64_000),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49_155),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202_048),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14_336, 65_536),
    "rwkv6-7b": (32, 4096, 64, 0, 14_336, 65_536),
}


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_assigned_dims(name):
    cfg = get_config(name)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == EXPECTED[name]


def test_ten_archs_assigned():
    assert len(ASSIGNED) == 10


def test_cells_account_for_40():
    run, skip = assigned_cells()
    assert len(run) + len(skip) == 40
    # long_500k runs only for sub-quadratic archs
    for arch, shape in run:
        if shape == "long_500k":
            assert get_config(arch).family in ("hybrid", "rwkv")


@pytest.mark.parametrize("name,lo,hi", [
    ("smollm-135m", 0.12e9, 0.15e9),
    ("deepseek-coder-33b", 32e9, 35e9),
    ("qwen1.5-4b", 3.5e9, 4.4e9),
    ("minicpm-2b", 2.4e9, 3.1e9),
    ("jamba-v0.1-52b", 50e9, 54e9),
    ("rwkv6-7b", 6.6e9, 7.6e9),
    ("llama4-maverick-400b-a17b", 380e9, 420e9),
    ("opt-66b", 64e9, 68e9),
    ("gpt3-20b", 19e9, 22e9),
])
def test_param_counts_match_names(name, lo, hi):
    assert lo <= get_config(name).total_params() <= hi


@pytest.mark.parametrize("name,lo,hi", [
    ("granite-moe-3b-a800m", 0.7e9, 1.0e9),      # a800m
    ("llama4-maverick-400b-a17b", 12e9, 20e9),   # a17b
    ("jamba-v0.1-52b", 10e9, 14e9),              # published ~12B active
])
def test_moe_active_params(name, lo, hi):
    assert lo <= get_config(name).active_params() <= hi


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_reduced_configs_small(name):
    red = get_config(name).reduced()
    assert red.total_params() < 50e6
    assert red.d_model <= 256


def test_shapes_table():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].global_batch == 1
