"""Paged KV cache: block allocator, bucketing, paged/dense parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.kernels.decode_attention import (decode_attention,
                                            gather_kv_pages,
                                            paged_decode_attention,
                                            paged_decode_attention_ref)
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas, paged_decode_attention_pallas)
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine
from repro.serving.kv_cache import BlockPool, blocks_for, bucket_for


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_block_pool_alloc_free_accounting():
    pool = BlockPool(num_blocks=8, block_size=16)
    assert pool.num_free == 7                      # block 0 reserved
    a = pool.alloc(3)
    assert a is not None and len(a) == 3
    assert 0 not in a                              # null block never granted
    assert pool.num_used == 3
    assert pool.used_bytes(100) == 300
    b = pool.alloc(4)
    assert b is not None and not set(a) & set(b)
    assert pool.alloc(1) is None                   # exhausted: no grant
    pool.free(a)
    assert pool.num_free == 3
    c = pool.alloc(3)
    assert c is not None


def test_block_pool_double_free_rejected():
    pool = BlockPool(num_blocks=4, block_size=16)
    a = pool.alloc(2)
    pool.free(a)
    with pytest.raises(ValueError):
        pool.free(a)
    with pytest.raises(ValueError):
        pool.free([0])                             # null block untouchable


def test_block_pool_bad_free_rejected():
    """Freeing ids the pool never granted must raise, not silently
    corrupt another table's refcounts (blocks are shared under the
    prefix cache, so a bad free can recycle a live block)."""
    pool = BlockPool(num_blocks=4, block_size=16)
    with pytest.raises(ValueError, match="never-allocated"):
        pool.free([2])                             # in range, never granted
    with pytest.raises(ValueError, match="bad block id"):
        pool.free([4])                             # out of range
    with pytest.raises(ValueError, match="bad block id"):
        pool.free([-1])
    a = pool.alloc(1)
    pool.share(a)                                  # ref 2: two tables
    pool.free(a)
    pool.free(a)                                   # both owners release
    with pytest.raises(ValueError, match="double free"):
        pool.free(a)                               # third free is a bug
    assert pool.num_free == 3                      # accounting intact


def test_bucket_for_pow2():
    assert bucket_for(1, 256) == 16
    assert bucket_for(16, 256) == 16
    assert bucket_for(17, 256) == 32
    assert bucket_for(100, 256) == 128
    assert bucket_for(200, 256) == 256
    assert bucket_for(5, 256, min_bucket=64) == 64
    with pytest.raises(ValueError):
        bucket_for(300, 256)
    # bucket count over all lengths is O(log2 max_seq)
    buckets = {bucket_for(n, 256) for n in range(1, 257)}
    assert len(buckets) <= 5


def test_blocks_for():
    assert blocks_for(1, 16) == 1
    assert blocks_for(16, 16) == 1
    assert blocks_for(17, 16) == 2
    assert blocks_for(0, 16) == 1                  # at least one block


# ---------------------------------------------------------------------------
# paged decode attention: parity with the dense kernel
# ---------------------------------------------------------------------------

def _paged_inputs(key, B=2, H=4, G=2, dh=128, bs=128, T=4, N=9):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    k_pages = jax.random.normal(ks[1], (N, bs, G, dh), jnp.float32)
    v_pages = jax.random.normal(ks[2], (N, bs, G, dh), jnp.float32)
    tables = jnp.asarray([[1, 3, 5, 0], [2, 4, 6, 8]], jnp.int32)
    lengths = jnp.asarray([3 * bs - 5, 4 * bs - 61], jnp.int32)
    return q, k_pages, v_pages, tables, lengths


def test_paged_kernel_bit_compatible_with_dense():
    """Same tile size => identical accumulation order => bitwise equal."""
    q, kp, vp, tables, lengths = _paged_inputs(jax.random.PRNGKey(0))
    bs = kp.shape[1]
    kd = gather_kv_pages(kp, tables)
    vd = gather_kv_pages(vp, tables)
    dense = decode_attention_pallas(q, kd, vd, lengths, block_s=bs)
    paged = paged_decode_attention_pallas(q, kp, vp, tables, lengths)
    assert np.array_equal(np.asarray(dense), np.asarray(paged))


def test_paged_ops_matches_dense_ops():
    q, kp, vp, tables, lengths = _paged_inputs(jax.random.PRNGKey(1))
    kd = gather_kv_pages(kp, tables)
    vd = gather_kv_pages(vp, tables)
    dense = decode_attention(q, kd, vd, lengths)
    paged = paged_decode_attention(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(paged),
                               rtol=2e-5, atol=2e-5)


def test_paged_ref_fallback_matches_pallas():
    q, kp, vp, tables, lengths = _paged_inputs(jax.random.PRNGKey(2))
    pal = paged_decode_attention(q, kp, vp, tables, lengths)
    ref = paged_decode_attention(q, kp, vp, tables, lengths,
                                 use_pallas=False)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_ref_gqa_expansion():
    """Oracle on H-expanded pages equals grouped pallas path."""
    q, kp, vp, tables, lengths = _paged_inputs(jax.random.PRNGKey(3))
    H, G = q.shape[1], kp.shape[2]
    gs = H // G
    ke = jnp.repeat(kp, gs, axis=2)
    ve = jnp.repeat(vp, gs, axis=2)
    ref = paged_decode_attention_ref(q, ke, ve, tables, lengths)
    pal = paged_decode_attention(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=2e-5, atol=2e-5)


def test_null_block_never_contributes():
    """Table entries past the valid length (null block 0) are masked."""
    q, kp, vp, tables, lengths = _paged_inputs(jax.random.PRNGKey(4))
    bs = kp.shape[1]
    lengths = jnp.asarray([2 * bs, 3 * bs], jnp.int32)   # 2/3 blocks valid
    out1 = paged_decode_attention(q, kp, vp, tables, lengths)
    # scribble over the null block AND the unused tail blocks
    kp2 = kp.at[0].set(1e3).at[8].set(-1e3)
    vp2 = vp.at[0].set(1e3).at[8].set(-1e3)
    tables2 = tables.at[0, 3].set(0).at[1, 3].set(0)
    out2 = paged_decode_attention(q, kp2, vp2, tables2, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# engine-level parity + preemption
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10, 11],
           [3, 1, 4, 1, 5, 9, 2, 6], [2, 7]]


def test_engine_paged_matches_dense(tiny_model):
    model, params = tiny_model
    dense = LPUEngine(model, params, slots=3, max_seq=64, paged=False)
    paged = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                      block_size=16)
    od = dense.generate(PROMPTS, max_new_tokens=8)
    op = paged.generate(PROMPTS, max_new_tokens=8)
    assert od == op
    assert paged.stats.prefill_traces <= 7       # log2(64)+1


def test_engine_pool_exhaustion_preempts(tiny_model):
    """A pool too small for all slots forces recompute preemption, and
    the outputs still match the dense engine exactly."""
    model, params = tiny_model
    dense = LPUEngine(model, params, slots=3, max_seq=64, paged=False)
    od = dense.generate(PROMPTS, max_new_tokens=20)
    # 3 slots x up to 28 resident tokens, but only 3 usable 8-tok blocks:
    # at most ~1 sequence's worth of KV is resident at a time
    paged = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                      block_size=8, num_blocks=5)
    op = paged.generate(PROMPTS, max_new_tokens=20)
    assert paged.stats.preemptions > 0
    assert od == op


def test_engine_single_seq_pool_overflow_raises(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                    block_size=8, num_blocks=3)   # 2 usable blocks = 16 tok
    with pytest.raises(RuntimeError):
        eng.generate([[1, 2, 3]], max_new_tokens=30)


def test_engine_prompt_longer_than_pool_rejected(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                    block_size=8, num_blocks=3)
    with pytest.raises(ValueError):
        eng.submit(list(range(1, 30)), max_new_tokens=4)


def test_scheduler_impossible_resume_rejected():
    """A preempted request whose resume state outgrew the pool must be
    popped with a structured reason instead of livelocking the admission
    loop (or crashing the whole engine over one doomed request)."""
    from repro.serving.scheduler import Scheduler

    class FakeReq:
        rid = 0
        prompt = list(range(10))
        out = list(range(30))

        def resume_tokens(self):
            return self.prompt + self.out[:-1]    # 39 tokens > 24-tok pool

    sched = Scheduler(2, 64, BlockPool(4, 8))     # 3 usable blocks
    sched.queue.append(FakeReq())                 # as if re-queued
    assert sched.admit_next() is None             # no crash, no livelock
    rejected = sched.take_rejected()
    assert len(rejected) == 1 and rejected[0][0].rid == 0
    assert "blocks" in rejected[0][1]
    assert not sched.queue                        # popped, not spun on


def test_paged_pool_smaller_than_dense(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=4, max_seq=64, paged=True,
                    block_size=16, num_blocks=9)   # half dense capacity
    outs = eng.generate(PROMPTS, max_new_tokens=8)
    assert all(len(o) == 8 for o in outs)
    assert eng.kv_cache_bytes() < eng.dense_equiv_bytes()
