"""Multi-device correctness (8 fake devices, subprocess): ESL overlap and
blocking modes must equal the single-device reference; serve step works
under full manual sharding."""
import pytest

from tests.util import run_multidevice


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-135m", "granite-moe-3b-a800m",
                                  "jamba-v0.1-52b", "rwkv6-7b"])
def test_distributed_loss_matches_reference(arch):
    out = run_multidevice(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_config
    from repro.compiler.mapper import plan_model
    from repro.models.registry import build_model
    from repro.core.compat import make_mesh, shard_map
    from repro.core.dist import make_axis_env
    from repro.core.steps import make_gather_fn
    from repro.models.transformer import sharded_xent

    mesh = make_mesh((2,4), ('data','model'))
    cfg = get_config({arch!r}).reduced()
    B,S = 4,16
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B,S), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(8), (B,S), 0,
                                cfg.vocab_size)
    plan1 = plan_model(cfg, None, (1,), 'train', esl_overlap=False,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m1 = build_model(cfg, plan1)
    p1, _ = m1.init(jax.random.PRNGKey(0))
    env1 = make_axis_env(plan1, batch=B)
    lg, _, _ = m1.forward(p1, tokens, env=env1, mode='train')
    ls, cnt = sharded_xent(lg, labels, env1)
    ref = float(ls/cnt)
    for overlap in (False, True):
        plan4 = plan_model(cfg, ('data','model'), (2,4), 'train',
                           esl_overlap=overlap, remat='none',
                           compute_dtype='float32', param_dtype='float32')
        m4 = build_model(cfg, plan4)
        p4, _ = m4.init(jax.random.PRNGKey(0))
        specs, _ = m4.param_specs()
        p4 = jax.device_put(p4, jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P)))
        env4 = make_axis_env(plan4, batch=B)
        def loss4(p, tok, lab):
            gf = make_gather_fn(plan4, env4, specs)
            lg, _, _ = m4.forward(p, tok, env=env4, mode='train',
                                  gather_fn=gf)
            ls, c = sharded_xent(lg, lab, env4)
            ls = jax.lax.psum(ls, ('data',))
            c = jax.lax.psum(c, ('data',))
            return ls/c
        f = jax.jit(shard_map(loss4, mesh=mesh,
            in_specs=(specs, P('data',None), P('data',None)),
            out_specs=P(), check_vma=False))
        got = float(f(p4, tokens, labels))
        tol = 2e-2 if cfg.moe is not None else 5e-3
        assert abs(got-ref) < tol*max(1,abs(ref)), (overlap, got, ref)
    print('PASS')
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_distributed_serve_step_and_grads():
    out = run_multidevice("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_config
    from repro.compiler.mapper import plan_model
    from repro.models.registry import build_model
    from repro.core.compat import make_mesh
    from repro.core.steps import (build_serve_step, build_train_step)
    from repro.optim import AdamW, get_schedule

    mesh = make_mesh((2,4), ('data','model'))
    cfg = get_config('smollm-135m').reduced()
    plan = plan_model(cfg, ('data','model'), (2,4), 'serve',
                      remat='none', compute_dtype='float32',
                      param_dtype='float32')
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    specs, _ = model.param_specs()
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P)))
    step, meta = build_serve_step(model, mesh, 4, 32)
    cache = model.init_cache(4, 32, dtype=jnp.float32)
    cspecs = meta['cache_specs']
    cache = jax.device_put(cache, jax.tree.map(
        lambda s: NamedSharding(mesh, s), cspecs,
        is_leaf=lambda x: isinstance(x, P)))
    toks = jnp.ones((4,1), jnp.int32)
    pos = jnp.zeros((4,), jnp.int32)
    nxt, cache2 = jax.jit(step)(params, cache, toks, pos)
    assert nxt.shape == (4,)
    assert int(nxt.min()) >= 0 and int(nxt.max()) < cfg.vocab_size

    # distributed train step end-to-end (FSDP gathers + optimizer)
    plan_t = plan_model(cfg, ('data','model'), (2,4), 'train',
                        remat='block', compute_dtype='float32',
                        param_dtype='float32')
    model_t = build_model(cfg, plan_t)
    params_t, _ = model_t.init(jax.random.PRNGKey(0))
    specs_t, _ = model_t.param_specs()
    params_t = jax.device_put(params_t, jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs_t,
        is_leaf=lambda x: isinstance(x, P)))
    opt = AdamW(lr=get_schedule('cosine', 1e-3, 2, 10))
    tstep, _ = build_train_step(model_t, opt, mesh, 4)
    opt_state = opt.init(params_t)
    batch = {'tokens': jnp.ones((4,16), jnp.int32),
             'labels': jnp.ones((4,16), jnp.int32)}
    p2, o2, m2 = jax.jit(tstep)(params_t, opt_state, batch)
    l1 = float(m2['loss'])
    p3, o3, m3 = jax.jit(tstep)(p2, o2, batch)
    assert float(m3['loss']) < l1   # same batch twice => loss drops
    print('PASS')
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_grouped_subring_esl_matches_per_ring_reference():
    """C3 grouped style: one program, one mesh axis, 2 sub-rings of 2 —
    ag/rs matmuls with ``ring=RingConfig(4,2)`` must equal each ring's
    independent tp=2 reference, in overlap and blocking modes alike."""
    out = run_multidevice("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import esl
    from repro.core.compat import make_mesh, shard_map
    from repro.core.rings import RingConfig

    mesh = make_mesh((4,), ('model',))
    ring = RingConfig(total=4, ring_size=2)
    B, D, N = 3, 16, 8          # per-ring: x (B,D) -> y (B,N) -> z (B,D)
    k = jax.random.PRNGKey(0)
    xs = jax.random.normal(k, (2, B, D))            # one input per ring
    w1 = jax.random.normal(jax.random.PRNGKey(1), (2, D, N))
    w2 = jax.random.normal(jax.random.PRNGKey(2), (2, N, D))
    # global layouts: ring r's tensors occupy its ranks' shards
    xg = xs.transpose(1, 0, 2).reshape(B, 2 * D)    # (B, rings*D)
    w1g = jnp.concatenate([w1[0], w1[1]], -1)       # (D, rings*N)
    w2g = jnp.concatenate([w2[0], w2[1]], 0)        # (rings*N, D)

    def run(overlap):
        def inner(x_l, w1_l, w2_l):
            h = esl.ag_matmul(x_l, w1_l, axis='model', tp=2,
                              overlap=overlap, scattered_in=True,
                              ring=ring)
            return esl.rs_matmul(h, w2_l, axis='model', tp=2,
                                 overlap=overlap, scatter_out=True,
                                 ring=ring)
        return shard_map(inner, mesh=mesh,
            in_specs=(P(None, 'model'), P(None, 'model'),
                      P('model', None)),
            out_specs=P(None, 'model'), check_vma=False)(xg, w1g, w2g)

    refs = [np.asarray((xs[r] @ w1[r]) @ w2[r]) for r in range(2)]
    for overlap in (False, True):
        z = np.asarray(run(overlap)).reshape(B, 2, D).transpose(1, 0, 2)
        for r in range(2):
            np.testing.assert_allclose(z[r], refs[r], rtol=2e-5,
                                       atol=2e-5)
    print('PASS')
    """, n_devices=4)
    assert "PASS" in out


@pytest.mark.slow
def test_esl_ring_collectives_in_hlo():
    """ESL mode must lower to collective-permute chains; the blocking
    baseline to all-reduce/all-gather — the paper's schedule contrast."""
    out = run_multidevice("""
    import jax, jax.numpy as jnp, re
    from collections import Counter
    from jax.sharding import PartitionSpec as P
    from repro.core import esl
    from repro.core.compat import make_mesh, shard_map
    mesh = make_mesh((2,4), ('data','model'))
    x = jnp.ones((4,8,32)); w = jnp.ones((32,64)); w2 = jnp.ones((64,32))
    def f(overlap):
        def inner(xs, ws, w2s):
            h = esl.ag_matmul(xs, ws, axis='model', tp=4, overlap=overlap,
                              scattered_in=True)
            return esl.rs_matmul(h, w2s, axis='model', tp=4,
                                 overlap=overlap, scatter_out=True)
        return jax.jit(shard_map(inner, mesh=mesh,
            in_specs=(P('data',None,'model'), P(None,'model'),
                      P('model',None)),
            out_specs=P('data',None,'model'), check_vma=False)
            ).lower(x, w, w2).compile().as_text()
    esl_txt = f(True); base_txt = f(False)
    c_esl = Counter(re.findall(
        r'(all-gather|all-reduce|reduce-scatter|collective-permute)\\b',
        esl_txt))
    c_base = Counter(re.findall(
        r'(all-gather|all-reduce|reduce-scatter|collective-permute)\\b',
        base_txt))
    assert c_esl.get('collective-permute', 0) >= 6, c_esl
    assert c_esl.get('all-gather', 0) == 0, c_esl
    assert c_base.get('all-gather', 0) >= 1, c_base
    assert c_base.get('collective-permute', 0) == 0, c_base
    print('PASS', dict(c_esl), dict(c_base))
    """)
    assert "PASS" in out
