"""Fused (in-jit, batched) sampling vs the host-loop oracle.

The serving engine's fused decode program samples every slot in one
call (`sample_batched`) with per-slot params as device arrays and the
rng chain carried on device (`split_rng_chain`).  These tests pin the
bit-level contract that makes fused and host (synced) engines produce
identical token streams: same filter math per row, same rng-split
order (only active stochastic slots consume), greedy never touches RNG.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampler import (SamplingParams, sample_batched,
                                   sample_local, split_rng_chain)

V = 97          # odd vocab so clamp/padding edges are exercised


def _host_loop(logits, rng, slot_params, active=None):
    """The engine's pre-fusion host path: visit slots in order, greedy
    rows argmax, stochastic rows split-then-sample_local."""
    B = logits.shape[0]
    active = [True] * B if active is None else active
    toks = []
    for i in range(B):
        p = slot_params[i]
        if not active[i]:
            toks.append(-1)
            continue
        if p.temperature <= 0.0:
            toks.append(int(np.argmax(np.asarray(logits[i]))))
            continue
        rng, sub = jax.random.split(rng)
        toks.append(int(sample_local(logits[i][None], sub, p)[0]))
    return toks, rng


@pytest.fixture(scope="module")
def logits():
    return jax.random.normal(jax.random.PRNGKey(3), (5, V),
                             jnp.float32) * 4.0


# -- satellite regression: top_k > vocab must clamp, not crash ----------

def test_sample_local_topk_exceeds_vocab(logits):
    rng = jax.random.PRNGKey(0)
    big = sample_local(logits, rng, SamplingParams(0.8, V + 50, 1.0))
    full = sample_local(logits, rng, SamplingParams(0.8, V, 1.0))
    # clamped k == V keeps every entry -> identical to the k=V draw
    assert big.tolist() == full.tolist()
    assert all(0 <= t < V for t in big.tolist())


def test_sample_local_topk_exact_vocab_edge(logits):
    rng = jax.random.PRNGKey(1)
    # k=V thresholds at the MINIMUM logit -> no entry filtered: same
    # draw as plain temperature sampling
    plain = sample_local(logits, rng, SamplingParams(0.7, 0, 1.0))
    kfull = sample_local(logits, rng, SamplingParams(0.7, V, 1.0))
    assert plain.tolist() == kfull.tolist()


# -- rng chain ----------------------------------------------------------

def test_split_rng_chain_matches_sequential():
    rng = jax.random.PRNGKey(42)
    stoch = jnp.array([True, False, True, True, False])
    new_rng, keys = jax.jit(split_rng_chain)(rng, stoch)
    r = jax.random.PRNGKey(42)
    for i, s in enumerate(stoch.tolist()):
        if s:
            r, sub = jax.random.split(r)
            assert keys[i].tolist() == sub.tolist(), i
    assert new_rng.tolist() == r.tolist()


def test_split_rng_chain_all_greedy_is_identity():
    rng = jax.random.PRNGKey(7)
    new_rng, _ = split_rng_chain(rng, jnp.zeros((4,), bool))
    assert new_rng.tolist() == rng.tolist()


# -- fused == host, bit for bit ----------------------------------------

MIXED = [SamplingParams(0.0, 0, 1.0),        # greedy
         SamplingParams(0.9, 10, 1.0),       # top-k
         SamplingParams(1.1, 0, 0.9),        # top-p
         SamplingParams(0.7, 2 * V, 0.95),   # both, k over-vocab
         SamplingParams(0.8, 0, 1.0)]        # temperature only


def _as_arrays(slot_params):
    return (jnp.asarray([p.temperature for p in slot_params], jnp.float32),
            jnp.asarray([p.top_k for p in slot_params], jnp.int32),
            jnp.asarray([p.top_p for p in slot_params], jnp.float32))


def test_sample_batched_matches_host_loop(logits):
    rng = jax.random.PRNGKey(5)
    want, want_rng = _host_loop(logits, rng, MIXED)
    temps, tks, tps = _as_arrays(MIXED)
    got, got_rng = jax.jit(sample_batched)(logits, rng, temps, tks, tps)
    assert got.tolist() == want
    assert got_rng.tolist() == want_rng.tolist()


def test_sample_batched_inactive_slots_consume_no_rng(logits):
    rng = jax.random.PRNGKey(9)
    active = [True, False, True, False, True]
    want, want_rng = _host_loop(logits, rng, MIXED, active)
    temps, tks, tps = _as_arrays(MIXED)
    got, got_rng = sample_batched(logits, rng, temps, tks, tps,
                                  jnp.asarray(active))
    for i, a in enumerate(active):
        if a:
            assert int(got[i]) == want[i], i
    assert got_rng.tolist() == want_rng.tolist()


def test_sample_batched_all_greedy_rng_untouched(logits):
    rng = jax.random.PRNGKey(13)
    temps = jnp.zeros((5,), jnp.float32)
    got, got_rng = sample_batched(logits, rng, temps,
                                  jnp.zeros((5,), jnp.int32),
                                  jnp.ones((5,), jnp.float32))
    assert got.tolist() == np.argmax(np.asarray(logits), -1).tolist()
    assert got_rng.tolist() == rng.tolist()


@pytest.mark.parametrize("params", MIXED[1:],
                         ids=["topk", "topp", "both-overk", "temp"])
def test_sample_batched_uniform_params_parity(logits, params):
    """Every filter combination separately, whole batch one param set."""
    rng = jax.random.PRNGKey(21)
    want, want_rng = _host_loop(logits, rng, [params] * 5)
    temps, tks, tps = _as_arrays([params] * 5)
    got, got_rng = sample_batched(logits, rng, temps, tks, tps)
    assert got.tolist() == want
    assert got_rng.tolist() == want_rng.tolist()
