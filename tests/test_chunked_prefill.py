"""Chunked prefill (--prefill-chunk): parity + interleave guarantees.

The engine's chunked-prefill mode replaces the monolithic bucketed
prefill with fixed-size chunks interleaved with decode windows.  These
tests pin the two contract halves:

* **Parity** — token streams are bit-identical to monolithic prefill
  for greedy decoding, for any chunk size (sub-block, block-aligned,
  block-crossing), under multi-step fused windows and under recompute
  preemption (incl. preemption of a partially-prefilled prompt).
* **Interleave** — while a long prompt trickles in chunk by chunk,
  every in-flight decode stream keeps producing a token per step and
  ``EngineStats.decode_stalls`` stays zero (the monolithic baseline
  stalls at least once on the same trace).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine

VOCAB = 512     # smollm reduced()


def _prompts(seed, lengths):
    """Seeded random prompts chosen for ROBUST greedy margins.

    Bit-identity assertions compare argmaxes, and XLA CPU's thread-
    count-dependent GEMM blocking jitters logits in the last few bits —
    a trace whose top-2 logit gap ever gets razor-thin (the repo-wide
    `[[1,2,3,...]]` trace has a 2.8e-4 step) flakes under load.  These
    seeds were picked so every sampled step of every test keeps a top-2
    margin >= 5e-3, ~50x the observed jitter.
    """
    rng = np.random.RandomState(seed)
    return [list(map(int, rng.randint(1, VOCAB, size=n)))
            for n in lengths]


# 4 short prompts + one 39-token long prompt (multi-chunk for every
# chunk size under test)
PROMPTS = _prompts(11, (7, 5, 3, 4, 39))


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def mono_ref(tiny_model):
    """Monolithic-prefill reference streams on the shared trace."""
    model, params = tiny_model
    return LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                     block_size=16).generate(PROMPTS, max_new_tokens=8)


# ---------------------------------------------------------------------------
# parity: chunked == monolithic, across chunk sizes and dispatch modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [4, 16, 40])
def test_chunked_matches_monolithic(tiny_model, mono_ref, chunk):
    """Bit-identical greedy streams for a sub-block chunk (4 < block 16),
    a chunk-boundary == block-boundary chunk (16) and a chunk that
    crosses block boundaries mid-chunk (40); the trace includes a
    39-token prompt so every size exercises multi-chunk resume."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                    block_size=16, prefill_chunk=chunk)
    assert eng.generate(PROMPTS, max_new_tokens=8) == mono_ref
    assert eng.stats.decode_stalls == 0
    assert eng.stats.prefill_chunks > len(PROMPTS) or chunk >= 40
    # ONE chunk trace regardless of the prompt-length mix
    assert eng.stats.prefill_traces == 1


def test_chunked_parity_gather_and_host_sampling(tiny_model, mono_ref):
    """The chunk program honors the engine's paged_kernel seam and the
    host-sampling oracle exactly like decode does."""
    model, params = tiny_model
    for kw in (dict(paged_kernel="gather"), dict(sampling="host")):
        eng = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                        block_size=16, prefill_chunk=8, **kw)
        assert eng.generate(PROMPTS, max_new_tokens=8) == mono_ref


def test_chunked_parity_multistep_windows(tiny_model, mono_ref):
    """Chunks interleave with S-step fused windows (double-buffered
    dispatch) without perturbing the streams: prefilling slots are
    frozen null-block rows inside the window."""
    model, params = tiny_model
    eng = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                    block_size=16, prefill_chunk=8, steps_per_sync=4)
    assert eng.generate(PROMPTS, max_new_tokens=8) == mono_ref


def test_chunked_parity_under_preemption(tiny_model):
    """A pool too small for the working set forces recompute preemption
    while prompts are chunk-prefilling; streams must still match the
    roomy-pool monolithic reference (recompute is exact, and a
    preempted partial prefill restarts from scratch)."""
    model, params = tiny_model
    prompts = _prompts(100, (7, 5, 3, 4))
    ref = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                    block_size=16).generate(prompts, max_new_tokens=10)
    eng = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                    block_size=8, num_blocks=4, prefill_chunk=4)
    got = eng.generate(prompts, max_new_tokens=10)
    assert eng.stats.preemptions > 0, "pool was meant to force preemption"
    assert got == ref


def test_mid_prefill_preemption_restarts_cleanly(tiny_model):
    """Preempt a prompt while only PART of it is resident: the victim's
    partial blocks are freed, and on re-admission the whole prompt is
    re-chunked from scratch — the stream still matches the monolithic
    reference.  The 40-token prompt is admitted FIRST (10 chunks of 4),
    so when the younger short stream's growth exhausts the 6 usable
    blocks, the newest-victim rule evicts the long sequence mid-chunk
    sequence (partial KV only, no sampled token yet)."""
    model, params = tiny_model
    long_prompt, short_prompt = _prompts(308, (40, 3))
    refs = {}
    refs[0] = LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                        block_size=16).generate([long_prompt],
                                                max_new_tokens=4)[0]
    refs[1] = LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                        block_size=16).generate([short_prompt],
                                                max_new_tokens=20)[0]
    eng = LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                    block_size=8, num_blocks=7, prefill_chunk=4)
    r0 = eng.submit(long_prompt, max_new_tokens=4)
    r1 = eng.submit(short_prompt, max_new_tokens=20)
    got = eng.drain()
    assert eng.stats.preemptions > 0, "pool was meant to force preemption"
    # the long prompt was re-chunked after eviction: strictly more chunk
    # launches than one clean pass over both prompts (10 + 1)
    assert eng.stats.prefill_chunks > 11
    assert got[r0] == refs[0] and got[r1] == refs[1]


def test_prefill_chunk_requires_paged(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError, match="paged"):
        LPUEngine(model, params, slots=2, max_seq=64, paged=False,
                  prefill_chunk=8)


# ---------------------------------------------------------------------------
# interleave: decode never stalls while a long prompt admits
# ---------------------------------------------------------------------------

def test_decode_stall_regression(tiny_model):
    """While a long prompt becomes resident chunk by chunk, every
    in-flight stream must produce exactly one token per step() — the
    regression this pins is the engine freezing decode for a full
    bucketed prefill (which the monolithic baseline measurably does on
    the same trace)."""
    model, params = tiny_model

    def run(prefill_chunk):
        got = {}

        def cb(rid, tok):
            got.setdefault(rid, []).append(tok)

        p0, p1, p_long = _prompts(208, (3, 4, 64))
        eng = LPUEngine(model, params, slots=3, max_seq=128, paged=True,
                        block_size=16, prefill_chunk=prefill_chunk)
        r0 = eng.submit(p0, max_new_tokens=40, stream_cb=cb)
        r1 = eng.submit(p1, max_new_tokens=40, stream_cb=cb)
        for _ in range(3):
            eng.step()
        r2 = eng.submit(p_long, max_new_tokens=4, stream_cb=cb)
        stalled = 0
        for _ in range(40):
            before = (len(got.get(r0, [])), len(got.get(r1, [])))
            eng.step()
            after = (len(got.get(r0, [])), len(got.get(r1, [])))
            if after == before:
                stalled += 1
            if r2 in got:
                break
        while eng.sched.has_work():
            eng.step()
        eng.drain()
        return eng, got, stalled, (r0, r1, r2)

    chunked, got_c, stalled_c, rids = run(prefill_chunk=8)
    assert chunked.stats.decode_stalls == 0
    # every step of the long prompt's 8-chunk residency also advanced
    # the short streams — no step left them without a new token
    assert stalled_c == 0, \
        f"{stalled_c} steps produced no tokens on active streams"
    mono, got_m, _, _ = run(prefill_chunk=0)
    assert mono.stats.decode_stalls >= 1, \
        "monolithic baseline should stall decode on the long admission"
    # scheduling differs, per-request streams must not
    assert all(got_c[r] == got_m[r] for r in rids)


# ---------------------------------------------------------------------------
# streamline entry: chunk == sequential single-token decode
# ---------------------------------------------------------------------------

def test_streamline_chunk_layer_matches_sequential_decode():
    """The chunk-as-batch reuse of the paged decode fold is exact: one
    chunk_prefill_layer call over S tokens equals feeding the same
    tokens one at a time through decode_layer (same pool, same table),
    including a chunk boundary that is NOT a block boundary."""
    from repro.core.streamline import chunk_prefill_layer, decode_layer
    from repro.models.common import InitCtx
    from repro.models.transformer import init_layer

    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    ctx = InitCtx(jax.random.PRNGKey(0), param_dtype=jnp.float32)
    p = init_layer(ctx, cfg, plan, 0)
    a = plan.attn
    bs, T = 8, 4
    table = jnp.arange(1, T + 1, dtype=jnp.int32)
    S, C = 13, 8                      # 2 chunks; second is padded
    xs = jax.random.normal(jax.random.PRNGKey(1), (S, cfg.d_model))

    pool = {"k": jnp.zeros((T + 1, bs, a.gp, a.d_head)),
            "v": jnp.zeros((T + 1, bs, a.gp, a.d_head))}
    ys, cache = [], pool
    for i in range(S):
        y, cache = decode_layer(p, xs[i:i + 1], cache,
                                jnp.asarray([i], jnp.int32), cfg=cfg,
                                plan=plan, use_kernels=False,
                                block_table=table[None])
        ys.append(np.asarray(y[0]))

    cache_ch = pool
    y1, cache_ch = chunk_prefill_layer(
        p, xs[:C], cache_ch, table, jnp.int32(0), jnp.int32(C),
        cfg=cfg, plan=plan, use_kernels=False)
    chunk2 = jnp.concatenate(
        [xs[C:], jnp.zeros((2 * C - S, cfg.d_model))])
    y2, cache_ch = chunk_prefill_layer(
        p, chunk2, cache_ch, table, jnp.int32(C), jnp.int32(S - C),
        cfg=cfg, plan=plan, use_kernels=False)
    y_chunk = np.concatenate([np.asarray(y1), np.asarray(y2)[:S - C]])
    np.testing.assert_allclose(np.stack(ys), y_chunk, rtol=1e-5,
                               atol=1e-5)
    # the resident KV itself is identical (padded rows hit null block 0)
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(cache[key][1:]),
                                      np.asarray(cache_ch[key][1:]))


def test_streamline_chunk_layer_kernel_parity():
    """use_kernels=True (Pallas gemv + paged kernel, interpret mode)
    matches the jnp oracle for the same chunk."""
    from repro.core.streamline import chunk_prefill_layer
    from repro.models.common import InitCtx
    from repro.models.transformer import init_layer

    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    ctx = InitCtx(jax.random.PRNGKey(0), param_dtype=jnp.float32)
    p = init_layer(ctx, cfg, plan, 0)
    a = plan.attn
    bs, T, C = 8, 4, 8
    table = jnp.arange(1, T + 1, dtype=jnp.int32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (C, cfg.d_model))
    pool = {"k": jnp.zeros((T + 1, bs, a.gp, a.d_head)),
            "v": jnp.zeros((T + 1, bs, a.gp, a.d_head))}
    y_k, c_k = chunk_prefill_layer(p, xs, pool, table, jnp.int32(0),
                                   jnp.int32(C), cfg=cfg, plan=plan,
                                   use_kernels=True, interpret=True)
    y_r, c_r = chunk_prefill_layer(p, xs, pool, table, jnp.int32(0),
                                   jnp.int32(C), cfg=cfg, plan=plan,
                                   use_kernels=False)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(c_k["k"]), np.asarray(c_r["k"]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ring tp: chunked prefill inside the shard_map engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ring_chunked_matches_dense_tp1():
    """tp=2 shard_map engine with chunked prefill (chunk KV scattered
    into per-rank head-sharded pools through replicated tables) must
    produce bit-identical token streams to the tp=1 dense engine."""
    from tests.util import run_multidevice
    out = run_multidevice("""
    import jax, numpy as np
    from repro.compiler.mapper import plan_model
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.registry import build_model
    from repro.serving.engine import LPUEngine

    cfg = get_config('smollm-135m').reduced()
    plan1 = plan_model(cfg, None, (1,), 'serve', esl_overlap=False,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m1 = build_model(cfg, plan1)
    p1, _ = m1.init(jax.random.PRNGKey(0))
    plan2 = plan_model(cfg, ('model',), (2,), 'serve', esl_overlap=True,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m2 = build_model(cfg, plan2)
    p2, _ = m2.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(11)     # margin-robust trace, see
    prompts = [list(map(int, rng.randint(1, 512, size=n)))  # _prompts
               for n in (7, 5, 3, 4, 39)]
    ref = LPUEngine(m1, p1, slots=3, max_seq=64, paged=False).generate(
        prompts, max_new_tokens=8)
    mesh = make_serving_mesh(tp=2, rings=1)
    eng = LPUEngine(m2, p2, slots=3, max_seq=64, paged=True,
                    block_size=16, mesh=mesh, prefill_chunk=8)
    got = eng.generate(prompts, max_new_tokens=8)
    assert got == ref, (got, ref)
    assert eng.stats.decode_stalls == 0
    assert eng.stats.prefill_chunks > 0
    print('PASS')
    """, n_devices=2)
    assert "PASS" in out
