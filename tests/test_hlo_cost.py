"""HLO cost parser: must match XLA cost_analysis on unrolled modules and
correctly multiply while-loop (scan) bodies by trip counts."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.core.hlo_cost import module_cost, xla_cost_analysis


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_unrolled_matches_xla():
    def f(w, x):
        for i in range(8):
            x = x @ w[i]
        return x
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, w, x)
    assert module_cost(c.as_text()).flops == \
        pytest.approx(xla_cost_analysis(c)["flops"], rel=1e-6)


def test_scan_trip_count_multiplied():
    def f(w, x):
        def body(cc, wi):
            return cc @ wi, None
        y, _ = lax.scan(body, x, w)
        return y
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, w, x)
    # XLA counts the body once; parser counts all 8 trips.  XLA's count
    # also includes a few scalar loop-counter flops per trip, so the
    # comparison is approximate at the 1e-5 level.
    assert module_cost(c.as_text()).flops == \
        pytest.approx(8 * xla_cost_analysis(c)["flops"], rel=1e-5)


def test_nested_scan():
    def f(w, x):
        def outer(cc, wi):
            def inner(c2, _):
                return c2 @ wi, None
            c2, _ = lax.scan(inner, cc, None, length=4)
            return c2, None
        y, _ = lax.scan(outer, x, w)
        return y
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, w, x)
    expect = 8 * 4 * 2 * 128 ** 3
    assert module_cost(c.as_text()).flops == pytest.approx(expect, rel=1e-6)


def test_hbm_bytes_scale_with_size():
    def f(x):
        return (x * 2.0).sum()
    small = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    big = _compile(f, jax.ShapeDtypeStruct((512, 512), jnp.float32))
    bs = module_cost(small.as_text()).hbm_bytes
    bb = module_cost(big.as_text()).hbm_bytes
    assert bb > 8 * bs
