"""Speculative decoding (draft-and-verify): the correctness gates.

A drafter proposes k tokens per decode slot; ONE chunk-as-batch verify
pass scores all k+1 positions against the paged pool and on-device
rejection sampling accepts a per-slot prefix.  These tests pin the
contract that makes speculation safe to enable by default:

* **Greedy bit-parity** — speculative token streams are bit-identical
  to the non-speculative engine on every axis: draft_k in {1, 2, 4},
  streamed/gather paged kernels, fused/host sampling, chunked prefill,
  prefix caching, mid-stream preemption, and the tp=2 ring engine.
* **Statistical correctness** — emitted tokens are EXACT draws from
  the target distribution under temperature/top-k/top-p, regardless of
  what the drafter proposed: per-position marginals, the accept
  probability, and engine-level outcome frequencies are all bounded
  against the non-speculative sampler (TV distance).
* **Rollback accounting** — forced-rejection windows leak nothing:
  pool refcounts, free-list size and the prefix-cache index match a
  non-speculative run, including rejected writes aimed at CoW-shared
  blocks.
* **Lookahead reservation** — an all-accept window landing at a block
  boundary writes into freshly reserved blocks, never the null block
  (the ``reserve_lookahead(draft_k=...)`` regression).
* **Nucleus regression** — the top-p cutoff bug this suite's
  statistical gate caught (every non-tied row collapsed to argmax)
  stays fixed.
"""
import math
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.drafter import NGramDrafter, make_drafter
from repro.serving.engine import LPUEngine, Request
from repro.serving.kv_cache import BlockPool
from repro.serving.sampler import (SamplingParams, _filter_row,
                                   sample_local, spec_verify_rows,
                                   split_spec_rng_chain)
from repro.serving.scheduler import Scheduler

VOCAB = 512     # smollm reduced()


def _prompts(seed, ns):
    """Seeded random prompts; seeds picked for robust greedy top-2
    margins (XLA CPU GEMM blocking is thread-dependent)."""
    rng = np.random.RandomState(seed)
    return [list(map(int, rng.randint(1, VOCAB, size=n))) for n in ns]


def _shared_prompts(seed, sys_len, tails):
    """A shared system prompt + random tails, final request the bare
    prompt itself (forces a tail prefill into a shared block — the
    copy-on-write shape, mirroring test_prefix_cache)."""
    rng = np.random.RandomState(seed)
    sysp = list(map(int, rng.randint(1, VOCAB, size=sys_len)))
    return [sysp + list(map(int, rng.randint(1, VOCAB, size=n)))
            for n in tails] + [list(sysp)]


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


class OracleDrafter:
    """Proposes the reference continuation — the all-accept extreme."""

    def __init__(self, prompts, outs):
        self.ref = {tuple(p): o for p, o in zip(prompts, outs)}

    def propose(self, tokens, k):
        for p, out in self.ref.items():
            if len(p) <= len(tokens) and tuple(tokens[:len(p)]) == p:
                done = len(tokens) - len(p)
                return list(out[done:done + k])
        return []


class AdversarialDrafter:
    """Proposes tokens the model will (almost) never emit — forces
    rejection-heavy windows so rollback runs constantly."""

    def propose(self, tokens, k):
        return [(int(tokens[-1]) + 101 + 17 * i) % VOCAB
                for i in range(k)]


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------

def test_ngram_drafter_suffix_match():
    d = NGramDrafter()
    # period-3 stream: longest-suffix match predicts the cycle
    assert d.propose([1, 2, 3, 1, 2, 3, 1, 2], 4) == [3, 1, 2, 3]
    assert d.propose([4, 4, 4, 4], 3) == [4, 4, 4]
    # cold stream: no earlier occurrence of any suffix -> no proposal
    assert d.propose([1, 2, 3, 4, 5], 4) == []
    assert d.propose([7], 4) == []
    assert d.propose([], 4) == []


def test_make_drafter_validation():
    assert make_drafter("off") is None
    assert isinstance(make_drafter("ngram"), NGramDrafter)
    with pytest.raises(ValueError):
        make_drafter("model")          # needs draft_model/draft_params
    with pytest.raises(ValueError):
        make_drafter("banana")


def test_engine_speculate_validation(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError):
        LPUEngine(model, params, speculate="banana")
    with pytest.raises(ValueError):
        LPUEngine(model, params, speculate="ngram", draft_k=0)
    with pytest.raises(ValueError):
        LPUEngine(model, params, speculate="ngram", paged=False)


# ---------------------------------------------------------------------------
# greedy bit-parity across the engine axes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("draft_k", [1, 2, 4])
def test_greedy_parity_draft_k(tiny_model, draft_k):
    model, params = tiny_model
    prompts = _prompts(7, (12, 9, 20))
    ref = LPUEngine(model, params, slots=2, max_seq=64).generate(
        prompts, max_new_tokens=12)
    eng = LPUEngine(model, params, slots=2, max_seq=64,
                    speculate="ngram", draft_k=draft_k)
    got = eng.generate(prompts, max_new_tokens=12)
    assert got == ref
    assert eng.stats.spec_rounds > 0 and eng.stats.draft_tokens > 0


@pytest.mark.parametrize("kernel", ["stream", "gather"])
def test_greedy_parity_paged_kernel(tiny_model, kernel):
    model, params = tiny_model
    prompts = _prompts(7, (12, 9, 20))
    kw = dict(slots=2, max_seq=64, block_size=16, paged_kernel=kernel)
    ref = LPUEngine(model, params, **kw).generate(
        prompts, max_new_tokens=10)
    got = LPUEngine(model, params, speculate="ngram", draft_k=4,
                    **kw).generate(prompts, max_new_tokens=10)
    assert got == ref


def test_fused_host_identical_streams(tiny_model):
    """Fused and host verify consume the identical rng chain, so even
    STOCHASTIC speculative streams match bit for bit.  (pipeline=False
    keeps the fused FALLBACK rounds at one token per round too — the
    pipelined second window changes where the drafter is consulted,
    which is a different — equally correct — rng path, not a bug.)"""
    model, params = tiny_model
    prompts = _prompts(7, (12, 9, 20))
    sp = SamplingParams(1.0, 40, 0.9)
    outs = {}
    for mode in ("fused", "host"):
        outs[mode] = LPUEngine(
            model, params, slots=2, max_seq=64, sampling=mode,
            speculate="ngram", draft_k=3, pipeline=False,
            rng=jax.random.PRNGKey(5)).generate(
                prompts, max_new_tokens=10, params=sp)
    assert outs["fused"] == outs["host"]


def test_greedy_parity_chunked_prefill(tiny_model):
    model, params = tiny_model
    prompts = _prompts(11, (7, 5, 39))
    kw = dict(slots=2, max_seq=64, block_size=16)
    ref = LPUEngine(model, params, **kw).generate(
        prompts, max_new_tokens=10)
    eng = LPUEngine(model, params, prefill_chunk=8, speculate="ngram",
                    draft_k=4, **kw)
    got = eng.generate(prompts, max_new_tokens=10)
    assert got == ref
    assert eng.stats.prefill_chunks > 0


def test_greedy_parity_prefix_cache(tiny_model):
    model, params = tiny_model
    prompts = _shared_prompts(3, 32, (6, 9, 3))
    kw = dict(slots=2, max_seq=64, block_size=16, prefix_cache=True)
    ref_eng = LPUEngine(model, params, **kw)
    ref = ref_eng.generate(prompts, max_new_tokens=10)
    eng = LPUEngine(model, params, speculate="ngram", draft_k=4, **kw)
    got = eng.generate(prompts, max_new_tokens=10)
    assert got == ref
    assert eng.stats.prefix_hit_blocks > 0


def test_greedy_parity_mid_stream_preemption(tiny_model):
    """A pool too small for the whole trace forces recompute preemption
    mid-decode; per-request speculative streams must still match the
    non-speculative run under the same pressure."""
    model, params = tiny_model
    prompts = _prompts(7, (12, 9, 20))
    kw = dict(slots=3, max_seq=64, block_size=16, num_blocks=7)
    ref_eng = LPUEngine(model, params, **kw)
    ref = ref_eng.generate(prompts, max_new_tokens=16)
    eng = LPUEngine(model, params, speculate="ngram", draft_k=4, **kw)
    got = eng.generate(prompts, max_new_tokens=16)
    assert got == ref
    assert ref_eng.stats.preemptions > 0 and eng.stats.preemptions > 0


# ---------------------------------------------------------------------------
# all-accept windows: the reserve_lookahead(draft_k) regression
# ---------------------------------------------------------------------------

def test_reserve_lookahead_accounts_draft_k():
    """The verify window writes KV at pos .. pos+K before the host
    knows how many drafts were accepted, so reservation must cover the
    K extra slots — an all-accept window at a block boundary must not
    scatter into the null block."""
    pool = BlockPool(8, 8)
    sched = Scheduler(2, 64, pool)
    sched.submit(Request(0, [1] * 6, 8))
    seq = sched.admit_next()
    assert seq.pos == 6 and len(seq.blocks) == 1
    assert sched.reserve_lookahead(1)            # pos 6 fits block 1
    assert len(seq.blocks) == 1
    # draft writes reach pos 9 -> a second block must be reserved
    assert sched.reserve_lookahead(1, draft_k=3)
    assert len(seq.blocks) == 2
    # all-or-nothing on shortfall: nothing allocated
    before = pool.num_free
    assert not sched.reserve_lookahead(1, draft_k=63)
    assert pool.num_free == before and len(seq.blocks) == 2


def test_all_accept_window_crosses_block_boundary(tiny_model):
    """Oracle drafter (proposes the reference continuation) on a prompt
    ending one token before a block boundary: every window is fully
    accepted and its tail tokens land past the boundary — in freshly
    reserved blocks, not the null block.  Bit-parity would break if any
    accepted draft's KV were lost."""
    model, params = tiny_model
    prompts = _prompts(3, (15, 31))
    kw = dict(slots=2, max_seq=64, block_size=16)
    ref = LPUEngine(model, params, **kw).generate(
        prompts, max_new_tokens=12)
    eng = LPUEngine(model, params, drafter=OracleDrafter(prompts, ref),
                    draft_k=4, **kw)
    got = eng.generate(prompts, max_new_tokens=12)
    assert got == ref
    st = eng.stats
    assert st.acceptance_rate == 1.0
    # all-accept emits K+1 tokens per round: far fewer rounds than tokens
    assert st.spec_rounds <= math.ceil(12 / 5) + 2
    assert st.accepted_per_window > 1.0


# ---------------------------------------------------------------------------
# rollback: forced-rejection windows leak nothing
# ---------------------------------------------------------------------------

def test_rollback_leak_accounting(tiny_model):
    model, params = tiny_model
    prompts = _prompts(7, (12, 9, 20))
    eng = LPUEngine(model, params, slots=2, max_seq=64, block_size=16,
                    drafter=AdversarialDrafter(), draft_k=4)
    ref = LPUEngine(model, params, slots=2, max_seq=64,
                    block_size=16).generate(prompts, max_new_tokens=12)
    got = eng.generate(prompts, max_new_tokens=12)
    assert got == ref
    st = eng.stats
    assert st.draft_tokens > 0 and st.acceptance_rate < 1.0
    pool = eng.sched.pool
    assert all(r == 0 for r in pool.ref[1:])
    assert pool.num_free == pool.num_blocks - 1


def test_rollback_prefix_index_and_cow_intact(tiny_model):
    """Rejection-heavy speculation over CoW-shared blocks: rejected
    draft writes must never reach a block another table (or the prefix
    index) still references, and after drain the index, refcounts and
    free list match the non-speculative run exactly."""
    model, params = tiny_model
    prompts = _shared_prompts(3, 32, (6, 9, 3))
    kw = dict(slots=2, max_seq=64, block_size=16, prefix_cache=True)

    def snapshot(eng):
        pool, idx = eng.sched.pool, eng.prefix
        return (set(idx._by_hash.keys()), sorted(pool.ref),
                pool.num_free)

    ref_eng = LPUEngine(model, params, **kw)
    ref = ref_eng.generate(prompts, max_new_tokens=10)
    eng = LPUEngine(model, params, drafter=AdversarialDrafter(),
                    draft_k=4, **kw)
    got = eng.generate(prompts, max_new_tokens=10)
    assert got == ref
    assert eng.stats.acceptance_rate < 1.0
    # the bare-sys-prompt request forces decode over a shared block, so
    # the speculative run must have split copy-on-write before writing
    assert eng.stats.cow_blocks > 0
    assert snapshot(eng) == snapshot(ref_eng)


# ---------------------------------------------------------------------------
# statistical correctness: rejection sampling == target distribution
# ---------------------------------------------------------------------------

def _tv(counts_a, counts_b, n_a, n_b):
    keys = set(counts_a) | set(counts_b)
    return 0.5 * sum(abs(counts_a.get(k, 0) / n_a
                         - counts_b.get(k, 0) / n_b) for k in keys)


def test_rejection_sampling_exact_marginals():
    """Tiny-vocab marginal check of the accept/resample formula: with a
    deterministic proposal q one-hot at the draft token, P(out = x)
    must equal the filtered target p(x) EXACTLY at every position —
    accept with p(draft), else resample from p with the draft masked.
    Bounds the TV distance of 20k draws and the accept frequency."""
    V, K = 8, 2
    rows = jax.random.normal(jax.random.PRNGKey(2), (K + 1, V)) * 2.0
    draft = jnp.asarray([2, 5], jnp.int32)
    temp = jnp.float32(1.0)
    tk, tp_ = jnp.int32(5), jnp.float32(0.85)
    p = np.asarray(jax.nn.softmax(jax.vmap(
        lambda r: _filter_row(r, temp, tk, tp_))(rows), -1))

    N = 20000
    rngs = jax.random.split(jax.random.PRNGKey(3), N)

    def one(r):
        _, keys = split_spec_rng_chain(r, jnp.ones((1,), bool), K + 1)
        return spec_verify_rows(rows, draft, keys[0], temp, tk, tp_)

    out, n_acc = jax.jit(jax.vmap(one))(rngs)
    out, n_acc = np.asarray(out), np.asarray(n_acc)

    # position 0 marginal == p0 (always emitted)
    freq0 = np.bincount(out[:, 0], minlength=V) / N
    assert 0.5 * np.abs(freq0 - p[0]).sum() < 0.02, (freq0, p[0])
    # accept frequency at position 0 == p0(draft0)
    acc0 = (out[:, 0] == int(draft[0])).mean()
    p_d0 = p[0, int(draft[0])]
    sigma = math.sqrt(p_d0 * (1 - p_d0) / N)
    # out[0]==draft0 also covers resamples that can't pick the masked
    # draft, so the frequency IS the accept probability
    assert abs(acc0 - p_d0) < 5 * sigma + 1e-3, (acc0, p_d0)
    # position 1, conditioned on the draft before it being accepted,
    # is an exact draw from p1 (independent rng per position)
    sel = out[n_acc >= 1]
    freq1 = np.bincount(sel[:, 1], minlength=V) / len(sel)
    assert 0.5 * np.abs(freq1 - p[1]).sum() < 0.03, (freq1, p[1])
    # the masked resample can never emit the rejected draft: rejected
    # position-0 outputs (out != draft AND literally rejected) exclude
    # draft0 by construction — check no other token got zero mass
    assert (freq0[p[0] > 0.01] > 0).all()


def test_greedy_verify_is_argmax_run():
    """temp <= 0: out rows are plain argmaxes and n_acc is the leading
    run of draft==argmax — the sequential greedy stream bit for bit."""
    V, K = 8, 3
    rows = jax.random.normal(jax.random.PRNGKey(4), (K + 1, V))
    am = np.asarray(jnp.argmax(rows, -1))
    draft = jnp.asarray([am[0], am[1], (am[2] + 1) % V], jnp.int32)
    _, keys = split_spec_rng_chain(jax.random.PRNGKey(0),
                                   jnp.ones((1,), bool), K + 1)
    out, n_acc = spec_verify_rows(rows, draft, keys[0], jnp.float32(0.0),
                                  jnp.int32(0), jnp.float32(1.0))
    assert np.asarray(out).tolist() == am.tolist()
    assert int(n_acc) == 2


def test_engine_stochastic_distribution_matches_nonspec(tiny_model):
    """Engine-level statistical gate: outcome frequencies of 2-token
    stochastic generations (temp=1, top_k=2 — a small joint outcome
    space) from the speculative engine match the non-speculative
    engine within TV 0.15.  The drafter proposes the GREEDY
    continuation, so it fires on every round and the drafts sit in the
    top-2 nucleus — the accept path and the masked-resample path are
    both exercised heavily."""
    model, params = tiny_model
    prompt = [7, 391, 44, 208] * 3
    sp = SamplingParams(1.0, 2, 1.0)
    N = 220
    kw0 = dict(slots=1, max_seq=32, block_size=16)
    greedy = LPUEngine(model, params, **kw0).generate(
        [prompt], max_new_tokens=2)[0]

    def collect(**kw):
        eng = LPUEngine(model, params, rng=jax.random.PRNGKey(123),
                        **kw0, **kw)
        counts = Counter()
        for _ in range(N):
            out = eng.generate([prompt], max_new_tokens=2, params=sp)[0]
            counts[tuple(out)] += 1
        return counts, eng.stats

    base, _ = collect()
    spec, st = collect(drafter=OracleDrafter([prompt], [greedy * 3]),
                       draft_k=2)
    assert st.spec_rounds > 0 and st.draft_tokens > 0
    assert st.accepted_tokens > 0
    tv = _tv(base, spec, N, N)
    assert tv < 0.15, (tv, dict(base), dict(spec))


# ---------------------------------------------------------------------------
# the top-p nucleus regression the statistical gate caught
# ---------------------------------------------------------------------------

def test_top_p_keeps_whole_nucleus():
    """The old cutoff (max of the kept prefix) collapsed every non-tied
    row to its argmax for ANY top_p < 1 — the speculative statistical
    suite caught it; this pins the fix (min of the kept prefix)."""
    lg = jnp.asarray([3.0, 2.0, 1.0, 0.0] + [-9.0] * 4)
    # p = softmax ~ [.64, .24, .09, .03, ...]: top_p=0.9 keeps 0, 1, 2
    kept = _filter_row(lg, jnp.float32(1.0), jnp.int32(0),
                       jnp.float32(0.9))
    finite = np.isfinite(np.asarray(kept))
    assert finite.tolist()[:4] == [True, True, True, False]
    toks = {int(sample_local(lg[None], jax.random.PRNGKey(i),
                             SamplingParams(1.0, 0, 0.9))[0])
            for i in range(300)}
    assert toks == {0, 1, 2}, toks


# ---------------------------------------------------------------------------
# streamline entry: verify window == sequential single-token decode
# ---------------------------------------------------------------------------

def test_streamline_verify_layer_matches_sequential_decode():
    """verify_layer's chunk-as-batch window (per-query tables and
    positions) is exact: one call over a slot's K+1 verify queries
    equals feeding them one at a time through decode_layer — including
    queries past a block boundary."""
    from repro.core.streamline import decode_layer, verify_layer
    from repro.models.common import InitCtx
    from repro.models.transformer import init_layer

    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    ctx = InitCtx(jax.random.PRNGKey(0), param_dtype=jnp.float32)
    p = init_layer(ctx, cfg, plan, 0)
    a = plan.attn
    bs, T = 8, 4
    table = jnp.arange(1, T + 1, dtype=jnp.int32)
    S0, K1 = 6, 4                  # resident history + verify window
    xs = jax.random.normal(jax.random.PRNGKey(1),
                           (S0 + K1, cfg.d_model))

    pool = {"k": jnp.zeros((T + 1, bs, a.gp, a.d_head)),
            "v": jnp.zeros((T + 1, bs, a.gp, a.d_head))}
    cache = pool
    ys = []
    for i in range(S0 + K1):
        y, cache = decode_layer(p, xs[i:i + 1], cache,
                                jnp.asarray([i], jnp.int32), cfg=cfg,
                                plan=plan, use_kernels=False,
                                block_table=table[None])
        ys.append(np.asarray(y[0]))

    # replay: same history, then the verify window in ONE call with
    # per-query tables/positions (positions 6..9 cross the bs=8 block)
    cache_v = pool
    for i in range(S0):
        _, cache_v = decode_layer(p, xs[i:i + 1], cache_v,
                                  jnp.asarray([i], jnp.int32), cfg=cfg,
                                  plan=plan, use_kernels=False,
                                  block_table=table[None])
    tabs = jnp.broadcast_to(table, (K1, T))
    posn = S0 + jnp.arange(K1, dtype=jnp.int32)
    y_v, cache_v = verify_layer(p, xs[S0:], cache_v, tabs, posn,
                                cfg=cfg, plan=plan, use_kernels=False)
    np.testing.assert_allclose(np.stack(ys[S0:]), np.asarray(y_v),
                               rtol=1e-5, atol=1e-5)
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(cache[key][1:]),
                                      np.asarray(cache_v[key][1:]))


# ---------------------------------------------------------------------------
# ring tp: speculation inside the shard_map engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ring_speculative_matches_dense_tp1():
    """tp=2 shard_map verify (draft KV scattered into per-rank
    head-sharded pools, candidate-set verification all-gathered) must
    produce bit-identical greedy streams to the tp=1 dense engine."""
    from tests.util import run_multidevice
    out = run_multidevice("""
    import jax, numpy as np
    from repro.compiler.mapper import plan_model
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.registry import build_model
    from repro.serving.engine import LPUEngine

    cfg = get_config('smollm-135m').reduced()
    plan1 = plan_model(cfg, None, (1,), 'serve', esl_overlap=False,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m1 = build_model(cfg, plan1)
    p1, _ = m1.init(jax.random.PRNGKey(0))
    plan2 = plan_model(cfg, ('model',), (2,), 'serve', esl_overlap=True,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m2 = build_model(cfg, plan2)
    p2, _ = m2.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(11)
    prompts = [list(map(int, rng.randint(1, 512, size=n)))
               for n in (7, 5, 12)]
    ref = LPUEngine(m1, p1, slots=2, max_seq=64, paged=False).generate(
        prompts, max_new_tokens=10)
    mesh = make_serving_mesh(tp=2, rings=1)
    eng = LPUEngine(m2, p2, slots=2, max_seq=64, paged=True,
                    block_size=16, mesh=mesh, speculate='ngram',
                    draft_k=4)
    got = eng.generate(prompts, max_new_tokens=10)
    assert got == ref, (got, ref)
    assert eng.stats.spec_rounds > 0 and eng.stats.draft_tokens > 0
    print('PASS')
    """, n_devices=2)
    assert "PASS" in out
