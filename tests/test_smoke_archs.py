"""Per-arch smoke tests: reduced config, one forward + train step on CPU,
asserting output shapes and finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import ASSIGNED, get_config
from repro.core.steps import build_train_step
from repro.models.registry import build_model
from repro.optim import AdamW, get_schedule


def _batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            k, (B, cfg.encdec.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            k, (B, cfg.vlm.n_patches, cfg.vlm.patch_embed_dim))
    return batch


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    plan = plan_model(cfg, None, (1,), "train", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, axes = model.init(jax.random.PRNGKey(0))
    assert axes, "no axes recorded"

    opt = AdamW(lr=get_schedule("cosine", 1e-3, 2, 10))
    step, _ = build_train_step(model, opt, None, 2)
    opt_state = opt.init(params)
    batch = _batch(cfg)
    p2, o2, metrics = jax.jit(step)(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0, loss
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_decode_shapes_no_nan(name):
    cfg = get_config(name).reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    from repro.core.dist import make_axis_env
    env = make_axis_env(plan, batch=2)
    B, MAX = 2, 32
    cache = model.init_cache(B, MAX, dtype=jnp.float32)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.encdec.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        kw["patch_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1),
            (B, cfg.vlm.n_patches, cfg.vlm.patch_embed_dim))
    toks = jnp.ones((B, 4), jnp.int32)
    lg, cache, _ = model.forward(params, toks, env=env, mode="prefill",
                                 cache=cache, **kw)
    offset = cfg.vlm.n_patches if cfg.family == "vlm" else 0
    pos = jnp.full((B,), 4 + offset, jnp.int32)
    lg2, cache, _ = model.forward(params, jnp.ones((B, 1), jnp.int32),
                                  env=env, mode="decode", positions=pos,
                                  cache=cache)
    assert lg2.shape[0] == B and lg2.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(
        jnp.where(lg2 < -1e30, 0.0, lg2))))
