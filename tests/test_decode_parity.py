"""Prefill + decode must match teacher-forced logits for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import ASSIGNED, get_config
from repro.core.dist import make_axis_env
from repro.models.registry import build_model


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_prefill_decode_matches_teacher_forcing(name):
    cfg = get_config(name).reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    env = make_axis_env(plan, batch=2)
    B, S, MAX = 2, 8, 32
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (B, S + 4), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encdec.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        kw["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.vlm.n_patches, cfg.vlm.patch_embed_dim))

    logits_ref, _, _ = model.forward(params, tokens, env=env, mode="train",
                                     **kw)
    cache = model.init_cache(B, MAX, dtype=jnp.float32)
    _, cache, _ = model.forward(params, tokens[:, :S], env=env,
                                mode="prefill", cache=cache, **kw)
    offset = cfg.vlm.n_patches if cfg.family == "vlm" else 0
    for t in range(4):
        pos = jnp.full((B,), S + t + offset, jnp.int32)
        lg, cache, _ = model.forward(
            params, tokens[:, S + t:S + t + 1], env=env, mode="decode",
            positions=pos, cache=cache)
        ref_t = logits_ref[:, offset + S + t]
        got_t = lg[:, 0]
        # MoE capacity drops differ between batch shapes: argmax must hold
        assert bool(jnp.all(jnp.argmax(ref_t, -1) == jnp.argmax(got_t, -1)))
        if cfg.moe is None:
            rel = float(jnp.max(jnp.abs(ref_t - got_t))
                        / (jnp.max(jnp.abs(ref_t)) + 1e-9))
            assert rel < 2e-3, (name, t, rel)
