"""Gradient accumulation: microbatched step == full-batch step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.core.steps import build_train_step
from repro.models.registry import build_model
from repro.optim import AdamW, get_schedule


def test_accum_matches_full_batch():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "train", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=get_schedule("cosine", 1e-3, 2, 10))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                     cfg.vocab_size),
    }
    outs = {}
    for accum in (1, 2, 4):
        step, _ = build_train_step(model, opt, None, 4, accum_steps=accum)
        p2, _, m = jax.jit(step)(params, opt.init(params), batch)
        outs[accum] = (float(m["loss"]), p2)
    # losses equal (mean over the same tokens) and updates near-identical
    assert abs(outs[1][0] - outs[2][0]) < 1e-5
    assert abs(outs[1][0] - outs[4][0]) < 1e-5
    l1 = jax.tree.leaves(outs[1][1])[0]
    l4 = jax.tree.leaves(outs[4][1])[0]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l4),
                               rtol=1e-4, atol=1e-5)
