"""The latency model must reproduce the paper's published claims."""
import pytest

from repro.configs import get_config
from repro.core.latency_model import (LPU_ASIC, fit_vector_params,
                                      scaling_curve, token_latency)

KV = 32 + 2016 // 2
PTS = [("opt-1.3b", 1, 1.25), ("opt-6.7b", 1, 4.62), ("opt-66b", 2, 22.2)]


@pytest.fixture(scope="module")
def calib():
    pts = [(get_config(n), d, LPU_ASIC, KV, ms) for n, d, ms in PTS]
    return fit_vector_params(pts)


def test_latency_calibration_residuals(calib):
    a, b, c, err = calib
    assert a >= 0 and b >= 0 and c >= 0
    # 6.7B and 66B within 5%; the 1.3B point is internally inconsistent
    # with any non-negative model of this family (EXPERIMENTS.md) — 15%.
    assert err < 0.15
    for name, n, paper in PTS[1:]:
        got = token_latency(get_config(name), n, LPU_ASIC, kv_len=KV,
                            vec_a=a, vec_b=b, vec_c=c)["ms_per_token"]
        assert abs(got - paper) / paper < 0.05, (name, got)


def test_bandwidth_util_rises_with_size(calib):
    a, b, c, _ = calib
    utils = []
    for name, n in [("opt-1.3b", 1), ("opt-6.7b", 1), ("opt-30b", 1),
                    ("opt-66b", 2)]:
        utils.append(token_latency(get_config(name), n, LPU_ASIC,
                                   kv_len=KV, vec_a=a, vec_b=b,
                                   vec_c=c)["bandwidth_util"])
    assert utils == sorted(utils)
    assert utils[-1] > 0.9                      # paper: 90.6% for 66B


def test_heldout_30b_utilization(calib):
    a, b, c, _ = calib
    r = token_latency(get_config("opt-30b"), 1, LPU_ASIC, kv_len=KV,
                      vec_a=a, vec_b=b, vec_c=c)
    assert abs(r["bandwidth_util"] - 0.902) < 0.05    # paper 90.2%


def test_scaling_beats_blocking(calib):
    a, b, c, _ = calib
    cfg = get_config("gpt3-20b")
    kw = dict(kv_len=KV, vec_a=a, vec_b=b, vec_c=c)
    esl = scaling_curve(cfg, LPU_ASIC, 8, overlap=True, **kw)
    blk = scaling_curve(cfg, LPU_ASIC, 8, overlap=False, **kw)
    # paper: 5.43x at 8 devices, ~1.75x per doubling; our model is within
    # ~25% optimistic (no FPGA jitter) but must preserve the ordering and
    # the near-linear-doubling property
    assert esl[-1] > blk[-1]
    assert esl[-1] > 5.0
    per_doubling = esl[-1] ** (1 / 3)
    assert 1.6 < per_doubling <= 2.0


def test_esl_sync_latency_hidden(calib):
    """ESL's exposed sync must be far below the blocking all-reduce."""
    a, b, c, _ = calib
    cfg = get_config("gpt3-20b")
    on = token_latency(cfg, 8, LPU_ASIC, overlap=True, kv_len=KV,
                       vec_a=a, vec_b=b, vec_c=c)["sync_ms"]
    off = token_latency(cfg, 8, LPU_ASIC, overlap=False, kv_len=KV,
                        vec_a=a, vec_b=b, vec_c=c)["sync_ms"]
    assert on < off / 5
