"""EngineConfig: the consolidated construction API + the legacy shim.

The redesign's contract: every scalar engine knob lives on ONE frozen
dataclass, ``LPUEngine(model, params, config=...)`` is the single
construction path, and the legacy ~20-kwarg call keeps working through
a parity-tested deprecation shim (warns once per process).
"""
import warnings

import jax
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving import config as config_mod
from repro.serving.config import EngineConfig, resolve_engine_config
from repro.serving.engine import LPUEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


PROMPTS = [[1, 2, 3], [4, 5, 6, 7]]


def test_defaults_match_legacy_defaults():
    c = EngineConfig()
    assert (c.slots, c.max_seq, c.paged) == (4, 256, None)
    assert (c.sampling, c.steps_per_sync, c.pipeline) == ("fused", 1, True)
    assert (c.kv_dtype, c.w_dtype) == ("auto", "auto")


def test_resolver_contracts():
    c = EngineConfig(slots=2)
    assert resolve_engine_config(c, {}) is c
    with pytest.raises(ValueError, match="not both"):
        resolve_engine_config(c, {"slots": 3})
    with pytest.raises(TypeError, match="unknown engine option"):
        resolve_engine_config(None, {"slotz": 3})
    with pytest.raises(TypeError, match="EngineConfig"):
        resolve_engine_config({"slots": 2}, {})


def test_validation_rejects_bad_dtypes():
    with pytest.raises(ValueError, match="kv_dtype"):
        EngineConfig(kv_dtype="int4")
    with pytest.raises(ValueError, match="w_dtype"):
        EngineConfig(w_dtype="fp8")


def test_with_overrides_is_frozen_safe():
    c = EngineConfig(slots=2)
    d = c.with_overrides(max_seq=64, kv_dtype="int8")
    assert (d.slots, d.max_seq, d.kv_dtype) == (2, 64, "int8")
    assert (c.max_seq, c.kv_dtype) == (256, "auto")   # original untouched
    with pytest.raises(Exception):
        c.slots = 3                                   # frozen


def test_legacy_shim_warns_once_and_matches_config(tiny_model):
    """The deprecation shim's parity contract: loose kwargs build the
    SAME engine as the equivalent EngineConfig, and the warning fires
    exactly once per process."""
    model, params = tiny_model
    config_mod._legacy_warned = False
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = LPUEngine(model, params, slots=2, max_seq=64,
                           paged=True, block_size=16)
    assert sum(issubclass(w.category, DeprecationWarning)
               for w in rec) == 1
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        LPUEngine(model, params, slots=2, max_seq=64)
    assert not any(issubclass(w.category, DeprecationWarning)
                   for w in rec2)                     # once per process
    modern = LPUEngine(model, params,
                       EngineConfig(slots=2, max_seq=64, paged=True,
                                    block_size=16))
    assert legacy.config == modern.config
    ol = legacy.generate(PROMPTS, max_new_tokens=6)
    om = modern.generate(PROMPTS, max_new_tokens=6)
    assert ol == om


def test_engine_rejects_mixed_sources(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError, match="not both"):
        LPUEngine(model, params, EngineConfig(slots=2), max_seq=64)


def test_engine_rejects_unknown_kwarg(tiny_model):
    model, params = tiny_model
    with pytest.raises(TypeError, match="unknown engine option"):
        LPUEngine(model, params, slotz=2)


def test_engine_records_its_config(tiny_model):
    model, params = tiny_model
    c = EngineConfig(slots=2, max_seq=64)
    eng = LPUEngine(model, params, c)
    assert eng.config is c
    assert (eng.slots, eng.max_seq) == (2, 64)
    assert (eng.kv_dtype, eng.w_dtype) == ("float32", "auto")
