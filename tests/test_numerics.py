"""Paper claim: 'LPU occurs no accuracy loss ... as it supports the
standard FP16 precision' — bf16 decode must match f32 argmax."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.core.dist import make_axis_env
from repro.models.registry import build_model


def test_bf16_decode_argmax_matches_f32():
    cfg = get_config("smollm-135m").reduced()
    outs = {}
    for cdt in ("float32", "bfloat16"):
        plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                          remat="none", compute_dtype=cdt,
                          param_dtype=cdt)
        model = build_model(cfg, plan)
        params, _ = model.init(jax.random.PRNGKey(0))
        env = make_axis_env(plan, batch=2)
        toks = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0,
                                  cfg.vocab_size)
        lg, _, _ = model.forward(params, toks, env=env, mode="train")
        outs[cdt] = np.asarray(jnp.argmax(lg, -1))
    match = (outs["float32"] == outs["bfloat16"]).mean()
    assert match > 0.95, match
