"""Async serving front end: streaming parity, cancellation, backpressure.

Parity is the load-bearing property: the frontend is a *facade* — it
must not perturb the engine's step order, so greedy token streams
consumed ``async for`` are bit-identical to the blocking
submit/step/drain results, on a single engine AND a mesh-free 2-ring
fleet, with and without SLO budget scheduling (window/chunk retuning is
parity-safe by the engine's own window-size gates).

Resource properties: cancellation mid-stream releases the slot and
every pool block (``check_pool_balanced`` after drain), and admission
beyond ``max_pending`` raises a structured ``AdmissionRejected`` whose
fields (not its message) carry the numbers.
"""
import jax
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.budget import BudgetScheduler
from repro.serving.config import EngineConfig
from repro.serving.engine import LPUEngine, MultiRingEngine
from repro.serving.frontend import (AdmissionRejected, AsyncFrontend,
                                    serve_trace)
from repro.serving.tracker import RingBufferTracker

pytestmark = pytest.mark.asyncio

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [1, 2, 3, 4, 5, 6]]
ECONF = EngineConfig(slots=2, max_seq=64, paged=True, block_size=16)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.fixture(scope="module")
def blocking_base(tiny_model):
    model, params = tiny_model
    return LPUEngine(model, params, ECONF).generate(PROMPTS,
                                                    max_new_tokens=8)


async def test_streaming_parity_tp1(tiny_model, blocking_base):
    model, params = tiny_model
    eng = LPUEngine(model, params, ECONF)
    async with AsyncFrontend(eng) as fe:
        streams = [fe.submit(p, 8) for p in PROMPTS]
        outs = [await s.drain() for s in streams]
    assert outs == blocking_base          # bit-identical, greedy
    assert all(s.status == "completed" for s in streams)
    assert fe.counters["completed"] == len(PROMPTS)
    assert fe.counters["completed"] + fe.counters["failed"] \
        + fe.counters["cancelled"] == fe.counters["submitted"]
    eng.check_pool_balanced()


async def test_streaming_parity_2ring_fleet(tiny_model):
    model, params = tiny_model
    base = MultiRingEngine(model, params, None, rings=2,
                           config=ECONF).generate(PROMPTS, 8)
    fleet = MultiRingEngine(model, params, None, rings=2, config=ECONF)
    async with AsyncFrontend(fleet) as fe:
        streams = [fe.submit(p, 8) for p in PROMPTS]
        outs = [await s.drain() for s in streams]
    assert outs == base                   # same routing, same streams
    for eng in fleet.engines:
        eng.check_pool_balanced()


async def test_budget_scheduling_keeps_parity(tiny_model):
    # SLO retuning changes WHEN tokens reconcile, never WHICH tokens:
    # the budget-driven frontend must stream bit-identically while
    # actually exercising the knob seam (plans recorded, EWMA updated)
    model, params = tiny_model
    chunked = ECONF.with_overrides(prefill_chunk=16)
    base = LPUEngine(model, params, chunked).generate(PROMPTS, 8)
    eng = LPUEngine(model, params, chunked)
    bud = BudgetScheduler(5.0, prior_step_s=2e-3, max_chunk=32)
    async with AsyncFrontend(eng, budget=bud) as fe:
        streams = [fe.submit(p, 8) for p in PROMPTS]
        outs = [await s.drain() for s in streams]
    assert outs == base
    assert bud.planned                     # the planner actually ran
    assert bud.observed_windows > 0        # ...and measured real steps
    assert all(c is None or c >= 8 for c, _ in bud.planned)
    assert all(1 <= s <= bud.max_steps_per_sync for _, s in bud.planned)


async def test_cancel_mid_stream_frees_blocks(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, ECONF)
    async with AsyncFrontend(eng) as fe:
        victim = fe.submit([1, 2, 3], 40)
        mate = fe.submit([4, 5], 6)
        got = 0
        async for _ in victim:
            got += 1
            if got == 3:
                assert await victim.cancel()
                break
        await fe.join()
    assert victim.status == "cancelled"
    assert len(victim.tokens) < 40        # genuinely aborted early
    assert mate.status == "completed"     # co-tenant unaffected
    assert eng.stats.cancelled_requests == 1
    assert fe.counters["cancelled"] == 1
    assert fe.counters["completed"] + fe.counters["failed"] \
        + fe.counters["cancelled"] == fe.counters["submitted"]
    eng.check_pool_balanced()             # zero leaked pool blocks
    # double-cancel and cancel-after-finish are no-ops
    assert not await victim.cancel()
    assert not await mate.cancel()


async def test_cancel_queued_request(tiny_model):
    # slots=2 + 3 submits: the third sits in the scheduler queue; a
    # queued cancel must remove it before it ever owns blocks
    model, params = tiny_model
    eng = LPUEngine(model, params, ECONF)
    async with AsyncFrontend(eng) as fe:
        a = fe.submit([1, 2, 3], 6)
        b = fe.submit([4, 5], 6)
        c = fe.submit([6, 7, 8], 6)
        assert await c.cancel()
        outs = [await s.drain() for s in (a, b)]
    assert c.status == "cancelled" and c.tokens == []
    assert all(len(o) == 6 for o in outs)
    eng.check_pool_balanced()


async def test_backpressure_structured_rejection(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, ECONF)
    async with AsyncFrontend(eng, max_pending=2) as fe:
        s1 = fe.submit([1, 2, 3], 6)
        s2 = fe.submit([4, 5], 6)
        with pytest.raises(AdmissionRejected) as exc:
            fe.submit([6, 7], 6)
        assert exc.value.pending == 2 and exc.value.limit == 2
        assert fe.counters["rejected"] == 1
        await s1.drain()
        await s2.drain()
        # capacity freed: admission opens again
        s3 = fe.submit([6, 7], 6)
        assert (await s3.drain())
    assert fe.counters["submitted"] == 3


async def test_max_pending_flows_from_config(tiny_model):
    model, params = tiny_model
    eng = LPUEngine(model, params, ECONF.with_overrides(max_pending=1))
    async with AsyncFrontend(eng) as fe:
        assert fe.max_pending == 1
        fe.submit([1, 2, 3], 4)
        with pytest.raises(AdmissionRejected):
            fe.submit([4, 5], 4)
        await fe.join()


async def test_failed_request_surfaces_through_stream(tiny_model):
    # a request whose resume state can never fit is rejected by the
    # scheduler mid-serve; the frontend must end its stream with
    # status="failed" + error, not hang the consumer
    from repro.serving.engine import Request
    model, params = tiny_model
    eng = LPUEngine(model, params, EngineConfig(
        slots=2, max_seq=64, paged=True, block_size=16, num_blocks=3))
    async with AsyncFrontend(eng) as fe:
        big = Request(7, list(range(1, 11)), 50)
        big.out = list(range(100, 145))    # resume needs 4 blocks
        t0 = fe.clock()
        rid = eng.submit(big)
        from repro.serving.frontend import TokenStream
        from repro.serving.tracker import RequestTimeline
        stream = TokenStream(rid, fe, RequestTimeline(rid, t0))
        fe._streams[rid] = stream
        fe._inflight[rid] = stream
        fe.counters["submitted"] += 1
        fe._idle.clear()
        fe._wake.set()
        ok = fe.submit([1, 2, 3], 4)
        await stream.drain()
        await ok.drain()
    assert stream.status == "failed" and "blocks" in stream.error
    assert ok.status == "completed"
    assert fe.counters["failed"] == 1
    assert fe.counters["completed"] + fe.counters["failed"] \
        + fe.counters["cancelled"] == fe.counters["submitted"]


async def test_serve_trace_replay_and_telemetry(tiny_model):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    import traces as tr
    model, params = tiny_model
    trace = tr.generate_trace(tr.TraceConfig(
        seed=3, requests=6, tenants=2, prefix_len=16, tail_max=8,
        max_new_max=6, rate_rps=1000.0))
    fleet = MultiRingEngine(model, params, None, rings=2,
                            config=ECONF.with_overrides(prefix_cache=True))
    sink = RingBufferTracker(512)
    async with AsyncFrontend(fleet, tracker=sink) as fe:
        streams = await serve_trace(fe, trace, speed=100.0)
    assert all(s is not None and s.status == "completed" for s in streams)
    kinds = {r["kind"] for r in sink.records()}
    assert kinds == {"engine_window", "request"}
    reqs = [r for r in sink.records() if r["kind"] == "request"]
    assert len(reqs) == len(trace)
    assert all(r["ttft_ms"] >= 0 for r in reqs)
    for eng in fleet.engines:
        eng.check_pool_balanced()
