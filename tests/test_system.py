"""End-to-end behaviour tests for the paper's system.

The LPU pipeline: compile-time mapper -> streamlined decode -> ESL ring
-> HyperDex-style runtime.  These tests exercise the whole chain on one
device; tests/test_distributed.py covers the ring.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model, summarize
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine


def test_end_to_end_generation_pipeline():
    cfg = get_config("qwen1.5-4b").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    s = summarize(plan)
    assert s["arch"] == cfg.name
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = LPUEngine(model, params, slots=2, max_seq=48)
    outs = eng.generate([[5, 6, 7], [9, 10]], max_new_tokens=6)
    assert len(outs) == 2 and all(len(o) == 6 for o in outs)
    v = cfg.vocab_size
    assert all(0 <= t < v for o in outs for t in o)


def test_mapper_plan_is_serializable():
    cfg = get_config("llama4-maverick-400b-a17b")
    plan = plan_model(cfg, ("pod", "data", "model"), (2, 16, 16), "serve")
    js = plan.to_json()
    assert "esl_overlap" in js and "vocab_padded" in js


def test_esl_modes_same_logits():
    """C2 is a schedule change, not a math change."""
    from repro.core.dist import make_axis_env
    cfg = get_config("smollm-135m").reduced()
    logits = {}
    for overlap in (False, True):
        plan = plan_model(cfg, None, (1,), "serve", esl_overlap=overlap,
                          remat="none", compute_dtype="float32",
                          param_dtype="float32")
        model = build_model(cfg, plan)
        params, _ = model.init(jax.random.PRNGKey(0))
        env = make_axis_env(plan, batch=1)
        toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        lg, _, _ = model.forward(params, toks, env=env, mode="train")
        logits[overlap] = np.asarray(lg)
    np.testing.assert_allclose(logits[False], logits[True],
                               rtol=1e-5, atol=1e-5)
