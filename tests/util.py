"""Test helpers: subprocess runner for multi-device (fake-device) tests."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

PREAMBLE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n}'
import sys
sys.path.insert(0, {src!r})
"""


def run_multidevice(body: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run `body` in a fresh python with n fake devices; returns stdout."""
    script = PREAMBLE.format(n=n_devices, src=SRC) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout,
        env={**os.environ, "PYTHONPATH": SRC},
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout
