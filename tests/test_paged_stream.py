"""Streamed paged decode: the Pallas kernel consumes KV tiles straight
from the block pool (scalar-prefetched tables, new token folded into the
online-softmax carry) — parity against the gather oracle and the dense
engine, including under recompute preemption, plus the null-block
property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.compiler.plan import plan_attention
from repro.configs import get_config
from repro.core.streamline import decode_layer
from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                gather_kv_pages)
from repro.models.attention import paged_stream_supported
from repro.models.common import InitCtx
from repro.models.registry import build_model
from repro.models.transformer import init_layer
from repro.serving.engine import LPUEngine


# ---------------------------------------------------------------------------
# kernel level: in-kernel fold of the just-generated token
# ---------------------------------------------------------------------------

def _fold_inputs(key, B=2, H=4, G=2, dh=16, bs=8, T=4, N=9):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, G, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, G, dh), jnp.float32)
    k_new = jax.random.normal(ks[3], (B, G, dh), jnp.float32)
    v_new = jax.random.normal(ks[4], (B, G, dh), jnp.float32)
    tables = jnp.asarray(np.arange(1, B * T + 1, dtype=np.int32)
                         .reshape(B, T))
    lengths = jnp.asarray([13, 27], jnp.int32)
    return q, kp, vp, k_new, v_new, tables, lengths


def test_kernel_fold_matches_scatter_oracle():
    """Folding (k_new, v_new) into the carry == scattering the new token
    at position ``length`` and attending over lengths+1."""
    q, kp, vp, kn, vn, tables, lengths = _fold_inputs(jax.random.PRNGKey(0))
    B, H = q.shape[:2]
    gs = H // kp.shape[2]
    folded = paged_decode_attention(q, kp, vp, tables, lengths,
                                    k_new=kn, v_new=vn)
    ke = jnp.repeat(gather_kv_pages(kp, tables), gs, axis=2)
    ve = jnp.repeat(gather_kv_pages(vp, tables), gs, axis=2)
    ke = ke.at[jnp.arange(B), lengths].set(jnp.repeat(kn, gs, axis=1))
    ve = ve.at[jnp.arange(B), lengths].set(jnp.repeat(vn, gs, axis=1))
    ref = decode_attention_ref(q, ke, ve, lengths + 1)
    np.testing.assert_allclose(np.asarray(folded), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_kernel_fold_fallback_matches_pallas():
    """The use_pallas=False oracle (mask-scatter pre-kernel) agrees with
    the in-kernel fold."""
    q, kp, vp, kn, vn, tables, lengths = _fold_inputs(jax.random.PRNGKey(1))
    pal = paged_decode_attention(q, kp, vp, tables, lengths,
                                 k_new=kn, v_new=vn)
    ref = paged_decode_attention(q, kp, vp, tables, lengths,
                                 k_new=kn, v_new=vn, use_pallas=False)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# layout gate: which plans may stream
# ---------------------------------------------------------------------------

def test_block_regular_layouts():
    # sharded GQA (n_kv >= tp): regular on every rank
    assert plan_attention(16, 4, 64, tp=4).block_regular
    # duplicated single kv head per rank: trivially regular
    assert plan_attention(8, 1, 64, tp=2).block_regular
    # dup>1 with multiple kv heads per rank and padding misalignment:
    # rank 0 holds q heads [0,1] both mapping kv 0 — NOT i//gs regular
    assert not plan_attention(8, 4, 64, tp=6).block_regular


def test_stream_supported_matches_plan():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    assert paged_stream_supported(plan) == plan.attn.block_regular


def test_stream_support_alignment_gate_compiled(monkeypatch):
    """Compiled on TPU (no interpret), misaligned tiles must resolve to
    gather UP FRONT — never a silent in-kernel fallback that the engine
    would account as streamed."""
    from repro.kernels.decode_attention import ops as da_ops
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    assert plan.attn.block_regular
    # interpret mode (CPU): any block size streams
    assert paged_stream_supported(plan, 16)
    # explicit interpret flag beats the backend-derived default
    assert paged_stream_supported(plan, 16, interpret=True)
    assert not paged_stream_supported(plan, 16, interpret=False)
    # compiled: LANE-aligned block AND d_head required
    monkeypatch.setattr(da_ops, "default_interpret", lambda: False)
    assert not paged_stream_supported(plan, 16)
    aligned = plan.attn.d_head % 128 == 0
    assert paged_stream_supported(plan, 128) == aligned
    # the engine's auto resolution follows the same gate
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                    block_size=16)
    assert eng.paged_kernel == "gather"
    with pytest.raises(ValueError):
        LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                  block_size=16, paged_kernel="stream")


# ---------------------------------------------------------------------------
# model level: forward(mode='decode') stream vs gather over the same pool
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_forward_stream_matches_gather(tiny_model):
    model, params = tiny_model
    from repro.core.dist import make_axis_env
    B, bs, nb, max_seq = 3, 16, 13, 64
    env = make_axis_env(model.plan, batch=B)
    cache = model.init_cache(B, max_seq, paged=True, num_blocks=nb,
                             block_size=bs)
    keys = iter(jax.random.split(jax.random.PRNGKey(7), 64))
    cache = jax.tree.map(
        lambda c: jax.random.normal(next(keys), c.shape, c.dtype), cache)
    tables = jnp.asarray(np.arange(1, B * 4 + 1, dtype=np.int32)
                         .reshape(B, 4))
    tokens = jnp.asarray([[5], [9], [2]], jnp.int32)
    positions = jnp.asarray([3, 17, 40], jnp.int32)
    res = {}
    for mode in ("stream", "gather"):
        logits, upd, _ = model.forward(
            params, tokens, env=env, mode="decode", positions=positions,
            cache=cache, block_tables=tables, paged_kernel=mode)
        res[mode] = (np.asarray(logits), upd)
    np.testing.assert_allclose(res["stream"][0], res["gather"][0],
                               rtol=2e-5, atol=2e-5)
    # the cache-update contract is the same in both modes (read the pool
    # pre-update, scatter the new KV rows into the scan carry); rows
    # written by layers > 0 inherit the tiny tiling-order differences of
    # the previous layer's attention output, hence allclose, not equal
    for a, b in zip(jax.tree.leaves(res["stream"][1]),
                    jax.tree.leaves(res["gather"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_forward_stream_rejects_irregular_plan(tiny_model):
    """An irregular stored layout cannot stream — the seam must refuse
    explicitly rather than silently compute wrong head groupings."""
    import dataclasses
    model, _ = tiny_model
    bad_plan = dataclasses.replace(model.plan,
                                   attn=plan_attention(8, 4, 64, tp=6))
    assert not paged_stream_supported(bad_plan)


# ---------------------------------------------------------------------------
# streamline (kernel-backed single-device chain)
# ---------------------------------------------------------------------------

def test_decode_layer_stream_matches_gather():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    ctx = InitCtx(jax.random.PRNGKey(0), param_dtype=jnp.float32)
    p = init_layer(ctx, cfg, plan, 0)
    a = plan.attn
    B, bs, T = 2, 8, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_model))
    pool_k = jax.random.normal(jax.random.PRNGKey(2),
                               (2 * T + 1, bs, a.gp, a.d_head))
    pool_v = jax.random.normal(jax.random.PRNGKey(3),
                               (2 * T + 1, bs, a.gp, a.d_head))
    tables = jnp.asarray(np.arange(1, 2 * T + 1, dtype=np.int32)
                         .reshape(B, T))
    pos = jnp.asarray([5, 11], jnp.int32)
    y_g, c_g = decode_layer(p, x, {"k": pool_k, "v": pool_v}, pos,
                            cfg=cfg, plan=plan, use_kernels=True,
                            block_table=tables, paged_kernel="gather")
    y_s, c_s = decode_layer(p, x, {"k": pool_k, "v": pool_v}, pos,
                            cfg=cfg, plan=plan, use_kernels=True,
                            block_table=tables, paged_kernel="stream")
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_g),
                               rtol=1e-4, atol=1e-4)
    # pool updates are identical: the dataflow changes reads, not writes
    np.testing.assert_array_equal(np.asarray(c_s["k"]), np.asarray(c_g["k"]))
    np.testing.assert_array_equal(np.asarray(c_s["v"]), np.asarray(c_g["v"]))


# ---------------------------------------------------------------------------
# engine level: token streams bit-identical across dataflows
# ---------------------------------------------------------------------------

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10, 11],
           [3, 1, 4, 1, 5, 9, 2, 6], [2, 7]]


def test_engine_stream_matches_gather_and_dense(tiny_model):
    model, params = tiny_model
    dense = LPUEngine(model, params, slots=3, max_seq=64, paged=False)
    gather = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                       block_size=16, paged_kernel="gather")
    stream = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                       block_size=16, paged_kernel="stream")
    od = dense.generate(PROMPTS, max_new_tokens=8)
    og = gather.generate(PROMPTS, max_new_tokens=8)
    os_ = stream.generate(PROMPTS, max_new_tokens=8)
    assert od == og == os_
    # auto resolves to stream for this (block-regular) plan
    auto = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                     block_size=16)
    assert auto.paged_kernel == "stream"
    assert auto.generate(PROMPTS, max_new_tokens=8) == od


def test_engine_stream_parity_under_preemption(tiny_model):
    """Pool pressure forces recompute preemption with the STREAMED kernel
    selected; the token streams must still match the dense engine."""
    model, params = tiny_model
    dense = LPUEngine(model, params, slots=3, max_seq=64, paged=False)
    od = dense.generate(PROMPTS, max_new_tokens=20)
    stream = LPUEngine(model, params, slots=3, max_seq=64, paged=True,
                       block_size=8, num_blocks=5, paged_kernel="stream")
    os_ = stream.generate(PROMPTS, max_new_tokens=20)
    assert stream.stats.preemptions > 0
    assert od == os_


def test_engine_rejects_bad_kernel_value(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError):
        LPUEngine(model, params, slots=2, max_seq=64, paged=True,
                  block_size=16, paged_kernel="bogus")


def test_engine_kv_moved_accounting(tiny_model):
    """The gather oracle materializes the per-request view (read pool +
    write copy + read copy); the streamed kernel only reads tiles."""
    model, params = tiny_model
    kw = dict(slots=3, max_seq=64, paged=True, block_size=16)
    stream = LPUEngine(model, params, paged_kernel="stream", **kw)
    gather = LPUEngine(model, params, paged_kernel="gather", **kw)
    assert stream.kv_bytes_moved_per_step() * 3 == \
        gather.kv_bytes_moved_per_step()
    stream.generate(PROMPTS[:3], max_new_tokens=4)
    assert 0 < stream.stats.peak_pool_blocks <= stream.num_blocks - 1


# ---------------------------------------------------------------------------
# property: the null block (0) never contributes to streamed output
# ---------------------------------------------------------------------------

def _check_null_block_inert(fill: float, len0: int, len1: int) -> None:
    """Scribbling any finite value over block 0 (the null sink absorbing
    padded-prefill and inactive-slot writes) must not change the streamed
    output — valid-length masking happens before the softmax max."""
    q, kp, vp, kn, vn, tables, _ = _fold_inputs(jax.random.PRNGKey(5))
    lengths = jnp.asarray([len0, len1], jnp.int32)
    # tail table entries past the valid length point at the null block
    bs = kp.shape[1]
    t_used0, t_used1 = (len0 + bs - 1) // bs, (len1 + bs - 1) // bs
    tb = np.asarray(tables).copy()
    tb[0, t_used0:] = 0
    tb[1, t_used1:] = 0
    tb = jnp.asarray(tb)
    base = paged_decode_attention(q, kp, vp, tb, lengths,
                                  k_new=kn, v_new=vn)
    kp2 = kp.at[0].set(fill)
    vp2 = vp.at[0].set(fill)
    scribbled = paged_decode_attention(q, kp2, vp2, tb, lengths,
                                       k_new=kn, v_new=vn)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(scribbled))


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(fill=st.floats(-1e30, 1e30, allow_nan=False,
                          allow_infinity=False, width=32),
           len0=st.integers(1, 16), len1=st.integers(1, 16))
    def test_null_block_never_contributes(fill, len0, len1):
        _check_null_block_inert(fill, len0, len1)
except ImportError:        # no hypothesis: fixed adversarial examples
    @pytest.mark.parametrize("fill,len0,len1",
                             [(0.0, 1, 1), (1e30, 3, 16), (-1e30, 16, 2),
                              (-7.5, 8, 9)])
    def test_null_block_never_contributes(fill, len0, len1):
        _check_null_block_inert(fill, len0, len1)


# ---------------------------------------------------------------------------
# ring tp: streamed kernel inside the shard_map engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_ring_streamed_engine_matches_dense_tp1():
    """tp=2 shard_map engine with the STREAMED paged kernel (per-rank
    head-sharded pools, replicated tables) must produce bit-identical
    token streams to the tp=1 dense engine."""
    from tests.util import run_multidevice
    out = run_multidevice("""
    import jax, numpy as np
    from repro.compiler.mapper import plan_model
    from repro.configs import get_config
    from repro.launch.mesh import make_serving_mesh
    from repro.models.registry import build_model
    from repro.serving.engine import LPUEngine

    cfg = get_config('smollm-135m').reduced()
    plan1 = plan_model(cfg, None, (1,), 'serve', esl_overlap=False,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m1 = build_model(cfg, plan1)
    p1, _ = m1.init(jax.random.PRNGKey(0))
    plan2 = plan_model(cfg, ('model',), (2,), 'serve', esl_overlap=True,
                       remat='none', compute_dtype='float32',
                       param_dtype='float32')
    m2 = build_model(cfg, plan2)
    p2, _ = m2.init(jax.random.PRNGKey(0))
    prompts = [[1,2,3,4,5,6,7],[8,9,10,11,12],[13,14,15],[16,17,18,19]]
    ref = LPUEngine(m1, p1, slots=3, max_seq=64, paged=False).generate(
        prompts, max_new_tokens=10)
    mesh = make_serving_mesh(tp=2, rings=1)
    eng = LPUEngine(m2, p2, slots=3, max_seq=64, paged=True,
                    block_size=16, mesh=mesh, paged_kernel='stream')
    got = eng.generate(prompts, max_new_tokens=10)
    assert got == ref, (got, ref)
    assert eng.per_rank_kv_bytes() * 2 == eng.kv_cache_bytes()
    print('PASS')
    """, n_devices=2)
    assert "PASS" in out
