"""Streamlined (kernel-backed) decode layer == ref-path decode layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.core.streamline import decode_layer, stream_bytes_per_layer
from repro.models.common import InitCtx
from repro.models.transformer import init_layer


@pytest.mark.parametrize("use_kernels", [False, True])
def test_decode_layer_kernel_parity(use_kernels):
    cfg = get_config("deepseek-coder-33b").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    ctx = InitCtx(jax.random.PRNGKey(0), param_dtype=jnp.float32)
    p = init_layer(ctx, cfg, plan, 0)
    B, S = 2, 32
    a = plan.attn
    x = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_model))
    cache = {"k": jnp.zeros((B, S, a.gp, a.d_head)),
             "v": jnp.zeros((B, S, a.gp, a.d_head))}
    pos = jnp.asarray([3, 7], jnp.int32)
    y, c2 = decode_layer(p, x, cache, pos, cfg=cfg, plan=plan,
                         use_kernels=use_kernels)
    y_ref, c_ref = decode_layer(p, x, cache, pos, cfg=cfg, plan=plan,
                                use_kernels=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(c2["k"]), np.asarray(c_ref["k"]),
                               rtol=1e-5, atol=1e-5)
    assert y.shape == (B, cfg.d_model)


def test_stream_bytes_accounting():
    cfg = get_config("deepseek-coder-33b")
    plan = plan_model(cfg, ("data", "model"), (16, 16), "serve")
    per_layer = stream_bytes_per_layer(cfg, plan, kv_len=1024)
    # weights dominate: roughly layer params * 2B / tp (padding inflates)
    approx = cfg.layer_params(0) * 2 / 16
    assert 0.8 * approx < per_layer < 2.5 * approx
