"""Streamlined (kernel-backed) decode layer == ref-path decode layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.core.streamline import decode_layer, stream_bytes_per_layer
from repro.models.common import InitCtx
from repro.models.transformer import init_layer


@pytest.mark.parametrize("use_kernels", [False, True])
def test_decode_layer_kernel_parity(use_kernels):
    cfg = get_config("deepseek-coder-33b").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    ctx = InitCtx(jax.random.PRNGKey(0), param_dtype=jnp.float32)
    p = init_layer(ctx, cfg, plan, 0)
    B, S = 2, 32
    a = plan.attn
    x = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_model))
    cache = {"k": jnp.zeros((B, S, a.gp, a.d_head)),
             "v": jnp.zeros((B, S, a.gp, a.d_head))}
    pos = jnp.asarray([3, 7], jnp.int32)
    y, c2 = decode_layer(p, x, cache, pos, cfg=cfg, plan=plan,
                         use_kernels=use_kernels)
    y_ref, c_ref = decode_layer(p, x, cache, pos, cfg=cfg, plan=plan,
                                use_kernels=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(c2["k"]), np.asarray(c_ref["k"]),
                               rtol=1e-5, atol=1e-5)
    assert y.shape == (B, cfg.d_model)


def test_decode_layer_paged_matches_dense():
    """decode_layer against the shared block pool (block tables) must
    equal the dense per-slot cache path bit-for-bit: the table only
    redirects where KV tiles live, never what is computed."""
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    ctx = InitCtx(jax.random.PRNGKey(0), param_dtype=jnp.float32)
    p = init_layer(ctx, cfg, plan, 0)
    a = plan.attn
    B, S, bs = 2, 32, 8
    T = S // bs
    x = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.d_model))
    k0 = jax.random.normal(jax.random.PRNGKey(2), (B, S, a.gp, a.d_head))
    v0 = jax.random.normal(jax.random.PRNGKey(3), (B, S, a.gp, a.d_head))
    pos = jnp.asarray([5, 11], jnp.int32)
    y_ref, c_ref = decode_layer(p, x, {"k": k0, "v": v0}, pos, cfg=cfg,
                                plan=plan, use_kernels=False)
    # scatter the dense cache into a pool through per-request tables
    # (block 0 = null block, requests own disjoint blocks 1..2T)
    tables = np.arange(1, 2 * T + 1, dtype=np.int32).reshape(B, T)
    pool_k = jnp.zeros((2 * T + 1, bs, a.gp, a.d_head))
    pool_v = jnp.zeros((2 * T + 1, bs, a.gp, a.d_head))
    chunks_k = np.asarray(k0).reshape(B, T, bs, a.gp, a.d_head)
    chunks_v = np.asarray(v0).reshape(B, T, bs, a.gp, a.d_head)
    pool_k = pool_k.at[tables].set(chunks_k)
    pool_v = pool_v.at[tables].set(chunks_v)
    y_pg, c_pg = decode_layer(p, x, {"k": pool_k, "v": pool_v}, pos,
                              cfg=cfg, plan=plan, use_kernels=False,
                              block_table=jnp.asarray(tables))
    assert np.array_equal(np.asarray(y_pg), np.asarray(y_ref))
    # the new token's KV landed in the right physical block slot
    for b in range(B):
        blk, off = tables[b, int(pos[b]) // bs], int(pos[b]) % bs
        assert np.array_equal(np.asarray(c_pg["k"][blk, off]),
                              np.asarray(c_ref["k"][b, int(pos[b])]))


def test_stream_bytes_accounting():
    cfg = get_config("deepseek-coder-33b")
    plan = plan_model(cfg, ("data", "model"), (16, 16), "serve")
    per_layer = stream_bytes_per_layer(cfg, plan, kv_len=1024)
    # weights dominate: roughly layer params * 2B / tp (padding inflates)
    approx = cfg.layer_params(0) * 2 / 16
    assert 0.8 * approx < per_layer < 2.5 * approx
