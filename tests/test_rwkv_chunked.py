"""Chunked WKV (the §Perf-2 formulation) vs the per-step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.rwkv import wkv_chunked, wkv_scan

K = jax.random.PRNGKey(0)


def _inputs(B, S, H, dh, wmin=0.2, seed=0):
    ks = [jax.random.fold_in(jax.random.PRNGKey(seed), i) for i in range(6)]
    r = jax.random.normal(ks[0], (B, S, H, dh))
    k = 0.3 * jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    w = jax.random.uniform(ks[3], (B, S, H, dh), minval=wmin, maxval=0.999)
    u = 0.2 * jax.random.normal(ks[4], (H, dh))
    s0 = 0.1 * jax.random.normal(ks[5], (B, H, dh, dh))
    return r, k, v, w, u, s0


@pytest.mark.parametrize("chunk", [8, 32, 64])
@pytest.mark.parametrize("S", [7, 32, 100])
def test_chunked_matches_scan(chunk, S):
    args = _inputs(2, S, 3, 16)
    y1, s1 = wkv_scan(*args)
    y2, s2 = wkv_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_chunked_stable_extreme_decay():
    """All decay exponents <= 0 by construction: tiny w must not blow up."""
    args = _inputs(2, 64, 2, 16, wmin=1e-6, seed=3)
    y1, s1 = wkv_scan(*args)
    y2, s2 = wkv_chunked(*args, chunk=32)
    assert np.isfinite(np.asarray(y2)).all()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)


def test_chunked_differentiable():
    args = list(_inputs(1, 16, 1, 8))

    def loss(r):
        y, _ = wkv_chunked(r, *args[1:], chunk=8)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(args[0])
    assert np.isfinite(np.asarray(g)).all()

    def loss_ref(r):
        y, _ = wkv_scan(r, *args[1:])
        return jnp.sum(y ** 2)

    g_ref = jax.grad(loss_ref)(args[0])
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-3, atol=1e-3)


@given(s=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_chunked_property(s, chunk, seed):
    args = _inputs(1, s, 2, 8, seed=seed)
    y1, s1 = wkv_scan(*args)
    y2, s2 = wkv_chunked(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=5e-4, atol=5e-4)
