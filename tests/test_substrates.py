"""Optimizer / checkpoint / FT / schedule / compression unit tests."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.launch.ft import (FailureInjector, HeartbeatTracker,
                             StragglerMonitor)
from repro.optim import AdamW, get_schedule
from repro.optim.adamw import compressed_psum, int8_compress, int8_decompress
from repro.optim.schedule import cosine_schedule, wsd_schedule


def test_adamw_descends_quadratic():
    opt = AdamW(lr=lambda s: 0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.apply(params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_grad_clip():
    opt = AdamW(lr=lambda s: 0.0, clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    _, _, m = opt.apply(params, {"w": jnp.full((4,), 100.0)}, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    cos = cosine_schedule(1.0, 10, 100)
    assert float(cos(0)) == 0.0
    assert float(cos(10)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1, rel=1e-2)
    wsd = wsd_schedule(1.0, 10, 100, decay_frac=0.2)
    assert float(wsd(50)) == pytest.approx(1.0)      # stable phase
    assert float(wsd(99)) < 0.05                     # decay tail
    assert float(wsd(5)) == pytest.approx(0.5)       # warmup


def test_int8_compression_roundtrip_error_feedback():
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(256).astype(np.float32))
    q, amax = int8_compress(g)
    deq = int8_decompress(q, amax)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.02
    # error feedback: accumulated residual keeps the running sum unbiased
    err = jnp.zeros_like(g)
    total_true = jnp.zeros_like(g)
    total_q = jnp.zeros_like(g)
    for i in range(16):
        gi = jnp.asarray(rng.randn(256).astype(np.float32))
        total_true += gi
        gf = gi + err
        q, amax = int8_compress(gf)
        deq = int8_decompress(q, amax)
        err = gf - deq
        total_q += deq
    drift = float(jnp.linalg.norm(total_q + err - total_true)
                  / jnp.linalg.norm(total_true))
    assert drift < 1e-5        # EF makes the quantizer lossless in sum


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr.save(5, tree, extra={"next_step": 6})
    assert mgr.latest_step() == 5
    out = mgr.restore(5, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    assert mgr.manifest(5)["extra"]["next_step"] == 6


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.latest_step() == 4
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(7, {"x": jnp.ones((3,))})
    mgr.wait()
    assert mgr.latest_step() == 7


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(warmup=3, cooldown=0)
    for s in range(10):
        assert mon.record(s, 0.1 + 0.001 * s) is None
    ev = mon.record(10, 2.0)
    assert ev is not None and ev.kind == "straggler"
    # mu not poisoned by the outlier
    assert mon.mu < 0.2


def test_heartbeat_tracker():
    hb = HeartbeatTracker(4, timeout_s=10.0)
    now = 1000.0
    for w in range(4):
        hb.beat(w, now)
    assert hb.check(now + 5) == []
    hb.beat(0, now + 9)
    failed = hb.check(now + 12)
    assert sorted(failed) == [1, 2, 3]
    assert hb.check(now + 12) == []       # no double report


def test_failure_injector():
    inj = FailureInjector(fail_at_steps=[3])
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError):
        inj.maybe_fail(3)
    inj.maybe_fail(3)                      # fires once only
