"""Quantized KV pool + int8 weight streaming.

Covers the tentpole's three contracts:

* quantization math — symmetric absmax per (row, kv head), all-zero
  rows (the null block) dequantize to EXACT zeros, outlier rows stay
  finite and within the rounding bound, fp8 storage when the jax build
  provides it;
* kernel dequant parity — the streamed Pallas kernel dequantizing
  inside its tile loop agrees with the gather oracle and with a
  hand-dequantized dense reference, including the folded new token;
* scale survival — the fp16 scale side-arrays ride along through every
  pool lifecycle event (copy-on-write duplication, prefix-cache block
  sharing, speculative rollback, preemption + recomputation), proven by
  bit-identical greedy streams across each on/off pair on the SAME
  int8 engine;

plus the int8 weight-streaming gemv (per-output-column scales applied
at the f32 flush) and the per-operand VMEM sizing fix in plan_blocks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.mapper import plan_model
from repro.compiler.plan import resolve_kv_precision
from repro.configs import get_config
from repro.kernels.decode_attention import (decode_attention_ref,
                                            gather_kv_pages,
                                            paged_decode_attention)
from repro.kernels.gemv import gemv, gemv_ref, plan_blocks, quantize_weight
from repro.kernels.gemv.ops import VMEM_BYTES
from repro.models.registry import build_model
from repro.serving.config import EngineConfig
from repro.serving.engine import LPUEngine
from repro.serving.kv_cache import (cache_bytes, copy_pool_block,
                                    dequantize_kv, per_rank_block_bytes,
                                    qmax_for_dtype, quantize_kv_rows)

HAS_FP8 = hasattr(jnp, "float8_e4m3fn")


# ---------------------------------------------------------------------------
# quantization math
# ---------------------------------------------------------------------------

def test_qmax_for_dtype():
    assert qmax_for_dtype(jnp.int8) == 127.0
    if HAS_FP8:
        assert qmax_for_dtype(jnp.float8_e4m3fn) == 448.0
    with pytest.raises(ValueError):
        qmax_for_dtype(jnp.float16)


def test_int8_roundtrip_within_rounding_bound():
    rows = jax.random.normal(jax.random.PRNGKey(0), (5, 16, 2, 32))
    q, s = quantize_kv_rows(rows, jnp.int8, jnp.float16)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float16
    assert s.shape == rows.shape[:-1]
    deq = dequantize_kv(q, s)
    # per-element error <= half a quantization step plus the fp16 scale
    # rounding amplified by up to qmax: (0.5 + 127 * 2^-11) * scale
    bound = np.asarray(s, np.float32)[..., None] * 0.57 + 1e-6
    assert np.all(np.abs(np.asarray(deq - rows)) <= bound)


def test_all_zero_rows_dequantize_to_exact_zeros():
    """The null block's contract: scale 0, no NaN from the 0/0 divisor,
    and the dequantized row is EXACTLY zero (so the null block never
    contributes to attention)."""
    rows = jnp.zeros((3, 8, 2, 32))
    q, s = quantize_kv_rows(rows, jnp.int8, jnp.float16)
    assert not np.any(np.isnan(np.asarray(s)))
    assert np.all(np.asarray(s) == 0.0)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(dequantize_kv(q, s)) == 0.0)


def test_outlier_row_stays_finite_and_bounded():
    """One huge magnitude sets the row scale; small entries may collapse
    to zero but nothing overflows, and every element stays within half a
    step of its source."""
    rows = np.full((1, 4, 1, 32), 0.3, np.float32)
    rows[0, 1, 0, 7] = 1e4
    q, s = quantize_kv_rows(jnp.asarray(rows), jnp.int8, jnp.float16)
    deq = np.asarray(dequantize_kv(q, s))
    assert np.all(np.isfinite(deq))
    bound = np.asarray(s, np.float32)[..., None] * 0.57 + 1e-6
    assert np.all(np.abs(deq - rows) <= bound)
    # the outlier itself survives to within a (rounding + fp16-scale)
    # step of its source
    assert abs(deq[0, 1, 0, 7] - 1e4) <= 0.57 * float(s[0, 1, 0])


@pytest.mark.skipif(not HAS_FP8, reason="no jnp.float8_e4m3fn")
def test_fp8_roundtrip():
    rows = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 2, 32))
    q, s = quantize_kv_rows(rows, jnp.float8_e4m3fn, jnp.float16)
    assert q.dtype == jnp.float8_e4m3fn
    deq = np.asarray(dequantize_kv(q, s))
    # fp8 e4m3 keeps ~2 significand bits through the scale: coarse but
    # proportional error
    ref = np.asarray(rows)
    assert np.abs(deq - ref).max() <= 0.1 * np.abs(ref).max() + 1e-3


def test_resolve_kv_precision_sizing():
    p = resolve_kv_precision("int8", "float32")
    assert p.quantized and p.store_dtype == "int8"
    assert p.itemsize == 1 and p.scale_itemsize == 2
    # the byte count the 0.55x moved-bytes gate depends on:
    # d_head + scale vs 2 * d_head
    assert p.bytes_per_row_head(32) == 34
    auto = resolve_kv_precision("auto", "float32")
    assert not auto.quantized and auto.scale_itemsize == 0
    assert auto.bytes_per_row_head(32) == 128
    fp16 = resolve_kv_precision("fp16", "float32")
    assert not fp16.quantized and fp16.bytes_per_row_head(32) == 64


def test_per_rank_block_bytes_includes_scales():
    base = per_rank_block_bytes(2, 2, 32, 16, 1)
    with_scales = per_rank_block_bytes(2, 2, 32, 16, 1, scale_bytes=2)
    assert with_scales - base == 2 * 2 * 16 * 2 * 2  # 2KV*L*bs*G*scale


# ---------------------------------------------------------------------------
# kernel dequant parity (stream vs oracle vs hand-dequantized dense)
# ---------------------------------------------------------------------------

def _quantized_fold_inputs(key, B=2, H=4, G=2, dh=16, bs=8, T=4, N=9):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, G, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, G, dh), jnp.float32)
    kq, ksc = quantize_kv_rows(kp, jnp.int8, jnp.float16)
    vq, vsc = quantize_kv_rows(vp, jnp.int8, jnp.float16)
    k_new = jax.random.normal(ks[3], (B, G, dh), jnp.float32)
    v_new = jax.random.normal(ks[4], (B, G, dh), jnp.float32)
    tables = jnp.asarray(np.arange(1, B * T + 1, dtype=np.int32)
                         .reshape(B, T))
    lengths = jnp.asarray([13, 27], jnp.int32)
    return q, kq, vq, ksc, vsc, k_new, v_new, tables, lengths


def test_stream_dequant_matches_hand_dequantized_dense():
    """The Pallas kernel dequantizing per tile == dequantize the whole
    pool first and run the dense reference."""
    (q, kq, vq, ksc, vsc, kn, vn, tables,
     lengths) = _quantized_fold_inputs(jax.random.PRNGKey(2))
    B, H = q.shape[:2]
    gs = H // kq.shape[2]
    out = paged_decode_attention(q, kq, vq, tables, lengths,
                                 k_new=kn, v_new=vn,
                                 k_scale=ksc, v_scale=vsc)
    kd, vd = dequantize_kv(kq, ksc), dequantize_kv(vq, vsc)
    ke = jnp.repeat(gather_kv_pages(kd, tables), gs, axis=2)
    ve = jnp.repeat(gather_kv_pages(vd, tables), gs, axis=2)
    ke = ke.at[jnp.arange(B), lengths].set(jnp.repeat(kn, gs, axis=1))
    ve = ve.at[jnp.arange(B), lengths].set(jnp.repeat(vn, gs, axis=1))
    ref = decode_attention_ref(q, ke, ve, lengths + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_stream_dequant_matches_gather_oracle():
    """Both paged paths must dequantize identically (the use_pallas=False
    oracle is what the engine's gather mode runs)."""
    (q, kq, vq, ksc, vsc, kn, vn, tables,
     lengths) = _quantized_fold_inputs(jax.random.PRNGKey(3))
    pal = paged_decode_attention(q, kq, vq, tables, lengths,
                                 k_new=kn, v_new=vn,
                                 k_scale=ksc, v_scale=vsc)
    ora = paged_decode_attention(q, kq, vq, tables, lengths,
                                 k_new=kn, v_new=vn,
                                 k_scale=ksc, v_scale=vsc,
                                 use_pallas=False)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ora),
                               rtol=2e-5, atol=2e-5)


def test_copy_pool_block_carries_scales():
    """Copy-on-write duplicates the scale side-arrays with the data —
    a CoW that forgot the scales would dequantize the copy wrongly."""
    key = jax.random.PRNGKey(4)
    kp = jax.random.normal(key, (1, 5, 4, 2, 8))   # (n_sb, N, bs, G, dh)
    kq, ksc = quantize_kv_rows(kp, jnp.int8, jnp.float16)
    cache = {"l0": {"k": kq, "v": kq, "k_scale": ksc, "v_scale": ksc}}
    out = copy_pool_block(cache, jnp.int32(2), jnp.int32(4))
    for leaf in ("k", "v", "k_scale", "v_scale"):
        np.testing.assert_array_equal(
            np.asarray(out["l0"][leaf][:, 4]),
            np.asarray(cache["l0"][leaf][:, 2]))


# ---------------------------------------------------------------------------
# engine level: scale survival + accuracy
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10, 11]]


def _int8(model, params, **kw):
    base = dict(slots=2, max_seq=64, paged=True, block_size=16,
                kv_dtype="int8")
    base.update(kw)
    return LPUEngine(model, params, EngineConfig(**base))


def test_engine_int8_stream_matches_gather(tiny_model):
    """Both dataflows fold the SAME quantize->dequantize round-trip of
    the new token, so their greedy streams agree token-for-token."""
    model, params = tiny_model
    outs = {}
    for kern in ("stream", "gather"):
        eng = _int8(model, params, paged_kernel=kern)
        outs[kern] = eng.generate(PROMPTS, max_new_tokens=8)
    assert outs["stream"] == outs["gather"]


def test_engine_int8_cache_has_scale_leaves_and_honest_bytes(tiny_model):
    model, params = tiny_model
    eng = _int8(model, params)
    l0 = eng.cache["l0"]
    assert set(l0) == {"k", "v", "k_scale", "v_scale"}
    assert l0["k"].dtype == jnp.int8
    assert l0["k_scale"].dtype == jnp.float16
    assert l0["k_scale"].shape == l0["k"].shape[:-1]
    # reported bytes include the scale side-arrays (per-rank block
    # bytes x blocks is exactly the pool pytree's footprint)
    a = eng.plan.attn
    per_block = per_rank_block_bytes(eng.cfg.n_layers, a.kv_per_rank,
                                     a.d_head, eng.block_size,
                                     eng.kv_prec.itemsize,
                                     eng.kv_prec.scale_itemsize)
    assert cache_bytes(eng.cache) == per_block * eng.num_blocks
    assert eng.kv_cache_bytes() == cache_bytes(eng.cache)


def test_engine_int8_prefix_sharing_parity(tiny_model):
    """Shared-prefix admissions map quantized blocks (and their scales)
    into other tables; streams must match the cold-start engine."""
    model, params = tiny_model
    sys_prompt = [7, 3, 5, 2, 9, 4, 8, 6] * 4       # 2 full blocks
    prompts = [sys_prompt + [t] for t in (11, 12, 13)]
    outs = {}
    for on in (False, True):
        eng = _int8(model, params, slots=3, prefix_cache=on)
        outs[on] = eng.generate(prompts, max_new_tokens=8)
        if on:
            assert eng.stats.prefix_hit_blocks > 0
    assert outs[True] == outs[False]


def test_engine_int8_speculative_parity(tiny_model):
    """Rejection sampling stays EXACT on the quantized pool: draft,
    verify and rollback all read/write the same stored (int8, scale)
    pairs, so spec-on streams match spec-off bit-for-bit."""
    model, params = tiny_model
    motif = [3, 1, 4, 1]
    prompts = [motif * 6, motif * 5]
    outs = {}
    for spec in ("off", "ngram"):
        eng = _int8(model, params, max_seq=128, speculate=spec,
                    draft_k=4)
        outs[spec] = eng.generate(prompts, max_new_tokens=12)
        if spec == "ngram":
            assert eng.stats.accepted_tokens > 0
    assert outs["ngram"] == outs["off"]


def test_engine_int8_preemption_parity(tiny_model):
    """A pool too small for all streams forces preempt + recompute; the
    recomputed blocks requantize to the same stored values, so streams
    match the uncontended engine."""
    model, params = tiny_model
    big = _int8(model, params, slots=3)
    ob = big.generate(PROMPTS, max_new_tokens=20)
    # 3 slots x up to 24 resident tokens but only 4 usable 8-tok blocks:
    # streams evict each other and recompute on resume
    small = _int8(model, params, slots=3, block_size=8, num_blocks=5)
    os_ = small.generate(PROMPTS, max_new_tokens=20)
    assert small.stats.preemptions > 0
    assert os_ == ob


def test_engine_int8_greedy_drift_bound(tiny_model):
    """Accuracy gate at engine level: int8 streams stay within the
    documented common-prefix bound of the full-precision engine (the
    same bound serving_bench enforces against its fp16 row)."""
    model, params = tiny_model
    fp = LPUEngine(model, params, EngineConfig(slots=2, max_seq=64,
                                               paged=True, block_size=16))
    of = fp.generate(PROMPTS, max_new_tokens=8)
    oq = _int8(model, params).generate(PROMPTS, max_new_tokens=8)
    agree = []
    for a, b in zip(oq, of):
        n = min(len(a), len(b))
        k = 0
        while k < n and a[k] == b[k]:
            k += 1
        agree.append(k / n)
    assert sum(agree) / len(agree) >= 0.75, agree


def test_engine_fp16_pool_halves_bytes(tiny_model):
    """An explicit fp dtype restores the pool at that width — no scale
    arrays, half the f32 bytes."""
    model, params = tiny_model
    f32 = LPUEngine(model, params, EngineConfig(slots=2, max_seq=64,
                                                paged=True, block_size=16))
    f16 = LPUEngine(model, params, EngineConfig(slots=2, max_seq=64,
                                                paged=True, block_size=16,
                                                kv_dtype="float16"))
    assert "k_scale" not in f16.cache["l0"]
    assert f16.kv_cache_bytes() * 2 == f32.kv_cache_bytes()


def test_engine_int8_moved_bytes_ratio(tiny_model):
    """The analytic bandwidth claim the bench gates: int8+scales move
    (dh + 2) / (2 * dh) of the fp16 bytes per step — 0.531 at dh=32,
    inside the 0.55 CI gate."""
    model, params = tiny_model
    f16 = LPUEngine(model, params, EngineConfig(slots=2, max_seq=64,
                                                paged=True, block_size=16,
                                                kv_dtype="float16"))
    q8 = _int8(model, params)
    ratio = q8.kv_bytes_moved_per_step() / f16.kv_bytes_moved_per_step()
    assert ratio <= 0.55, ratio


def test_engine_int8_requires_paged(tiny_model):
    model, params = tiny_model
    with pytest.raises(ValueError, match="paged"):
        LPUEngine(model, params, EngineConfig(paged=False,
                                              kv_dtype="int8"))


# ---------------------------------------------------------------------------
# int8 weight streaming (gemv) + per-operand VMEM sizing
# ---------------------------------------------------------------------------

def test_gemv_int8_matches_fp_within_quant_error():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(6), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(7), (256,), jnp.float32)
    qw, ws = quantize_weight(w)
    assert qw.dtype == jnp.int8 and ws.shape == (256,)
    out = gemv(x, qw, b, w_scale=ws)
    ref = x @ w + b
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel <= 0.05, rel


def test_gemv_int8_pallas_matches_ref_exactly():
    """Same quantized operands through the kernel and the jnp oracle:
    the scale is applied at the f32 flush BEFORE the bias in both."""
    x = jax.random.normal(jax.random.PRNGKey(8), (3, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(9), (128, 128), jnp.float32)
    b = jnp.ones((128,), jnp.float32) * 100.0      # bias must NOT scale
    qw, ws = quantize_weight(w)
    pal = gemv(x, qw, b, w_scale=ws, use_pallas=True)
    ref = gemv_ref(x, qw, b, w_scale=ws)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-4)


def test_quantize_weight_zero_column():
    w = jnp.zeros((64, 4))
    qw, ws = quantize_weight(w)
    assert np.all(np.asarray(ws) == 0) and np.all(np.asarray(qw) == 0)
    out = gemv_ref(jnp.ones((1, 64)), qw, None, w_scale=ws)
    assert np.all(np.asarray(out) == 0)


def test_plan_blocks_sizes_per_operand():
    """int8 weights with f32 activations: the streamed tile is budgeted
    at 1 B/elem while the stationary activation pays its own 4 B/elem —
    a uniform byte width would either starve or overflow the window."""
    B, K, N = 8, 4096, 4096
    budget = VMEM_BYTES // 2
    bk, bn = plan_blocks(B, K, N, w_bytes=1, x_bytes=4)
    assert 2 * bk * bn * 1 + B * bk * 4 + B * bn * 4 <= budget
    # the int8 stream affords at least the fp16 tile area
    bk2, bn2 = plan_blocks(B, K, N, w_bytes=2, x_bytes=4)
    assert bk * bn >= bk2 * bn2
    assert 2 * bk2 * bn2 * 2 + B * bk2 * 4 + B * bn2 * 4 <= budget
