"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.gemv import gemv, gemv_ref
from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
from repro.kernels.rwkv_scan import rwkv_scan, rwkv_scan_ref

K0 = jax.random.PRNGKey(0)


def _tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,K,N", [(1, 128, 128), (8, 512, 1024),
                                   (4, 1024, 384), (2, 256, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bias", [False, True])
def test_gemv(B, K, N, dtype, bias):
    x = jax.random.normal(K0, (B, K), dtype)
    w = jax.random.normal(jax.random.fold_in(K0, 1), (K, N), dtype)
    b = jax.random.normal(jax.random.fold_in(K0, 2), (N,), dtype) \
        if bias else None
    got = gemv(x, w, b)
    ref = gemv_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,G,gs,dh", [
    (2, 512, 2, 4, 128), (1, 1024, 1, 8, 128), (3, 384, 4, 1, 128),
    (2, 256, 8, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, G, gs, dh, dtype):
    H = G * gs
    q = jax.random.normal(K0, (B, H, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(K0, 3), (B, S, G, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(K0, 4), (B, S, G, dh), dtype)
    lengths = jnp.asarray(
        np.random.RandomState(0).randint(1, S + 1, size=B), jnp.int32)
    got = decode_attention(q, k, v, lengths)
    ke = jnp.repeat(k, gs, 2)
    ve = jnp.repeat(v, gs, 2)
    ref = decode_attention_ref(q, ke, ve, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,C,N", [(1, 32, 8, 8), (2, 128, 16, 16),
                                     (2, 64, 32, 8)])
def test_mamba_scan(B, S, C, N):
    da = jax.random.uniform(K0, (B, S, C, N), minval=0.5, maxval=0.99)
    bx = 0.1 * jax.random.normal(jax.random.fold_in(K0, 5), (B, S, C, N))
    c = jax.random.normal(jax.random.fold_in(K0, 6), (B, S, N))
    h0 = 0.1 * jax.random.normal(jax.random.fold_in(K0, 7), (B, C, N))
    y, h = mamba_scan(da, bx, c, h0)
    yr, hr = mamba_scan_ref(da, bx, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S,H,dh", [(1, 16, 1, 8), (2, 64, 2, 16),
                                      (2, 32, 4, 32)])
def test_rwkv_scan(B, S, H, dh):
    r = jax.random.normal(K0, (B, S, H, dh))
    k = 0.3 * jax.random.normal(jax.random.fold_in(K0, 8), (B, S, H, dh))
    v = jax.random.normal(jax.random.fold_in(K0, 9), (B, S, H, dh))
    w = jax.random.uniform(jax.random.fold_in(K0, 10), (B, S, H, dh),
                           minval=0.8, maxval=0.999)
    u = 0.2 * jax.random.normal(jax.random.fold_in(K0, 11), (H, dh))
    s0 = 0.1 * jax.random.normal(jax.random.fold_in(K0, 12),
                                 (B, H, dh, dh))
    y, s = rwkv_scan(r, k, v, w, u, s0)
    yr, sr = rwkv_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)


def test_gemv_state_reset_between_calls():
    """Grid re-execution must re-init the accumulator."""
    x = jnp.ones((2, 256), jnp.float32)
    w = jnp.ones((256, 256), jnp.float32)
    a = gemv(x, w)
    b = gemv(x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
