"""Trace generator determinism + randomized interleaving against the fleet.

Two layers:

* generator properties (no model): same seed -> bit-identical trace
  across calls (and, because PCG64 + crc32-free construction, across
  processes), sorted arrivals, per-tenant shared prefixes, config
  validation.  With ``hypothesis`` installed the property runs over a
  drawn config space; without it, a fixed seed sweep (the repo's
  guarded-hypothesis convention).
* interleaving (tiny model): a seeded random schedule of submits,
  mid-stream cancels and injected chaos against a mesh-free 2-ring
  fleet must end with the admission ledger balanced —
  ``completed + failed + cancelled == submitted`` — and zero leaked
  pool blocks (``assert_pool_balanced`` via ``check_pool_balanced``).
"""
import asyncio
import random
import sys
from pathlib import Path

import jax
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))

import traces as tr  # noqa: E402

from repro.compiler.mapper import plan_model  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.serving.config import EngineConfig  # noqa: E402
from repro.serving.engine import MultiRingEngine  # noqa: E402
from repro.serving.frontend import AsyncFrontend  # noqa: E402

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("smollm-135m").reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


# -- generator properties ----------------------------------------------


def _props(cfg: tr.TraceConfig):
    trace = tr.generate_trace(cfg)
    again = tr.generate_trace(cfg)
    assert trace == again                       # process-deterministic
    assert len(trace) == cfg.requests
    arr = [r.arrival_s for r in trace]
    assert arr == sorted(arr) and arr[0] == 0.0
    names = {r.tenant for r in trace}
    assert names <= {f"tenant{i}" for i in range(cfg.tenants)}
    prefixes = tr.tenant_prefixes(cfg)
    by_name = {f"tenant{i}": p for i, p in enumerate(prefixes)}
    for r in trace:
        assert list(r.prompt[:cfg.prefix_len]) == by_name[r.tenant]
        assert cfg.tail_min <= len(r.prompt) - cfg.prefix_len \
            <= cfg.tail_max
        assert cfg.max_new_min <= r.max_new_tokens <= cfg.max_new_max
        assert all(1 <= t < cfg.vocab for t in r.prompt)


def test_trace_deterministic_fixed_seeds():
    for seed in (0, 1, 7, 123):
        for arrival in ("poisson", "pareto"):
            _props(tr.TraceConfig(seed=seed, requests=12, tenants=2,
                                  arrival=arrival, prefix_len=16))
    # different seeds diverge (same config otherwise)
    a = tr.generate_trace(tr.TraceConfig(seed=0, requests=12))
    b = tr.generate_trace(tr.TraceConfig(seed=1, requests=12))
    assert a != b


def test_trace_config_validation():
    for bad in (dict(requests=0), dict(tenants=0),
                dict(arrival="uniform"), dict(rate_rps=0.0),
                dict(pareto_shape=1.0), dict(tail_min=0),
                dict(tail_min=9, tail_max=8), dict(max_new_min=0),
                dict(vocab=1), dict(prefix_len=-1)):
        with pytest.raises(ValueError):
            tr.TraceConfig(**bad)
    with pytest.raises(ValueError):
        tr.generate_trace(tr.TraceConfig(tenants=2,
                                         tenant_names=("only-one",)))


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1),
           requests=st.integers(1, 24),
           tenants=st.integers(1, 4),
           arrival=st.sampled_from(["poisson", "pareto"]),
           prefix_len=st.integers(0, 48),
           rate=st.floats(0.5, 1e4, allow_nan=False))
    @settings(max_examples=60, deadline=None)
    def test_trace_properties_fuzz(seed, requests, tenants, arrival,
                                   prefix_len, rate):
        _props(tr.TraceConfig(seed=seed, requests=requests,
                              tenants=tenants, arrival=arrival,
                              prefix_len=prefix_len, rate_rps=rate))


# -- randomized submit/cancel/chaos interleaving -----------------------


def _interleave(tiny_model, seed: int, chaos: str) -> None:
    """One seeded episode: replay a bursty trace through the async
    frontend over a chaos fleet, cancelling a random subset of streams
    mid-flight; assert the ledger balances and no block leaks."""
    model, params = tiny_model
    rng = random.Random(seed)
    trace = tr.generate_trace(tr.TraceConfig(
        seed=seed, requests=8, tenants=2, prefix_len=16, tail_max=8,
        max_new_min=4, max_new_max=10, rate_rps=500.0))
    fleet = MultiRingEngine(model, params, None, rings=2,
                            config=EngineConfig(
                                slots=2, max_seq=64, paged=True,
                                block_size=16, prefix_cache=True,
                                chaos=chaos, heartbeat_timeout_s=4.0))
    cancel_at = {r.rid: rng.randint(0, 3) for r in trace
                 if rng.random() < 0.4}

    async def consume(stream, after):
        got = 0
        async for _ in stream:
            got += 1
            if after is not None and got >= after:
                await stream.cancel()
                break

    async def main():
        async with AsyncFrontend(fleet) as fe:
            tasks = []
            for r in trace:
                stream = fe.submit(r.prompt, r.max_new_tokens,
                                   tenant=r.tenant)
                tasks.append(asyncio.ensure_future(
                    consume(stream, cancel_at.get(r.rid))))
                if rng.random() < 0.5:
                    await asyncio.sleep(0)      # jitter the interleave
            await asyncio.gather(*tasks)
            await fe.join()
        c = fe.counters
        assert c["completed"] + c["failed"] + c["cancelled"] \
            == c["submitted"] == len(trace), c
        for eng in fleet.engines:
            eng.check_pool_balanced()           # zero leaked blocks

    asyncio.run(main())


CHAOS = "ring@2,nan@4"


def test_interleaving_fixed_seeds_with_chaos(tiny_model):
    for seed in (0, 3):
        _interleave(tiny_model, seed, CHAOS)


def test_interleaving_no_chaos(tiny_model):
    _interleave(tiny_model, 11, "")


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=4, deadline=None)
    def test_interleaving_fuzz(tiny_model, seed):
        _interleave(tiny_model, seed, CHAOS)
