"""Quickstart: build a model, run the HyperDex-style generate() API.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.compiler.mapper import plan_model, summarize  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.serving.config import EngineConfig  # noqa: E402
from repro.serving.engine import LPUEngine  # noqa: E402
from repro.serving.sampler import SamplingParams  # noqa: E402


def main():
    # any assigned arch works: --arch qwen / deepseek / jamba / rwkv6 ...
    arch = sys.argv[sys.argv.index("--arch") + 1] \
        if "--arch" in sys.argv else "smollm-135m"
    cfg = get_config(arch).reduced()       # CPU-feasible reduction
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    print("mapper plan:", summarize(plan))

    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  ({n/1e6:.1f}M params reduced)")

    engine = LPUEngine(model, params, EngineConfig(slots=2, max_seq=64))
    prompts = [[1, 2, 3, 4], [10, 11, 12]]

    def stream(rid, tok):
        print(f"  [stream] request {rid} -> token {tok}")

    outs = engine.generate(prompts, max_new_tokens=8,
                           params=SamplingParams(0.0, 0, 1.0),
                           stream_cb=stream)
    for i, o in enumerate(outs):
        print(f"request {i}: {o}")
    st = engine.stats
    print(f"{st.tokens} tokens @ {st.tokens_per_s:.1f} tok/s, "
          f"occupancy {st.occupancy:.2f}")


if __name__ == "__main__":
    main()
