"""End-to-end driver: train the ~135M-param smollm config for a few
hundred steps on the synthetic corpus, with checkpoints + auto-resume.

Full-size 135M on CPU is slow; by default this trains the true config at
a shortened sequence length (the assignment's 'train ~100M model for a
few hundred steps' driver — pass --full-seq on real hardware).

    PYTHONPATH=src python examples/train_smollm.py --steps 300
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from dataclasses import replace  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.train import run_training  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-seq", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CI-speed)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_smollm_ckpt")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if args.tiny:
        cfg = cfg.reduced()
    seq = 2048 if args.full_seq else args.seq

    _, _, losses = run_training(
        cfg=cfg, steps=args.steps, global_batch=args.batch, seq_len=seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, lr=6e-4, schedule="cosine",
        log_every=10, compute_dtype="float32", param_dtype="float32")
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps "
          f"({'DECREASED' if losses[-1] < losses[0] else 'FLAT'})")


if __name__ == "__main__":
    main()
