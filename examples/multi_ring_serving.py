"""Reconfigurable ESL network (C3): two tenants on disjoint sub-rings.

The paper: an 8-device ring splits into two independent 4-rings so two
models serve concurrently with no interference and no rewiring.  Here:
an 8-device (fake) ``model`` axis splits into two 4-device sub-meshes,
each running a full ring-parallel ``LPUEngine`` — a *different
architecture* per tenant, ESL-overlapped collectives, paged KV pool
sharded 1/4 per rank — and the ring groups are validated disjoint.

    PYTHONPATH=src python examples/multi_ring_serving.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402
from pathlib import Path  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import zlib  # noqa: E402

import jax  # noqa: E402

from repro.compiler.mapper import plan_model  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core.compat import make_mesh  # noqa: E402
from repro.core.rings import reconfigure, submeshes  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.serving.config import EngineConfig  # noqa: E402
from repro.serving.engine import LPUEngine  # noqa: E402


def serve_on(mesh, arch: str):
    """One tenant: a ring-parallel engine on its own sub-mesh."""
    tp = mesh.devices.shape[-1]
    cfg = get_config(arch).reduced()
    plan = plan_model(cfg, ("model",), (tp,), "serve", esl_overlap=True,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(
        jax.random.PRNGKey(zlib.crc32(arch.encode()) % 2 ** 31))
    eng = LPUEngine(model, params, EngineConfig(slots=2, max_seq=32),
                    mesh=mesh)
    outs = eng.generate([[1, 2, 3, 4], [5, 6, 7]], max_new_tokens=6)
    return outs, eng


def main():
    ring = reconfigure(total=8, ring_size=4)
    assert ring.validate_disjoint()
    print(f"[rings] 8-wide model axis -> {ring.n_rings} independent "
          f"4-rings: {ring.groups()}")

    full = make_mesh((8,), ("model",))
    ring_a, ring_b = submeshes(full, ring_size=4)
    print(f"[rings] tenant A devices: {[d.id for d in ring_a.devices.flat]}")
    print(f"[rings] tenant B devices: {[d.id for d in ring_b.devices.flat]}")

    outs_a, eng_a = serve_on(ring_a, "smollm-135m")
    outs_b, eng_b = serve_on(ring_b, "qwen1.5-4b")
    print(f"[rings] tenant A (smollm) decoded: {outs_a}")
    print(f"[rings] tenant B (qwen)   decoded: {outs_b}")
    for name, eng in (("A", eng_a), ("B", eng_b)):
        print(f"[rings] tenant {name}: kv={'paged' if eng.paged else 'dense'}"
              f" {eng.kv_cache_bytes()} B total, "
              f"{eng.per_rank_kv_bytes()} B/rank over tp={eng.tp}")
    print("[rings] two models served concurrently on disjoint sub-rings "
          "— no cross-ring collective possible by construction")


if __name__ == "__main__":
    main()
