"""Reconfigurable ESL network (C3): two models on disjoint sub-rings.

The paper: an 8-device ring splits into two independent 4-rings so two
models serve concurrently with no interference and no rewiring.  Here:
an 8-device (fake) mesh model axis splits into two 4-device sub-meshes,
each serving a *different architecture* simultaneously; the ring groups
are validated disjoint.

    PYTHONPATH=src python examples/multi_ring_serving.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402
from pathlib import Path  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compiler.mapper import plan_model  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core.rings import reconfigure, submeshes  # noqa: E402
from repro.core.dist import make_axis_env  # noqa: E402
from repro.core.steps import build_serve_step  # noqa: E402
from repro.models.registry import build_model  # noqa: E402


def serve_on(mesh, arch: str, steps: int = 4):
    cfg = get_config(arch).reduced()
    plan = plan_model(cfg, ("data", "model"),
                      tuple(mesh.devices.shape), "serve",
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(hash(arch) % 2 ** 31))
    specs, _ = model.param_specs()
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P)))
    step, meta = build_serve_step(model, mesh, 2, 32)
    cache = model.init_cache(2, 32, dtype=jnp.float32)
    cache = jax.device_put(cache, jax.tree.map(
        lambda s: NamedSharding(mesh, s), meta["cache_specs"],
        is_leaf=lambda x: isinstance(x, P)))
    step = jax.jit(step)
    toks = jnp.ones((2, 1), jnp.int32)
    seq = []
    for t in range(steps):
        pos = jnp.full((2,), t, jnp.int32)
        nxt, cache = step(params, cache, toks, pos)
        toks = np.asarray(nxt)[:, None]
        seq.append(int(nxt[0]))
        toks = jnp.asarray(toks)
    return seq


def main():
    ring = reconfigure(total=8, ring_size=4)
    assert ring.validate_disjoint()
    print(f"[rings] 8-wide model axis -> {ring.n_rings} independent "
          f"4-rings: {ring.groups()}")

    full = jax.make_mesh((1, 8), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    ring_a, ring_b = submeshes(full, ring_size=4)
    print(f"[rings] tenant A devices: {[d.id for d in ring_a.devices.flat]}")
    print(f"[rings] tenant B devices: {[d.id for d in ring_b.devices.flat]}")

    seq_a = serve_on(ring_a, "smollm-135m")
    seq_b = serve_on(ring_b, "granite-moe-3b-a800m")
    print(f"[rings] tenant A (smollm)  decoded: {seq_a}")
    print(f"[rings] tenant B (granite) decoded: {seq_b}")
    print("[rings] two models served concurrently on disjoint sub-rings "
          "— no cross-ring collective possible by construction")


if __name__ == "__main__":
    main()
