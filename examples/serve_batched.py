"""Batched serving with mixed request lengths + continuous batching —
the paper's datacenter scenario (many users, small individual batches),
on the paged KV-cache serving stack: a shared block pool sized at half
the dense worst-case, power-of-two prefill buckets, and the
non-blocking submit/step/drain interface.

    PYTHONPATH=src python examples/serve_batched.py --requests 12
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.compiler.mapper import plan_model  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.serving.config import EngineConfig  # noqa: E402
from repro.serving.engine import LPUEngine  # noqa: E402
from repro.serving.sampler import SamplingParams  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--kv-dtype", default="auto",
                    help="KV pool storage precision (e.g. int8: half "
                         "the pool bytes, scales stored alongside)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))

    max_seq = 96
    # paged pool at ~half the dense capacity: requests share blocks on
    # demand instead of each slot pre-claiming max_seq tokens
    table_len = max_seq // args.block_size
    engine = LPUEngine(model, params, EngineConfig(
        slots=args.slots, max_seq=max_seq, paged=True,
        block_size=args.block_size,
        num_blocks=(args.slots * table_len) // 2 + 1,
        kv_dtype=args.kv_dtype))

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab_size,
                                size=int(rng.randint(2, 14))))
               for _ in range(args.requests)]
    sp = SamplingParams(args.temperature, 20, 0.95)

    # continuous serving: submit everything up-front (a real server would
    # interleave submits with steps), then pump the engine by hand
    rids = [engine.submit(p, max_new_tokens=args.max_new, params=sp)
            for p in prompts]
    outs = {}
    while engine.sched.has_work():
        for req in engine.step():           # finished this round
            outs[req.rid] = req.out
    st = engine.stats
    print(f"[serve_batched] {len(outs)} requests on {args.slots} slots: "
          f"{st.tokens} tokens, {st.tokens_per_s:.1f} tok/s, "
          f"occupancy {st.occupancy:.2f} "
          f"(continuous batching kept slots {st.occupancy:.0%} busy)")
    print(f"[serve_batched] paged kv: "
          f"{engine.kv_cache_bytes() / 1024:.0f} KiB pool vs "
          f"{engine.dense_equiv_bytes() / 1024:.0f} KiB dense, "
          f"{st.prefill_traces} prefill traces for "
          f"{len(set(map(len, prompts)))} distinct prompt lengths, "
          f"{st.preemptions} preemptions")
    for rid in rids[:3]:
        print(f"  req{rid} ({len(prompts[rid])} prompt toks): {outs[rid]}")


if __name__ == "__main__":
    main()
