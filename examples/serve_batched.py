"""Batched serving with mixed request lengths + continuous batching —
the paper's datacenter scenario (many users, small individual batches).

    PYTHONPATH=src python examples/serve_batched.py --requests 12
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.compiler.mapper import plan_model  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.serving.engine import LPUEngine  # noqa: E402
from repro.serving.sampler import SamplingParams  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = LPUEngine(model, params, slots=args.slots, max_seq=96)

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab_size,
                                size=int(rng.randint(2, 14))))
               for _ in range(args.requests)]
    outs = engine.generate(
        prompts, max_new_tokens=args.max_new,
        params=SamplingParams(args.temperature, 20, 0.95))
    st = engine.stats
    print(f"[serve_batched] {len(outs)} requests on {args.slots} slots: "
          f"{st.tokens} tokens, {st.tokens_per_s:.1f} tok/s, "
          f"occupancy {st.occupancy:.2f} "
          f"(continuous batching kept slots {st.occupancy:.0%} busy)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i} ({len(prompts[i])} prompt toks): {o}")


if __name__ == "__main__":
    main()
