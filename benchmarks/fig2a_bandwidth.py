"""Fig. 2a — memory-bandwidth utilization across model sizes.

LPU-model utilization per OPT size vs the paper's published LPU and GPU
utilizations.  The shape of the claim — utilization *rises* with model
size and the LPU dominates the GPU at every size — must reproduce.
"""
from __future__ import annotations

from typing import List

from repro.configs import get_config
from repro.core.latency_model import LPU_ASIC, token_latency

from benchmarks.fig7a_latency import calibrate
from benchmarks.paper_constants import (MEAN_KV, PAPER_BW_UTIL,
                                        PAPER_GPU_BW_UTIL)

SIZES = [("opt-1.3b", 1), ("opt-6.7b", 1), ("opt-30b", 1), ("opt-66b", 2)]


def run() -> List[str]:
    a, b, c, _ = calibrate()
    rows = []
    prev = 0.0
    for name, n in SIZES:
        r = token_latency(get_config(name), n, LPU_ASIC, kv_len=MEAN_KV,
                          vec_a=a, vec_b=b, vec_c=c)
        util = r["bandwidth_util"]
        paper = PAPER_BW_UTIL.get((name, n))
        gpu = PAPER_GPU_BW_UTIL.get((name, n))
        monotone = util >= prev
        prev = util
        rows.append(
            f"fig2a.bw_util.{name},{util*1e6:.0f},"
            f"model={util:.3f};paper_lpu={paper};paper_gpu={gpu};"
            f"rises_with_size={monotone}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
