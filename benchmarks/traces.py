"""Deterministic bursty multi-tenant request traces.

The tail-latency harness needs load that looks like production — bursty
arrivals, several tenants, shared per-tenant system prompts — but
replays bit-identically across processes (CI compares affinity-on vs
-off on the SAME trace, and the hypothesis interleaving suite shrinks
counterexamples).  So everything here is a pure function of
``TraceConfig``: arrivals come from a seeded ``numpy`` generator
(exponential gaps for ``"poisson"``, heavy-tailed Pareto gaps for
``"pareto"`` — the classic burst model: many near-simultaneous
arrivals separated by long lulls), and prompts are drawn from per-tenant
pools that all open with that tenant's fixed system prefix (block-
aligned, so the prefix cache and affinity router have something real
to hit).

No jax, no wall clock, no ``hash()`` — importable by tests, the bench
and CI alike.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class TraceRequest:
    rid: int
    tenant: str
    arrival_s: float              # offset from trace start
    prompt: Tuple[int, ...]
    max_new_tokens: int


@dataclass(frozen=True)
class TraceConfig:
    seed: int = 0
    requests: int = 16
    tenants: int = 3
    arrival: str = "pareto"       # "poisson" | "pareto" (bursty)
    rate_rps: float = 100.0       # mean arrival rate (1 / mean gap)
    pareto_shape: float = 1.5     # tail index; smaller = burstier
    prefix_len: int = 32          # shared per-tenant system prefix
                                  # (block-align to the pool for hits)
    tail_min: int = 4             # per-request unique suffix length
    tail_max: int = 24
    max_new_min: int = 4
    max_new_max: int = 16
    vocab: int = 256              # token id range [1, vocab) — keep
                                  # under the serving model's vocab
                                  # (the reduced test configs use 512;
                                  # out-of-range ids embed to garbage
                                  # and trip the NaN-logits guard)
    tenant_names: Tuple[str, ...] = field(default=())

    def __post_init__(self):
        if self.requests < 1:
            raise ValueError(f"requests={self.requests} must be >= 1")
        if self.tenants < 1:
            raise ValueError(f"tenants={self.tenants} must be >= 1")
        if self.arrival not in ("poisson", "pareto"):
            raise ValueError(f"arrival={self.arrival!r} not in "
                             "('poisson', 'pareto')")
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps={self.rate_rps} must be > 0")
        if self.pareto_shape <= 1.0:
            raise ValueError(
                f"pareto_shape={self.pareto_shape} must be > 1 "
                "(shape <= 1 has no finite mean gap)")
        if not (0 < self.tail_min <= self.tail_max):
            raise ValueError(f"need 0 < tail_min <= tail_max, got "
                             f"({self.tail_min}, {self.tail_max})")
        if not (0 < self.max_new_min <= self.max_new_max):
            raise ValueError(f"need 0 < max_new_min <= max_new_max, got "
                             f"({self.max_new_min}, {self.max_new_max})")
        if self.prefix_len < 0:
            raise ValueError(f"prefix_len={self.prefix_len} must be >= 0")
        if self.vocab < 2:
            raise ValueError(f"vocab={self.vocab} must be >= 2")


def tenant_prefixes(cfg: TraceConfig) -> List[List[int]]:
    """Each tenant's fixed system prefix (deterministic, disjoint by
    construction: drawn from one seeded stream per tenant)."""
    out = []
    for t in range(cfg.tenants):
        rng = np.random.Generator(np.random.PCG64(cfg.seed * 1000003 + t))
        out.append(rng.integers(1, cfg.vocab,
                                size=cfg.prefix_len).tolist())
    return out


def generate_trace(cfg: TraceConfig) -> List[TraceRequest]:
    """The trace: ``cfg.requests`` requests sorted by arrival time.

    Same config -> bit-identical trace, across processes and platforms
    (PCG64 is stable; nothing reads the clock or ``hash()``).
    """
    rng = np.random.Generator(np.random.PCG64(cfg.seed))
    mean_gap = 1.0 / cfg.rate_rps
    if cfg.arrival == "poisson":
        gaps = rng.exponential(mean_gap, size=cfg.requests)
    else:
        # Lomax (Pareto II) gaps scaled to the same mean: xm * (U^(-1/a)
        # - 1) with xm = mean * (a - 1) has mean ``mean_gap`` and a
        # heavy tail — most gaps tiny (a burst), a few huge (the lull)
        a = cfg.pareto_shape
        xm = mean_gap * (a - 1.0)
        gaps = xm * (rng.pareto(a, size=cfg.requests))
    arrivals = np.cumsum(gaps)
    arrivals -= arrivals[0]                      # first request at t=0
    prefixes = tenant_prefixes(cfg)
    names = (cfg.tenant_names if cfg.tenant_names
             else tuple(f"tenant{t}" for t in range(cfg.tenants)))
    if len(names) != cfg.tenants:
        raise ValueError(f"{len(names)} tenant_names for "
                         f"{cfg.tenants} tenants")
    reqs: List[TraceRequest] = []
    for i in range(cfg.requests):
        t = int(rng.integers(0, cfg.tenants))
        tail_n = int(rng.integers(cfg.tail_min, cfg.tail_max + 1))
        tail = rng.integers(1, cfg.vocab, size=tail_n).tolist()
        max_new = int(rng.integers(cfg.max_new_min, cfg.max_new_max + 1))
        reqs.append(TraceRequest(
            rid=i, tenant=names[t], arrival_s=float(arrivals[i]),
            prompt=tuple(prefixes[t] + tail), max_new_tokens=max_new))
    return reqs
