"""Kernel microbenchmarks: arithmetic intensity, VMEM tiles, and
interpret-mode wall time (correctness-path cost only — CPU interpret
timing says nothing about TPU; the roofline terms are the perf claim).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import (decode_attention,
                                                plan_block_s)
from repro.kernels.gemv.ops import gemv, plan_blocks
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def run() -> List[str]:
    rows = []
    rng = jax.random.PRNGKey(0)

    for B, K, N in [(8, 4096, 4096), (8, 7168, 19200 // 16 * 16)]:
        bk, bn = plan_blocks(B, K, N)
        flops = 2 * B * K * N
        bytes_ = K * N * 2 + B * K * 2 + B * N * 2
        ai = flops / bytes_
        ridge = PEAK_FLOPS_BF16 / HBM_BW
        x = jax.random.normal(rng, (B, K), jnp.bfloat16)
        w = jax.random.normal(rng, (K, N), jnp.bfloat16)
        t0 = time.time()
        gemv(x, w).block_until_ready()
        dt = time.time() - t0
        rows.append(
            f"kernel.gemv.B{B}K{K}N{N},{dt*1e6:.0f},"
            f"block=({bk}x{bn});arith_intensity={ai:.2f};"
            f"ridge={ridge:.0f};bound=memory;"
            f"t_hbm_us={bytes_/HBM_BW*1e6:.1f}")

    for B, S, G, gs, dh in [(8, 2048, 1, 4, 128), (4, 4096, 2, 2, 128)]:
        H = G * gs
        q = jax.random.normal(rng, (B, H, dh), jnp.bfloat16)
        k = jax.random.normal(rng, (B, S, G, dh), jnp.bfloat16)
        v = jax.random.normal(rng, (B, S, G, dh), jnp.bfloat16)
        lens = jnp.full((B,), S, jnp.int32)
        bs = plan_block_s(S, dh, gs)
        bytes_ = 2 * B * S * G * dh * 2
        t0 = time.time()
        decode_attention(q, k, v, lens).block_until_ready()
        dt = time.time() - t0
        rows.append(
            f"kernel.decode_attention.B{B}S{S}G{G},{dt*1e6:.0f},"
            f"block_s={bs};kv_bytes={bytes_};"
            f"t_hbm_us={bytes_/HBM_BW*1e6:.1f};bound=memory")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
