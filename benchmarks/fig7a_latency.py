"""Fig. 7a — token-generation latency, OPT family (paper reproduction).

Calibrates the analytic simulator's (a, b, c) vector-overhead terms on
the paper's three published latencies, reports per-point residuals
(6.7B/66B reproduce within ~4%; the 1.3B point is internally
inconsistent with any non-negative model of this family — documented in
EXPERIMENTS.md §Paper-validation), and the held-out 30B utilization.
"""
from __future__ import annotations

from typing import List

from repro.configs import get_config
from repro.core.latency_model import (LPU_ASIC, H100, fit_vector_params,
                                      token_latency)

from benchmarks.paper_constants import (MEAN_KV, PAPER_BW_UTIL,
                                        PAPER_LATENCY,
                                        PAPER_SPEEDUP_VS_GPU)


def calibrate():
    pts = [(get_config(n), d, LPU_ASIC, MEAN_KV, ms)
           for (n, d), ms in PAPER_LATENCY.items()]
    return fit_vector_params(pts)


def run() -> List[str]:
    a, b, c, err = calibrate()
    rows = [f"fig7a.calibration,a_us={a*1e6:.2f};b_ns={b*1e9:.2f};"
            f"c_us={c*1e6:.2f},max_rel_err={err:.3f}"]
    for (name, n), paper_ms in PAPER_LATENCY.items():
        cfg = get_config(name)
        r = token_latency(cfg, n, LPU_ASIC, kv_len=MEAN_KV, vec_a=a,
                          vec_b=b, vec_c=c)
        rel = abs(r["ms_per_token"] - paper_ms) / paper_ms
        rows.append(
            f"fig7a.latency.{name}.n{n},{r['ms_per_token']*1e3:.1f},"
            f"paper_ms={paper_ms};model_ms={r['ms_per_token']:.2f};"
            f"rel_err={rel:.3f};util={r['bandwidth_util']:.3f}")
    # held-out: OPT-30B utilization (paper: 90.2%)
    cfg30 = get_config("opt-30b")
    r30 = token_latency(cfg30, 1, LPU_ASIC, kv_len=MEAN_KV, vec_a=a,
                        vec_b=b, vec_c=c)
    rows.append(
        f"fig7a.heldout.opt-30b.util,{r30['bandwidth_util']*1e6:.0f},"
        f"model={r30['bandwidth_util']:.3f};paper={PAPER_BW_UTIL[('opt-30b', 1)]}"
        f";ms={r30['ms_per_token']:.2f}")
    # GPU comparison factors (paper: 2.09x on 1.3B, 1.37x on 66B)
    for (name, n), factor in PAPER_SPEEDUP_VS_GPU.items():
        cfg = get_config(name)
        lpu = token_latency(cfg, n, LPU_ASIC, kv_len=MEAN_KV, vec_a=a,
                            vec_b=b, vec_c=c)
        # GPU modeled at its published utilization on comparable BW
        from benchmarks.paper_constants import PAPER_GPU_BW_UTIL
        util_gpu = PAPER_GPU_BW_UTIL.get(
            (name, n), PAPER_GPU_BW_UTIL[("opt-66b", 2)])
        from repro.core.latency_model import decode_stream_bytes, \
            kv_stream_bytes
        stream = (decode_stream_bytes(cfg, MEAN_KV) / n
                  + kv_stream_bytes(cfg, MEAN_KV)) / H100.mem_bw
        gpu_ms = stream / util_gpu * 1e3
        ours = gpu_ms / lpu["ms_per_token"]
        rows.append(
            f"fig7a.speedup_vs_gpu.{name},{ours*1e3:.0f},"
            f"model_x={ours:.2f};paper_x={factor}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
