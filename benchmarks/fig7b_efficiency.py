"""Fig. 7b — server energy efficiency (tokens/s/kW).

Orion-cloud (8x FPGA LPUs) vs 2xH100 on OPT-66B, and Orion-edge
(2x FPGA LPUs) vs 2xL4 on OPT-6.7B, using published system powers and
each side's modeled token rate (GPU at its published utilization).
"""
from __future__ import annotations

from typing import List

from repro.configs import get_config
from repro.core.latency_model import (H100, L4, LPU_FPGA,
                                      decode_stream_bytes, kv_stream_bytes,
                                      token_latency)

from benchmarks.fig7a_latency import calibrate
from benchmarks.paper_constants import (MEAN_KV, PAPER_EFFICIENCY_CLOUD,
                                        PAPER_EFFICIENCY_EDGE,
                                        PAPER_GPU_BW_UTIL,
                                        PAPER_H100_SERVER_W,
                                        PAPER_ORION_CLOUD_W)


def _gpu_tokens_per_s(cfg, n, hw, util):
    stream = (decode_stream_bytes(cfg, MEAN_KV) / n
              + kv_stream_bytes(cfg, MEAN_KV)) / hw.mem_bw
    return util / stream


def run() -> List[str]:
    a, b, c, _ = calibrate()
    rows = []
    # cloud: OPT-66B on 8 FPGA LPUs vs 2x H100
    cfg = get_config("opt-66b")
    lpu = token_latency(cfg, 8, LPU_FPGA, kv_len=MEAN_KV, vec_a=a,
                        vec_b=b, vec_c=c)
    lpu_eff = lpu["tokens_per_s"] / (PAPER_ORION_CLOUD_W / 1e3)
    gpu_tps = _gpu_tokens_per_s(cfg, 2, H100,
                                PAPER_GPU_BW_UTIL[("opt-66b", 2)])
    gpu_eff = gpu_tps / (PAPER_H100_SERVER_W / 1e3)
    ratio = lpu_eff / gpu_eff
    rows.append(
        f"fig7b.cloud.opt-66b,{lpu_eff*1e3:.0f},"
        f"lpu_tps_per_kw={lpu_eff:.1f};gpu_tps_per_kw={gpu_eff:.1f};"
        f"model_ratio={ratio:.2f};paper_ratio={PAPER_EFFICIENCY_CLOUD}")
    # edge: OPT-6.7B on 2 FPGA LPUs vs 2x L4
    cfg = get_config("opt-6.7b")
    lpu = token_latency(cfg, 2, LPU_FPGA, kv_len=MEAN_KV, vec_a=a,
                        vec_b=b, vec_c=c)
    edge_w = 2 * 160.0          # Orion-edge chassis (2 cards + host)
    lpu_eff = lpu["tokens_per_s"] / (edge_w / 1e3)
    gpu_tps = _gpu_tokens_per_s(cfg, 2, L4,
                                PAPER_GPU_BW_UTIL[("opt-1.3b", 1)] * 2.2)
    gpu_eff = gpu_tps / (2 * L4.system_power_w + 180) * 1e3
    ratio = lpu_eff / gpu_eff
    rows.append(
        f"fig7b.edge.opt-6.7b,{lpu_eff*1e3:.0f},"
        f"lpu_tps_per_kw={lpu_eff:.1f};gpu_tps_per_kw={gpu_eff:.1f};"
        f"model_ratio={ratio:.2f};paper_ratio={PAPER_EFFICIENCY_EDGE};"
        f"note=edge chassis power split unpublished - ratio sensitive to "
        f"the host-power assumption (cloud point is the calibrated one)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
