# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (us_per_call column carries the benchmark's headline scalar in
# micro-units where noted).
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig2a_bandwidth, fig7a_latency, fig7b_efficiency,
                            fig7c_scaling, kernels_bench, roofline)
    print("name,us_per_call,derived")
    ok = True
    for mod in (fig7a_latency, fig2a_bandwidth, fig7c_scaling,
                fig7b_efficiency, roofline, kernels_bench):
        try:
            for row in mod.run():
                print(row)
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
