"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import SHAPES, assigned_cells
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

from benchmarks.roofline import model_flops_per_device

ARCHS = ["whisper-tiny", "qwen1.5-4b", "deepseek-coder-33b", "minicpm-2b",
         "smollm-135m", "llava-next-34b", "granite-moe-3b-a800m",
         "llama4-maverick-400b-a17b", "jamba-v0.1-52b", "rwkv6-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d: Path):
    rows = {}
    for p in d.glob("*.json"):
        r = json.loads(p.read_text())
        if r.get("status") != "ok" or r.get("tag"):
            continue
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def dryrun_table(rows) -> str:
    out = ["| arch | shape | 16x16 | 2x16x16 | compile s (1pod) | "
           "peak GiB/dev | plan notes |",
           "|---|---|---|---|---|---|---|"]
    run, skip = assigned_cells()
    skipset = {(a, s) for a, s in skip}
    for a in ARCHS:
        for s in SHAPE_ORDER:
            if (a, s) in skipset:
                out.append(f"| {a} | {s} | SKIP | SKIP | — | — | "
                           f"full-attention arch: 512k dense KV excluded "
                           f"(DESIGN.md §5) |")
                continue
            r1 = rows.get((a, s, "16x16"))
            r2 = rows.get((a, s, "2x16x16"))
            if not r1:
                out.append(f"| {a} | {s} | MISSING | — | — | — | |")
                continue
            p = r1["plan"]
            notes = (f"kvs={p.get('kv_shards','-')} dup={p.get('dup','-')}"
                     + (f" ep={p.get('ep')}x{p.get('ffn_split')}"
                        if p.get("ep") else ""))
            out.append(
                f"| {a} | {s} | OK | {'OK' if r2 else 'MISSING'} | "
                f"{r1['t_compile_s']:.0f} | "
                f"{r1['memory']['peak_bytes']/2**30:.1f} | {notes} |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| arch | shape | t_comp | t_mem | t_coll | bound | "
           "useful/HLO flops | collectives |",
           "|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPE_ORDER:
            r = rows.get((a, s, "16x16"))
            if not r:
                continue
            tc = r["flops_per_device"] / PEAK_FLOPS_BF16
            tm = r["bytes_per_device"] / HBM_BW
            tw = r.get("wire_bytes_per_device", 0.0) / ICI_BW
            dom = max((tc, "compute"), (tm, "memory"),
                      (tw, "collective"))[1]
            mf = model_flops_per_device(a, s, 256)
            ratio = mf / max(r["flops_per_device"], 1.0)
            cc = r.get("coll_counts", {})
            ccs = " ".join(f"{k.split('-')[-1]}:{int(v)}"
                           for k, v in sorted(cc.items()))
            def fmt(t):
                return f"{t*1e3:.1f}ms" if t < 10 else f"{t:.1f}s"
            out.append(
                f"| {a} | {s} | {fmt(tc)} | {fmt(tm)} | {fmt(tw)} | "
                f"**{dom}** | {ratio:.2f} | {ccs} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None)
    args = ap.parse_args()
    d = Path(args.dir) if args.dir else \
        Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    rows = load(d)
    print("### §Dry-run matrix\n")
    print(dryrun_table(rows))
    print("\n### §Roofline (single-pod 16x16, per device per step)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
