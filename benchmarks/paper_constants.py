"""Published numbers from the LPU paper (targets for reproduction)."""

# Fig. 7a — simulated LPU latency, ms/token (in=32, out=2016)
PAPER_LATENCY = {
    ("opt-1.3b", 1): 1.25,
    ("opt-6.7b", 1): 4.62,
    ("opt-66b", 2): 22.2,
}

# text: bandwidth utilization
PAPER_BW_UTIL = {
    ("opt-1.3b", 1): 0.633,
    ("opt-30b", 1): 0.902,
    ("opt-66b", 2): 0.906,
}
PAPER_GPU_BW_UTIL = {
    ("opt-1.3b", 1): 0.289,
    ("opt-30b", 1): 0.708,
    ("opt-66b", 2): 0.649,
}

# Fig. 7a — GPU comparison factors
PAPER_SPEEDUP_VS_GPU = {("opt-1.3b", 1): 2.09, ("opt-66b", 2): 1.37}

# Fig. 7c — strong scaling, GPT3-20B
PAPER_LPU_SCALING_8DEV = 5.43
PAPER_LPU_SCALING_PER_DOUBLING = 1.75
PAPER_DGX_SCALING_PER_DOUBLING = 1.38
PAPER_DGX_SCALING_8DEV = 2.65

# Fig. 7b — server efficiency
PAPER_EFFICIENCY_CLOUD = 1.33      # Orion-cloud vs 2xH100, OPT-66B
PAPER_EFFICIENCY_EDGE = 1.32       # Orion-edge vs 2xL4, OPT-6.7B
PAPER_ORION_CLOUD_W = 608.0
PAPER_H100_SERVER_W = 1100.0

# measurement protocol
IN_TOKENS = 32
OUT_TOKENS = 2016
MEAN_KV = IN_TOKENS + OUT_TOKENS // 2
