"""Fig. 7c — strong scaling of token generation, GPT3-20B, 1..8 devices.

ESL (overlapped ring) vs blocking baseline vs the paper's published
DGX A100 reference.  Also quantifies the beyond-paper win of *sharding
the KV cache* across the ring (the LPU replicates it — see
core/latency_model.py docstring).
"""
from __future__ import annotations

from typing import List

from repro.configs import get_config
from repro.core.latency_model import LPU_ASIC, scaling_curve

from benchmarks.fig7a_latency import calibrate
from benchmarks.paper_constants import (MEAN_KV, PAPER_DGX_SCALING_8DEV,
                                        PAPER_LPU_SCALING_8DEV,
                                        PAPER_LPU_SCALING_PER_DOUBLING)


def run() -> List[str]:
    a, b, c, _ = calibrate()
    cfg = get_config("gpt3-20b")
    kw = dict(kv_len=MEAN_KV, vec_a=a, vec_b=b, vec_c=c)
    esl = scaling_curve(cfg, LPU_ASIC, 8, overlap=True, **kw)
    blk = scaling_curve(cfg, LPU_ASIC, 8, overlap=False, **kw)
    esl_kv = scaling_curve(cfg, LPU_ASIC, 8, overlap=True, shard_kv=True,
                           **kw)
    dbl = (esl[-1]) ** (1 / 3)
    rows = [
        f"fig7c.scaling.esl.8dev,{esl[-1]*1e3:.0f},"
        f"curve={[round(x,2) for x in esl]};paper={PAPER_LPU_SCALING_8DEV}",
        f"fig7c.scaling.esl.per_doubling,{dbl*1e3:.0f},"
        f"model_x={dbl:.2f};paper_x={PAPER_LPU_SCALING_PER_DOUBLING}",
        f"fig7c.scaling.blocking.8dev,{blk[-1]*1e3:.0f},"
        f"curve={[round(x,2) for x in blk]};"
        f"dgx_published={PAPER_DGX_SCALING_8DEV}",
        f"fig7c.scaling.esl_shardkv.8dev,{esl_kv[-1]*1e3:.0f},"
        f"curve={[round(x,2) for x in esl_kv]};beyond_paper=kv_sharded",
    ]
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
