"""Serving-stack benchmark: paged KV pool vs. the dense slot cache.

Drives the LPU engine through a mixed-length request trace — dense
(slots, max_seq) cache, paged pool with the **gather** oracle, and paged
pool with the **streamed** Pallas kernel — and reports the serving-level
statistics the paged refactor targets:

* tokens/s, ms/token and slot occupancy (continuous batching health),
* prefill retrace count: with pow2 length buckets the prefill jit traces
  at most log2(max_seq) times, vs. once per distinct prompt length for
  the unbucketed dense baseline,
* KV bytes: pool bytes (scales with resident tokens) vs. the dense
  worst-case allocation, peak block-pool occupancy, and **KV bytes
  moved per decode step** — the streamed kernel reads each resident
  tile once where the gather path reads the pool, writes a contiguous
  copy and reads it back (3x), the copy the paper's no-materialization
  decode stream removes,
* **host-sync accounting (synced vs fused)** — the paper's C1 on-chip
  sampling contrast: the ``paged-stream-synced`` row ships the full
  (slots, vocab) logits row to the host every token (O(vocab)
  bytes-to-host per token, one blocking sync per step), the fused rows
  sample in-jit and read back only int32 token ids (O(slots) bytes per
  token), and ``paged-stream-fused-sN`` additionally runs N decode
  steps per sync through one lax.scan window — host syncs drop ~Nx.
  Each row also records the KV stream tile (``block_s``, overridable
  with ``--block-s``) next to the ``plan_block_s`` recommendation so
  real-hardware sweeps can tune the tile against the planner,
* **decode-stall accounting (standdown vs interleaved)** — the
  tail-latency contrast of chunked prefill: on a trace with a LONG
  prompt landing mid-decode, the ``paged-stream-standdown`` row runs
  monolithic bucketed prefills that freeze every in-flight stream
  (``decode_stalls`` counts those launches) while the
  ``paged-stream-interleaved`` row (``--prefill-chunk`` tokens/step)
  runs one prefill chunk AND one decode window per step —
  ``decode_stalls`` must be zero and the token streams bit-identical,
* **prefix-cache accounting (off vs on)** — a shared-system-prompt
  workload (every request opens with the same prefix) run twice on the
  streamed engine: the ``paged-stream-prefix-on`` row maps the cached
  prefix blocks into each admission (refcounted, copy-on-write) and
  prefills only the tail — ``prefill_tokens_saved``,
  ``prefix_hit_blocks`` and the mean TTFT record the win, the OFF row
  must save nothing, and the token streams must be bit-identical,
* **speculative-decoding accounting (spec-off vs spec-kN)** — a
  repetitive workload (motif-repeat prompts) run twice on the streamed
  engine: the ``paged-stream-spec-kN`` row drafts N tokens per slot
  per round (n-gram drafter), verifies all of them in ONE
  chunk-as-batch pass and accepts a rejection-sampled prefix —
  ``acceptance_rate`` / ``accepted_per_window`` record the win,
  ``decode_steps`` collapses below one round per token, and the token
  streams must be bit-identical to the spec-off run.
* **fault-tolerance accounting (paged-stream-chaos)** — the same trace
  through a 2-ring host fleet with ``--chaos``-style injection (ring
  failure, stalled window, NaN logits, corrupted pool block); the
  smoke gate asserts completed + failed == submitted, every surviving
  greedy stream bit-identical to the chaos-off fleet, and zero leaked
  pool blocks after the rebuilds (docs/serving.md "Fault tolerance").
* **KV-precision accounting (kv-fp16 vs kv-int8)** — the quantized-KV
  tentpole's memory claim: the same trace under the SAME per-rank HBM
  budget, pool stored at fp16 vs int8 + per-(row, kv-head) fp16 absmax
  scales dequantized inside the streamed kernel's tile loop — the
  ``paged-stream-kv-int8`` row must stream <= 0.55x the fp16 KV bytes
  per step and fit >= 1.8x the blocks in the same budget, while its
  greedy streams stay within a documented common-prefix drift bound of
  the fp16 row (``greedy_prefix_agreement``; every row also records
  its ``kv_dtype`` / ``w_dtype`` precision pair).

    PYTHONPATH=src python benchmarks/serving_bench.py --requests 16

Ring mode (``--tp N [--rings R]``) adds the multi-LPU scaling view:
the same trace through the ring-parallel paged engine with ESL overlap
vs. the blocking-collective baseline (the paper's C2 contrast), plus
per-ring tokens/s for an R x (tp=N) sub-ring fleet (C3).  Outputs are
asserted identical to the tp=1 dense engine.  CPU note: fake devices
measure *schedule* differences only — wall-clock speedups need ICI.

    PYTHONPATH=src python benchmarks/serving_bench.py --tp 2 --rings 2

CI smoke (``--smoke``): shrink the trace, validate the result dict
(schema + no NaN/inf) and write it to ``--out`` (BENCH_serving.json) so
the perf-trajectory artifact is produced by CI on every PR.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.fake_devices import ensure_host_devices  # noqa: E402

ensure_host_devices(sys.argv)   # must precede the jax import

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.compiler.mapper import plan_model  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import make_serving_mesh  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.serving.config import EngineConfig  # noqa: E402
from repro.serving.engine import LPUEngine, MultiRingEngine  # noqa: E402
from repro.serving.kv_cache import per_rank_block_bytes  # noqa: E402


def run_engine(model, params, prompts, *, slots, max_seq, max_new,
               paged, block_size=0, num_blocks=0, kv_budget_bytes=0,
               paged_kernel="auto", sampling="fused", steps_per_sync=1,
               block_s=0, prefill_chunk=0, prefix_cache=False,
               speculate="off", draft_k=4, kv_dtype="auto",
               w_dtype="auto"):
    """Run one engine config over the trace.  Returns
    ``(engine, outputs, mean TTFT ms)`` — time-to-first-token is wall
    time from batch submission to each request's first streamed token
    (its prefill completing), the latency prefix caching attacks."""
    econf = EngineConfig(slots=slots, max_seq=max_seq, paged=paged,
                         block_size=block_size, num_blocks=num_blocks,
                         kv_budget_bytes=kv_budget_bytes,
                         paged_kernel=paged_kernel, sampling=sampling,
                         steps_per_sync=steps_per_sync, block_s=block_s,
                         prefill_chunk=prefill_chunk,
                         prefix_cache=prefix_cache, speculate=speculate,
                         draft_k=draft_k, kv_dtype=kv_dtype,
                         w_dtype=w_dtype)
    eng = LPUEngine(model, params, econf)
    t_first = {}
    t0 = time.time()

    def cb(rid, tok):
        t_first.setdefault(rid, time.time())

    outs = eng.generate(prompts, max_new_tokens=max_new, stream_cb=cb)
    assert all(len(o) == max_new for o in outs)
    ttft_ms = 1e3 * sum(t - t0 for t in t_first.values()) \
        / max(len(t_first), 1)
    return eng, outs, ttft_ms


MLIR_DTYPE = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
              "int8": "i8", "float8_e4m3fn": "f8E4M3FN"}


# accuracy floor of the precision rows: mean greedy common-prefix
# fraction vs the drift reference (dense for the fp16 row, fp16 for the
# int8 row).  Empirically the reduced-config trace agrees exactly
# (1.0); the bound leaves room for a late near-tie flip on other
# seeds/shapes without letting real quantization damage through.  The
# methodology is documented in docs/serving.md.
KV_INT8_DRIFT_BOUND = 0.75


def greedy_prefix_agreement(outs, ref_outs) -> float:
    """Mean common-prefix fraction of the greedy token streams.

    The accuracy metric of the quantized-KV rows: 1.0 means every
    stream matches its reference token-for-token; a stream that first
    diverges at token k contributes k/len.  Prefix-wise (not
    positional) because one flipped greedy token reroutes everything
    after it — positional overlap past the split is luck, not fidelity.
    """
    fr = []
    for o, r in zip(outs, ref_outs):
        n = min(len(o), len(r))
        k = 0
        while k < n and o[k] == r[k]:
            k += 1
        fr.append(k / max(n, 1))
    return sum(fr) / max(len(fr), 1)


def view_tensor_count(eng) -> int:
    """MEASURED no-copy check: tensors of the per-request contiguous
    view shape (slots, max_seq, Gp, dh) in the lowered decode program.

    The gather oracle materializes one per K and V per layer; the
    streamed kernel must lower with ZERO — if the streamed path ever
    regresses to gathering, the view shape reappears in its program and
    the bench (and the CI smoke job) fails.  This is the falsifiable
    counterpart of the analytic ``kv_moved_bytes_per_step`` formula.
    Lowered via ``lower_decode_text``, so it inspects the program the
    engine actually dispatches (fused window or host logits step).
    """
    a = eng.plan.attn
    txt = eng.lower_decode_text()
    # the view's element type is the engine's KV STORAGE dtype (a
    # quantized pool's gather regression would materialize i8 views)
    dt = MLIR_DTYPE[jnp.dtype(eng.kv_dtype).name]
    sig = f"tensor<{eng.slots}x{eng.max_seq}x{a.gp}x{a.d_head}x{dt}>"
    return txt.count(sig)


def ring_rows(cfg, prompts, dense_outs, args):
    """tp>1: ESL-overlap vs blocking engines + per-ring fleet rows."""
    tp, rings = args.tp, args.rings
    mesh = make_serving_mesh(tp=tp, rings=1)
    rows = []
    for overlap in (True, False):
        plan = plan_model(cfg, ("model",), (tp,), "serve",
                          esl_overlap=overlap, remat="none",
                          compute_dtype="float32", param_dtype="float32")
        model = build_model(cfg, plan)
        params, _ = model.init(jax.random.PRNGKey(0))
        eng = LPUEngine(model, params,
                        EngineConfig(slots=args.slots,
                                     max_seq=args.max_seq, paged=True,
                                     block_size=args.block_size),
                        mesh=mesh)
        outs = eng.generate(prompts, max_new_tokens=args.max_new)
        st = eng.stats
        rows.append({
            "mode": f"tp{tp}-" + ("esl-overlap" if overlap
                                  else "blocking"),
            "tokens_per_s": round(st.tokens_per_s, 1),
            "occupancy": round(st.occupancy, 3),
            "decode_steps": st.steps,
            "kv_bytes_per_rank": eng.per_rank_kv_bytes(),
            "same_output_as_tp1_dense": outs == dense_outs,
        })
    ring_stats = []
    if rings > 1:
        fleet_mesh = make_serving_mesh(tp=tp, rings=rings)
        plan = plan_model(cfg, ("model",), (tp,), "serve",
                          esl_overlap=True, remat="none",
                          compute_dtype="float32", param_dtype="float32")
        model = build_model(cfg, plan)
        params, _ = model.init(jax.random.PRNGKey(0))
        fleet = MultiRingEngine(
            model, params, fleet_mesh, ring_size=tp,
            config=EngineConfig(slots=args.slots, max_seq=args.max_seq,
                                paged=True, block_size=args.block_size))
        t0 = time.time()
        fleet_outs = fleet.generate(prompts,
                                    max_new_tokens=args.max_new)
        fleet_wall = time.time() - t0
        for i, (eng, st) in enumerate(zip(fleet.engines,
                                          fleet.per_ring_stats())):
            ring_stats.append({
                "ring": i, "requests": fleet.router.routed[i],
                "tokens": st.tokens,
                "tokens_per_s": round(st.tokens_per_s, 1),
                "occupancy": round(st.occupancy, 3),
                "kv_bytes_per_rank": eng.per_rank_kv_bytes(),
            })
        # fleet rate = total decode tokens / fleet wall-clock.  NOT the
        # sum of per-ring rates: this host dispatches the rings
        # sequentially inside each step round (one engine per host in a
        # real deployment), so summing would overstate throughput ~Rx.
        rows.append({
            "mode": f"{rings}x(tp{tp})-fleet",
            "tokens_per_s": round(sum(r["tokens"] for r in ring_stats)
                                  / max(fleet_wall, 1e-9), 1),
            "fleet_wall_s": round(fleet_wall, 2),
            "same_output_as_tp1_dense": fleet_outs == dense_outs,
        })
    assert all(r["same_output_as_tp1_dense"] for r in rows), \
        "ring-parallel output diverged from the tp=1 dense engine"
    return rows, ring_stats


def chaos_section(model, params, prompts, max_new, fleet_kw):
    """Fault-tolerance contrast (docs/serving.md §Fault tolerance): the
    same trace through a 2-ring host fleet twice — chaos off (baseline
    streams) vs chaos on, injecting a ring failure, a stalled window, a
    NaN-logits event and a corrupted pool block mid-run.  The gates are
    the PR's recovery claims: the drain never raises, every request is
    accounted for (completed + failed == submitted), every surviving
    greedy stream is bit-identical to the chaos-off baseline (recovery
    is recompute-resume), and after the rebuilds every engine's pool
    refcounts balance to zero leaks."""
    from repro.serving.engine import MultiRingEngine

    # ring 0 eats an outright failure, NaN logits and a corrupted pool
    # block (three separate recovery cycles — _step_no and the fired-set
    # survive each rebuild, so later events still fire); ring 1 wedges
    # and is drained by the heartbeat timeout (ManualClock: 1 virtual
    # second per fleet round).
    spec = "ring@2,stall@3:1,nan@5,corrupt@8"
    off = EngineConfig(chaos="", heartbeat_timeout_s=4.0, **fleet_kw)
    on = EngineConfig(chaos=spec, heartbeat_timeout_s=4.0, **fleet_kw)
    base = MultiRingEngine(model, params, None, rings=2, config=off)
    base_outs = base.generate(prompts, max_new_tokens=max_new)
    fleet = MultiRingEngine(model, params, None, rings=2, config=on)
    rids = [fleet.submit(list(p), max_new) for p in prompts]
    results = fleet.drain()          # must not raise: that IS the gate
    outs = [results[r] for r in rids]
    survivors = [i for i, r in enumerate(rids) if r not in fleet.failed]
    diverged = sum(1 for i in survivors if outs[i] != base_outs[i])
    for eng in fleet.engines:        # zero leaked blocks post-rebuild
        eng.check_pool_balanced()
    fc = fleet.fleet_counters()
    sec = {
        "mode": "paged-stream-chaos",
        "chaos_spec": spec,
        "submitted": len(rids),
        "completed": len(survivors),
        "failed": fc["failed_requests"],
        "ring_failures": fc["ring_failures"],
        "migrated_requests": fc["migrated_requests"],
        "retries": fc["retries"],
        "rejected_requests": fc["rejected_requests"],
        "events": fc["events"],
        "survivor_stream_divergence": diverged,
        "leaked_blocks": 0,          # check_pool_balanced passed above
    }
    assert sec["completed"] + sec["failed"] == sec["submitted"], \
        (sec, "chaos run lost requests: completed + failed != submitted")
    assert all(len(outs[i]) == max_new for i in survivors), \
        "a surviving request's stream is short"
    assert diverged == 0, \
        (diverged, "surviving streams diverged from the chaos-off "
         "baseline: recovery is not bit-exact")
    assert sec["ring_failures"] >= 1 and sec["retries"] >= 1, \
        (sec, "chaos spec injected faults but no recovery cycle ran")
    for req in fleet.failed.values():
        assert req.failed and req.error, \
            "failed request lacks structured status"
    return sec


def tail_latency_section(cfg, model, params, args, tracker_path):
    """Tail-latency harness (the async front-end's latency claims): ONE
    deterministic bursty multi-tenant trace (benchmarks/traces.py —
    Pareto gaps, two tenants sharing block-aligned system prefixes)
    replayed through :class:`AsyncFrontend` four times, reporting
    p50/p99 TTFT and ms/token per row:

    * ``tail-affinity-off`` vs ``tail-affinity-on`` — a mesh-free
      2-ring fleet with prefix caching; OFF routes least-loaded, ON
      routes each request to the ring whose PrefixCache owns the
      deepest prefix of its prompt.  Gates: the token streams are
      bit-identical (routing never changes greedy content) and the ON
      run's fleet-wide prefix hit rate >= the OFF run's — same trace,
      same engines, the only variable is the router.
    * ``tail-budget-off`` vs ``tail-budget-on`` — a single
      chunked-prefill engine; ON re-plans ``prefill_chunk`` /
      ``steps_per_sync`` every pump tick from the step-time EWMA
      (seeded by the analytic ``step_time_prior``).  Gate: bit-identical
      streams — SLO retuning changes WHEN tokens reconcile, never WHICH
      tokens — while the planner demonstrably ran (plans recorded,
      windows observed).

    Every run's telemetry (per-window EngineStats deltas + per-request
    TTFT/ms-per-token records) streams through one schema-validating
    :class:`JsonlTracker` artifact at ``tracker_path`` — the file CI's
    tail-latency-smoke leg uploads — plus a per-run ring buffer that
    feeds the percentiles.  ``read_jsonl`` re-validates the artifact
    and every run's admission ledger must balance:
    ``completed + failed + cancelled == submitted``.
    """
    import asyncio

    import traces as tr
    from repro.core.latency_model import LPU_FPGA, step_time_prior
    from repro.serving.budget import BudgetScheduler
    from repro.serving.frontend import AsyncFrontend, serve_trace
    from repro.serving.tracker import (JsonlTracker, RingBufferTracker,
                                       read_jsonl)

    class _SectionSink:
        """Fan log() to the shared artifact + a per-run buffer, but
        swallow finish(): each frontend's stop() flushes its tracker,
        and the jsonl artifact must outlive all four runs."""

        def __init__(self, *sinks):
            self.sinks = sinks

        def log(self, rec):
            for s in self.sinks:
                s.log(rec)

        def finish(self):
            pass

    tcfg = tr.TraceConfig(seed=7, requests=args.requests, tenants=2,
                          arrival="pareto", rate_rps=200.0,
                          prefix_len=2 * args.block_size, tail_max=12,
                          max_new_min=4, max_new_max=args.max_new)
    trace = tr.generate_trace(tcfg)
    table_len = args.max_seq // args.block_size
    pool = args.slots * table_len + 1      # dense-equivalent + null
    jsonl = JsonlTracker(tracker_path)

    def pct(vals, q):
        return (round(float(np.percentile(np.asarray(vals, np.float64),
                                          q)), 3) if vals else -1.0)

    def run(mode, target, budget=None):
        sink = RingBufferTracker(65536)
        fleet = isinstance(target, MultiRingEngine)

        async def go():
            async with AsyncFrontend(target, budget=budget,
                                     tracker=_SectionSink(jsonl,
                                                          sink)) as fe:
                streams = await serve_trace(fe, trace)
            return fe, streams

        fe, streams = asyncio.run(go())
        outs = [s.tokens for s in streams]
        c = fe.counters
        assert c["completed"] + c["failed"] + c["cancelled"] \
            == c["submitted"] == len(trace), \
            (mode, c, "tail run lost requests: ledger unbalanced")
        for eng in fe.engines:
            eng.check_pool_balanced()       # zero leaked blocks
        reqs = [r for r in sink.records() if r["kind"] == "request"]
        ttft = [r["ttft_ms"] for r in reqs if r["ttft_ms"] >= 0]
        mpt = [r["ms_per_token"] for r in reqs if r["tokens"] >= 2]
        hits = sum(e.stats.prefix_hits for e in fe.engines)
        looks = sum(e.stats.prefix_lookups for e in fe.engines)
        row = {
            "mode": mode,
            "completed": c["completed"], "failed": c["failed"],
            "cancelled": c["cancelled"], "rejected": c["rejected"],
            "ttft_ms_p50": pct(ttft, 50), "ttft_ms_p99": pct(ttft, 99),
            "ms_per_token_p50": pct(mpt, 50),
            "ms_per_token_p99": pct(mpt, 99),
            "prefix_hits": hits, "prefix_lookups": looks,
            "prefix_hit_rate": round(hits / max(looks, 1), 3),
            "affinity_routed": (sum(target.router.affinity_routed)
                                if fleet else 0),
            "window_records": sum(1 for r in sink.records()
                                  if r["kind"] == "engine_window"),
            "request_records": len(reqs),
        }
        return row, outs

    # -- affinity contrast: 2-ring prefix-cache fleet, routing only ----
    fleet_kw = dict(slots=args.slots, max_seq=args.max_seq, paged=True,
                    block_size=args.block_size, num_blocks=pool,
                    prefix_cache=True)
    aff_off_row, aff_off_outs = run(
        "tail-affinity-off",
        MultiRingEngine(model, params, None, rings=2,
                        config=EngineConfig(affinity="least_loaded",
                                            **fleet_kw)))
    aff_on_row, aff_on_outs = run(
        "tail-affinity-on",
        MultiRingEngine(model, params, None, rings=2,
                        config=EngineConfig(affinity="prefix",
                                            **fleet_kw)))
    assert aff_on_outs == aff_off_outs, \
        "affinity routing changed greedy token streams"
    assert aff_on_row["prefix_hit_rate"] >= \
        aff_off_row["prefix_hit_rate"], \
        (aff_on_row["prefix_hit_rate"], aff_off_row["prefix_hit_rate"],
         "prefix-affinity routing must not LOWER the fleet hit rate "
         "on the shared-tenant trace")
    assert aff_on_row["affinity_routed"] > 0, \
        "affinity-on run never routed by prefix ownership"

    # -- budget contrast: single chunked engine, SLO retuning only -----
    budget_ms = 5.0
    prior = step_time_prior(cfg, 1, LPU_FPGA, kv_len=args.max_seq)
    eng_kw = dict(slots=args.slots, max_seq=args.max_seq, paged=True,
                  block_size=args.block_size, num_blocks=pool,
                  prefill_chunk=args.prefill_chunk)
    bud_off_row, bud_off_outs = run(
        "tail-budget-off", LPUEngine(model, params,
                                     EngineConfig(**eng_kw)))
    bud = BudgetScheduler(budget_ms, prior_step_s=prior,
                          max_chunk=args.max_seq)
    bud_on_row, bud_on_outs = run(
        "tail-budget-on",
        LPUEngine(model, params, EngineConfig(**eng_kw)), budget=bud)
    assert bud_on_outs == bud_off_outs, \
        "budget scheduling changed greedy token streams"
    assert bud.planned and bud.observed_windows > 0, \
        (len(bud.planned), bud.observed_windows,
         "budget-on run never planned or never observed a window")

    jsonl.finish()
    recs = read_jsonl(tracker_path)         # re-validates every record
    assert len(recs) == jsonl.written, \
        (len(recs), jsonl.written, "tracker artifact lost records")
    rows = [aff_off_row, aff_on_row, bud_off_row, bud_on_row]
    assert sum(r["request_records"] for r in rows) == 4 * len(trace), \
        "tracker is missing per-request records"
    return {
        "trace": {"seed": tcfg.seed, "requests": tcfg.requests,
                  "tenants": tcfg.tenants, "arrival": tcfg.arrival,
                  "rate_rps": tcfg.rate_rps,
                  "prefix_len": tcfg.prefix_len},
        "rows": rows,
        "same_output_affinity": aff_on_outs == aff_off_outs,
        "same_output_budget": bud_on_outs == bud_off_outs,
        "budget_ms": budget_ms,
        "budget_prior_step_ms": round(prior * 1e3, 4),
        "budget_planned": len(bud.planned),
        "budget_observed_windows": bud.observed_windows,
        "tracker_path": str(tracker_path),
        "tracker_records": len(recs),
        "ledger_balanced": True,            # asserted per run above
    }


TAIL_ROW_KEYS = {"mode", "completed", "failed", "cancelled", "rejected",
                 "ttft_ms_p50", "ttft_ms_p99", "ms_per_token_p50",
                 "ms_per_token_p99", "prefix_hits", "prefix_lookups",
                 "prefix_hit_rate", "affinity_routed", "window_records",
                 "request_records"}

TAIL_MODES = ("tail-affinity-off", "tail-affinity-on",
              "tail-budget-off", "tail-budget-on")


def validate_tail(sec: dict) -> None:
    """Schema + NaN/inf gate for the tail-latency section (CI uploads
    it inside BENCH_serving.json / BENCH_tail_latency.json)."""
    for key in ("trace", "rows", "same_output_affinity",
                "same_output_budget", "budget_ms", "tracker_path",
                "tracker_records", "ledger_balanced"):
        if key not in sec:
            raise ValueError(f"TAIL schema: missing key {key!r}")
    modes = [r.get("mode") for r in sec["rows"]]
    for want in TAIL_MODES:
        if want not in modes:
            raise ValueError(f"TAIL schema: missing row {want!r}")
    for row in sec["rows"]:
        missing = TAIL_ROW_KEYS - set(row)
        if missing:
            raise ValueError(
                f"TAIL schema: row {row.get('mode')!r} missing {missing}")
        # the smoke gate: every percentile is a real measurement
        for k in ("ttft_ms_p50", "ttft_ms_p99", "ms_per_token_p50",
                  "ms_per_token_p99"):
            v = row[k]
            if not (isinstance(v, (int, float)) and math.isfinite(v)):
                raise ValueError(
                    f"TAIL schema: {row['mode']}.{k}={v!r} not finite")
        if row["ttft_ms_p99"] < 0:
            raise ValueError(
                f"TAIL schema: {row['mode']} has no TTFT samples")
    if sec["tracker_records"] < 1:
        raise ValueError("TAIL schema: empty tracker artifact")
    _walk_finite(sec, "$tail")


def _walk_finite(x, path):
    if isinstance(x, dict):
        for k, v in x.items():
            _walk_finite(v, f"{path}.{k}")
    elif isinstance(x, (list, tuple)):
        for i, v in enumerate(x):
            _walk_finite(v, f"{path}[{i}]")
    elif isinstance(x, float) and not math.isfinite(x):
        raise ValueError(f"BENCH schema: non-finite value at {path}")


def print_tail(sec: dict) -> None:
    tcfg = sec["trace"]
    print(f"[serving_bench] tail latency: {tcfg['requests']} requests, "
          f"{tcfg['tenants']} tenants, {tcfg['arrival']} arrivals "
          f"@{tcfg['rate_rps']:.0f} rps (seed {tcfg['seed']})")
    for r in sec["rows"]:
        print(f"  {r['mode']:>18}: ttft p50/p99 "
              f"{r['ttft_ms_p50']:8.1f}/{r['ttft_ms_p99']:8.1f} ms  "
              f"ms/tok p50/p99 {r['ms_per_token_p50']:6.2f}/"
              f"{r['ms_per_token_p99']:6.2f}  "
              f"hit_rate {r['prefix_hit_rate']:.2f} "
              f"(affinity_routed {r['affinity_routed']})  "
              f"{r['completed']}/{r['completed'] + r['failed'] + r['cancelled']} ok")
    print(f"  streams identical: affinity={sec['same_output_affinity']} "
          f"budget={sec['same_output_budget']}  "
          f"budget plans {sec['budget_planned']} "
          f"(observed {sec['budget_observed_windows']} windows, "
          f"prior {sec['budget_prior_step_ms']:.3f} ms/step)  "
          f"tracker {sec['tracker_records']} records -> "
          f"{sec['tracker_path']}")


REQUIRED_ROW_KEYS = {"mode", "tokens_per_s", "ms_per_token", "occupancy",
                     "decode_steps", "prefills", "prefill_traces",
                     "preemptions", "kv_bytes", "kv_dense_equiv_bytes",
                     "kv_moved_bytes_per_step", "view_tensors_in_program",
                     "sampling", "steps_per_sync", "host_syncs",
                     "prefill_syncs", "syncs_per_token",
                     "bytes_to_host_per_token", "overrun_tokens",
                     "block_s", "planned_block_s",
                     "prefill_chunk", "prefill_chunks", "decode_stalls",
                     "prefix_cache", "prefix_hit_rate",
                     "prefix_hit_blocks", "prefill_tokens_saved",
                     "evicted_blocks", "cow_blocks", "speculate",
                     "draft_k", "spec_rounds", "draft_tokens",
                     "accepted_tokens", "acceptance_rate",
                     "accepted_per_window", "ttft_ms_mean",
                     "kv_dtype", "w_dtype", "greedy_prefix_agreement"}


def validate_bench(out: dict) -> None:
    """Schema + NaN/inf gate for the CI perf-trajectory artifact."""
    for key in ("requests", "distinct_prompt_lengths",
                "bucket_trace_bound_log2", "rows", "same_output",
                "chaos", "tail_latency"):
        if key not in out:
            raise ValueError(f"BENCH schema: missing top-level key {key!r}")
    validate_tail(out["tail_latency"])
    if out["chaos"].get("mode") != "paged-stream-chaos":
        raise ValueError("BENCH schema: chaos section must carry mode "
                         "'paged-stream-chaos'")
    for key in ("submitted", "completed", "failed", "ring_failures",
                "survivor_stream_divergence", "leaked_blocks"):
        if key not in out["chaos"]:
            raise ValueError(f"BENCH schema: chaos section missing {key!r}")
    if not out["rows"]:
        raise ValueError("BENCH schema: empty rows")
    modes = {r["mode"] for r in out["rows"]}
    for want in ("dense", "paged-gather", "paged-stream",
                 "paged-stream-synced", "paged-stream-standdown",
                 "paged-stream-interleaved", "paged-stream-prefix-off",
                 "paged-stream-prefix-on", "paged-stream-spec-off",
                 "paged-stream-kv-fp16", "paged-stream-kv-int8"):
        if want not in modes:
            raise ValueError(f"BENCH schema: missing row {want!r}")
    if not any(m.startswith("paged-stream-fused-s") for m in modes):
        raise ValueError("BENCH schema: missing multi-step fused row "
                         "(paged-stream-fused-sN)")
    if not any(m.startswith("paged-stream-spec-k") for m in modes):
        raise ValueError("BENCH schema: missing speculative row "
                         "(paged-stream-spec-kN)")
    for row in out["rows"]:
        missing = REQUIRED_ROW_KEYS - set(row)
        if missing:
            raise ValueError(
                f"BENCH schema: row {row.get('mode')!r} missing {missing}")

    _walk_finite(out, "$")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged pool size (0 = half the dense capacity)")
    ap.add_argument("--tp", type=int, default=1,
                    help="ESL ring width (adds the ring scaling rows)")
    ap.add_argument("--rings", type=int, default=1,
                    help="sub-ring fleet size (per-ring tokens/s rows)")
    ap.add_argument("--steps-per-sync", type=int, default=4,
                    help="window size of the multi-step fused row")
    ap.add_argument("--block-s", type=int, default=0,
                    help="KV stream tile override (0 = planned default; "
                         "recorded per row for hardware tuning sweeps)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunk size of the interleaved-prefill row "
                         "(paged-stream-interleaved)")
    ap.add_argument("--prefix-cache", default="off",
                    choices=("on", "off"),
                    help="enable prefix caching on the MAIN mixed-trace "
                         "paged rows (the shared-system-prompt contrast "
                         "pair always runs both off and on)")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: validate the result schema and "
                         "write it to --out")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="result file written in --smoke mode")
    ap.add_argument("--tail-only", action="store_true",
                    help="run ONLY the tail-latency section (bursty "
                         "trace through the async frontend: affinity "
                         "and budget off/on rows) — the CI "
                         "tail-latency-smoke leg")
    ap.add_argument("--tracker-out", default="TRACKER_serving.jsonl",
                    help="jsonl telemetry artifact written by the "
                         "tail-latency section (schema-validated, "
                         "uploaded by CI)")
    args = ap.parse_args()
    if args.prefill_chunk < 1:
        ap.error("--prefill-chunk must be >= 1: the interleaved row "
                 "exists to contrast chunked admission with the "
                 "monolithic standdown row")
    # the multi-step row's window size (>= 2 so the contrast exists)
    S = max(args.steps_per_sync, 2)
    if args.smoke:
        args.requests = min(args.requests, 6)
        args.slots = min(args.slots, 2)
        # >= 2 full S-step windows per request, so the ~Sx host-sync
        # reduction is observable on the smoke trace
        args.max_new = min(args.max_new, 2 * S)
        args.max_seq = min(args.max_seq, 64)

    cfg = get_config(args.arch).reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))

    if args.tail_only:
        tail = tail_latency_section(cfg, model, params, args,
                                    args.tracker_out)
        out = {"requests": args.requests, "tail_latency": tail}
        validate_tail(tail)
        if args.json:
            print(json.dumps(out, indent=2))
        else:
            print_tail(tail)
        if args.smoke:
            Path(args.out).write_text(json.dumps(out, indent=2),
                                      encoding="utf-8")
            print(f"[serving_bench] tail smoke OK -> {args.out} "
                  f"(+ {tail['tracker_records']} tracker records -> "
                  f"{tail['tracker_path']})")
        return out

    # mixed-length trace: many distinct prompt lengths (the dense
    # engine's worst case for prefill retracing)
    rng = np.random.RandomState(0)
    lengths = rng.randint(2, min(48, args.max_seq - args.max_new - 2),
                          size=args.requests)
    prompts = [list(rng.randint(1, cfg.vocab_size, size=int(n)))
               for n in lengths]
    distinct_lengths = len(set(int(n) for n in lengths))

    prefix_on = args.prefix_cache == "on"
    dense, dense_outs, dense_ttft = run_engine(
        model, params, prompts, slots=args.slots, max_seq=args.max_seq,
        max_new=args.max_new, paged=False, block_s=args.block_s)
    # every row's token streams are asserted against a reference trace
    # run — dense for the shared-trace rows, the monolithic standdown
    # run for the interleave pair (which adds a long prompt), the
    # prefix-off run for the shared-system-prompt pair
    engines = [("dense", dense, dense_outs, dense_outs, dense_ttft)]
    # paged pool sized at half the dense capacity: enough for the trace's
    # resident tokens, impossible for a dense allocator.  Same pool, two
    # dataflows: the gather oracle (contiguous per-request copy each
    # step) vs. the streamed Pallas kernel (tiles straight off the pool).
    table_len = args.max_seq // args.block_size
    num_blocks = args.num_blocks or \
        (args.slots * table_len) // 2 + 1
    paged_kw = dict(slots=args.slots, max_seq=args.max_seq,
                    max_new=args.max_new, paged=True,
                    block_size=args.block_size, num_blocks=num_blocks)
    # the streamed kernel's tile is structurally the pool block size, so
    # a --block-s override only reaches the gather/dense flash chunk
    stream_bs = args.block_s if args.block_s == args.block_size else 0
    for kern, bs in (("gather", args.block_s), ("stream", stream_bs)):
        eng, outs, ttft = run_engine(model, params, prompts,
                                     paged_kernel=kern, block_s=bs,
                                     prefix_cache=prefix_on, **paged_kw)
        engines.append((f"paged-{kern}", eng, outs, dense_outs, ttft))
    # the synced-vs-fused contrast (paper C1 on-chip sampling): same
    # streamed pool, three host-loop disciplines — full logits row to
    # host per token, fused 1-step (token ids only), fused multi-step
    # (steps_per_sync tokens per readback)
    eng, outs, ttft = run_engine(model, params, prompts,
                                 paged_kernel="stream", sampling="host",
                                 block_s=stream_bs, **paged_kw)
    engines.append(("paged-stream-synced", eng, outs, dense_outs, ttft))
    # multi-step windows reserve their whole lookahead up front and
    # NEVER preempt for it, so at the half-capacity pool above the
    # engine would (correctly) degrade to single-step under pressure —
    # the S-step row gets the dense-equivalent pool to show the
    # headroom-funded win (pool fields record the difference)
    msd_kw = dict(paged_kw, num_blocks=args.slots * table_len + 1)
    eng, outs, ttft = run_engine(model, params, prompts,
                                 paged_kernel="stream", sampling="fused",
                                 steps_per_sync=S, block_s=stream_bs,
                                 **msd_kw)
    engines.append((f"paged-stream-fused-s{S}", eng, outs, dense_outs,
                    ttft))
    # the interleave contrast (streamlined-dataflow latency claim): the
    # SAME streamed engine, monolithic vs chunked admission, on the
    # trace plus ONE LONG prompt that lands while short streams are
    # mid-decode.  Monolithic ("standdown") freezes every in-flight
    # stream for each full bucketed prefill (decode_stalls counts
    # them); chunked ("interleaved", --prefill-chunk tokens/step) runs
    # a prefill chunk AND a decode window per step, so decode_stalls
    # must be ZERO while the token streams stay bit-identical.  Both
    # get the dense-equivalent pool so the contrast is purely the
    # admission policy, not preemption noise.
    long_len = args.max_seq - args.max_new - 2
    il_prompts = prompts + [list(rng.randint(1, cfg.vocab_size,
                                             size=long_len))]
    sd_eng, sd_outs, sd_ttft = run_engine(model, params, il_prompts,
                                          paged_kernel="stream",
                                          block_s=stream_bs, **msd_kw)
    engines.append(("paged-stream-standdown", sd_eng, sd_outs, sd_outs,
                    sd_ttft))
    eng, outs, ttft = run_engine(model, params, il_prompts,
                                 paged_kernel="stream", block_s=stream_bs,
                                 prefill_chunk=args.prefill_chunk,
                                 **msd_kw)
    engines.append(("paged-stream-interleaved", eng, outs, sd_outs, ttft))
    # the prefix-caching contrast (this PR's latency claim): a
    # shared-system-prompt workload — every request opens with the SAME
    # sys_len-token prefix (the datacenter shape prefix caching exists
    # for) plus a unique tail.  Same streamed engine, same
    # dense-equivalent pool, prefix cache off vs on: the ON run
    # prefills the shared prefix ONCE, every later admission maps the
    # cached blocks (refcounted, copy-on-write) into its table and
    # prefills only the tail — prefill_tokens_saved / prefix_hit_blocks
    # count the win, TTFT shows it, and the token streams must stay
    # bit-identical.
    sys_len = 3 * args.block_size
    sp_rng = np.random.RandomState(11)
    sys_prompt = list(sp_rng.randint(1, cfg.vocab_size, size=sys_len))
    tail_max = max(args.max_seq - args.max_new - sys_len - 1, 2)
    sp_prompts = [sys_prompt + list(sp_rng.randint(
        1, cfg.vocab_size, size=int(sp_rng.randint(1, min(tail_max, 8)))))
        for _ in range(args.requests)]
    px_off, px_off_outs, px_off_ttft = run_engine(
        model, params, sp_prompts, paged_kernel="stream",
        block_s=stream_bs, **msd_kw)
    engines.append(("paged-stream-prefix-off", px_off, px_off_outs,
                    px_off_outs, px_off_ttft))
    px_on, px_on_outs, px_on_ttft = run_engine(
        model, params, sp_prompts, paged_kernel="stream",
        block_s=stream_bs, prefix_cache=True, **msd_kw)
    engines.append(("paged-stream-prefix-on", px_on, px_on_outs,
                    px_off_outs, px_on_ttft))
    # the speculative-decoding contrast (this PR's latency claim): a
    # REPETITIVE workload — each prompt is a 4-token motif repeated, the
    # shape (boilerplate, code, tables) speculation exists for — so the
    # n-gram drafter's suffix match predicts the cyclic greedy
    # continuation.  Same streamed engine, same dense-equivalent pool,
    # speculation off vs on: the ON run drafts ``sp_k`` tokens per slot
    # per round, verifies all of them in ONE chunk-as-batch pass, and
    # must emit BIT-IDENTICAL streams (rejection sampling is exact; ref
    # is the OFF run) while accepting >1 draft per verify window — each
    # accepted token is a decode round the engine never ran, which is
    # why the ON row's decode_steps collapses.  rng seed 1 is picked
    # (like the trace seeds above) for robust greedy top-2 margins.
    sp_k = 4
    rep_rng = np.random.RandomState(1)
    rep_new = max(min(24, args.max_seq - 26), 4)
    rep_prompts = []
    for _ in range(args.requests):
        motif = list(rep_rng.randint(1, cfg.vocab_size, size=4))
        rep_prompts.append(motif * 6)
    spec_kw = dict(msd_kw, max_new=rep_new)
    spec_off, spec_off_outs, spec_off_ttft = run_engine(
        model, params, rep_prompts, paged_kernel="stream",
        block_s=stream_bs, **spec_kw)
    engines.append(("paged-stream-spec-off", spec_off, spec_off_outs,
                    spec_off_outs, spec_off_ttft))
    spec_on, spec_on_outs, spec_on_ttft = run_engine(
        model, params, rep_prompts, paged_kernel="stream",
        block_s=stream_bs, speculate="ngram", draft_k=sp_k, **spec_kw)
    engines.append((f"paged-stream-spec-k{sp_k}", spec_on, spec_on_outs,
                    spec_off_outs, spec_on_ttft))
    # the KV-precision contrast (this PR's tentpole memory claim): the
    # SAME mixed trace under the SAME per-rank HBM budget, pool stored
    # at fp16 vs int8 + per-(row, kv-head) fp16 absmax scales.  The
    # budget is denominated in fp16 block units (dense-equivalent
    # working set + 4 blocks slack) so the fp16 row fits the trace;
    # the int8 row's smaller blocks (d_head + 2 scale bytes per
    # row-head vs 2*d_head) pack ~1.9x as many blocks into the SAME
    # bytes and the streamed kernel reads 34/64 = 0.53x the bytes per
    # step — the capacity and bandwidth halves of the claim, gated
    # below.  Accuracy is gated prefix-wise, not bit-exact: fp
    # narrowing (fp16 vs the f32 plan dtype) and int8 rounding may
    # legitimately flip a late greedy near-tie, so each precision row
    # self-references same_output (its own determinism) and reports
    # greedy_prefix_agreement against its drift reference — dense for
    # the fp16 row, the fp16 row for the int8 row (the bound is
    # documented in docs/serving.md).
    a = plan.attn
    fp16_block_bytes = per_rank_block_bytes(
        cfg.n_layers, a.kv_per_rank, a.d_head, args.block_size, 2)
    kv_budget = fp16_block_bytes * (args.slots * table_len + 4)
    kv_kw = dict(paged_kw, num_blocks=0, kv_budget_bytes=kv_budget)
    kvf, kvf_outs, kvf_ttft = run_engine(
        model, params, prompts, paged_kernel="stream", block_s=stream_bs,
        kv_dtype="float16", **kv_kw)
    engines.append(("paged-stream-kv-fp16", kvf, kvf_outs, kvf_outs,
                    kvf_ttft, dense_outs))
    kvq, kvq_outs, kvq_ttft = run_engine(
        model, params, prompts, paged_kernel="stream", block_s=stream_bs,
        kv_dtype="int8", **kv_kw)
    engines.append(("paged-stream-kv-int8", kvq, kvq_outs, kvq_outs,
                    kvq_ttft, kvf_outs))

    bucket_bound = int(math.log2(args.max_seq)) + 1
    rows = []
    for name, eng, outs, ref_outs, ttft, *rest in engines:
        # optional 6th element: the drift reference the prefix-agreement
        # metric compares against (the bit-exact ref otherwise)
        drift_ref = rest[0] if rest else ref_outs
        st = eng.stats
        rows.append({
            "mode": name,
            "tokens_per_s": round(st.tokens_per_s, 1),
            "ms_per_token": round(1e3 * st.wall / max(st.tokens, 1), 3),
            "occupancy": round(st.occupancy, 3),
            "decode_steps": st.steps,
            "prefills": st.prefills,
            "prefill_traces": st.prefill_traces,
            "preemptions": st.preemptions,
            "kv_bytes": eng.kv_cache_bytes(),
            "kv_dense_equiv_bytes": eng.dense_equiv_bytes(),
            "kv_moved_bytes_per_step": eng.kv_bytes_moved_per_step(),
            "pool_peak_blocks": st.peak_pool_blocks,
            "pool_blocks": (eng.num_blocks - 1 if eng.paged else 0),
            "same_output_as_dense": outs == ref_outs,
            # measured from the lowered program, not the formula
            "view_tensors_in_program": (view_tensor_count(eng)
                                        if eng.paged else None),
            "sampling": eng.sampling,
            "steps_per_sync": eng.steps_per_sync,
            "host_syncs": st.host_syncs,
            "prefill_syncs": st.prefill_syncs,
            "syncs_per_token": round(st.syncs_per_token, 4),
            "bytes_to_host_per_token": round(st.bytes_to_host_per_token,
                                             1),
            "overrun_tokens": st.overrun_tokens,
            "block_s": eng.decode_block_s(),
            "planned_block_s": eng.planned_block_s(),
            "prefill_chunk": eng.prefill_chunk,
            "prefill_chunks": st.prefill_chunks,
            "decode_stalls": st.decode_stalls,
            "prefix_cache": eng.prefix_cache,
            "prefix_hit_rate": round(st.prefix_hit_rate, 3),
            "prefix_hit_blocks": st.prefix_hit_blocks,
            "prefill_tokens_saved": st.prefill_tokens_saved,
            "evicted_blocks": st.evicted_blocks,
            "cow_blocks": st.cow_blocks,
            "speculate": eng.speculate,
            "draft_k": (eng.draft_k if eng.speculate != "off" else 0),
            "spec_rounds": st.spec_rounds,
            "draft_tokens": st.draft_tokens,
            "accepted_tokens": st.accepted_tokens,
            "acceptance_rate": round(st.acceptance_rate, 3),
            "accepted_per_window": round(st.accepted_per_window, 2),
            "ttft_ms_mean": round(ttft, 2),
            "kv_dtype": eng.kv_dtype,
            "w_dtype": eng.w_dtype,
            "greedy_prefix_agreement": round(
                greedy_prefix_agreement(outs, drift_ref), 4),
        })
    scaling_rows, ring_stats = [], []
    if args.tp > 1:
        scaling_rows, ring_stats = ring_rows(cfg, prompts, dense_outs,
                                             args)
    # fault-tolerance contrast: a 2-ring host fleet under injected
    # chaos, gated on full request accounting, bit-exact survivors and
    # zero leaked blocks (dense-equivalent pool: migration is already
    # recompute, pool pressure would only add preemption noise)
    chaos = chaos_section(
        model, params, prompts, args.max_new,
        dict(slots=args.slots, max_seq=args.max_seq, paged=True,
             block_size=args.block_size,
             num_blocks=args.slots * table_len + 1))
    # tail-latency section: the async front end under the bursty trace
    # (affinity + budget contrasts, percentile latencies, jsonl
    # telemetry artifact) — self-gating, see tail_latency_section
    tail = tail_latency_section(cfg, model, params, args,
                                args.tracker_out)

    out = {
        "requests": args.requests,
        "distinct_prompt_lengths": distinct_lengths,
        "bucket_trace_bound_log2": bucket_bound,
        "rows": rows,
        "scaling_rows": scaling_rows,
        "per_ring": ring_stats,
        "chaos": chaos,
        "tail_latency": tail,
        "same_output": all(r["same_output_as_dense"] for r in rows),
    }
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        print(f"[serving_bench] {args.requests} requests "
              f"({distinct_lengths} distinct prompt lengths), "
              f"slots={args.slots}, max_seq={args.max_seq}")
        for r in rows:
            occ_pool = (f"  pool {r['pool_peak_blocks']}/{r['pool_blocks']}"
                        if r["pool_blocks"] else "")
            print(f"  {r['mode']:>22}: {r['tokens_per_s']:8.1f} tok/s  "
                  f"{r['ms_per_token']:7.2f} ms/tok  "
                  f"occ {r['occupancy']:.2f}  "
                  f"traces {r['prefill_traces']}  "
                  f"preempt {r['preemptions']}  "
                  f"kv {r['kv_bytes']/1024:.0f} KiB "
                  f"(moved/step {r['kv_moved_bytes_per_step']/1024:.0f} "
                  f"KiB, view tensors "
                  f"{r['view_tensors_in_program']}){occ_pool}")
            print(f"  {'':>22}  syncs {r['host_syncs']} "
                  f"({r['syncs_per_token']:.2f}/tok)  "
                  f"B->host/tok {r['bytes_to_host_per_token']:.1f}  "
                  f"overrun {r['overrun_tokens']}  "
                  f"[{r['sampling']}, S={r['steps_per_sync']}, "
                  f"block_s {r['block_s']} "
                  f"(planned {r['planned_block_s']})]")
            print(f"  {'':>22}  prefill_chunk {r['prefill_chunk']}  "
                  f"chunks {r['prefill_chunks']}  "
                  f"decode_stalls {r['decode_stalls']}  "
                  f"prefix[{'on' if r['prefix_cache'] else 'off'}] "
                  f"hit_rate {r['prefix_hit_rate']:.2f} "
                  f"saved {r['prefill_tokens_saved']} "
                  f"cow {r['cow_blocks']} evict {r['evicted_blocks']}  "
                  f"ttft {r['ttft_ms_mean']:.1f} ms")
            print(f"  {'':>22}  spec[{r['speculate']}] "
                  f"k={r['draft_k']} rounds {r['spec_rounds']}  "
                  f"accepted {r['accepted_tokens']}/{r['draft_tokens']} "
                  f"(rate {r['acceptance_rate']:.2f}, "
                  f"{r['accepted_per_window']:.2f}/window)  "
                  f"kv[{r['kv_dtype']}/w:{r['w_dtype']}] "
                  f"agree {r['greedy_prefix_agreement']:.2f}")
        print(f"  bucketed prefill traces <= log2(max_seq)+1 = "
              f"{bucket_bound} (vs {distinct_lengths} distinct lengths); "
              f"outputs identical: {out['same_output']}")
        print(f"  {chaos['mode']:>22}: chaos={chaos['chaos_spec']}  "
              f"{chaos['completed']}/{chaos['submitted']} completed "
              f"({chaos['failed']} failed)  "
              f"ring_failures {chaos['ring_failures']}  "
              f"migrated {chaos['migrated_requests']}  "
              f"retries {chaos['retries']}  "
              f"diverged {chaos['survivor_stream_divergence']}  "
              f"leaked {chaos['leaked_blocks']}")
        for r in scaling_rows:
            extra = "" if "occupancy" not in r else \
                (f"  occ {r['occupancy']:.2f}  "
                 f"kv/rank {r['kv_bytes_per_rank']/1024:.0f} KiB")
            print(f"  {r['mode']:>16}: {r['tokens_per_s']:8.1f} tok/s"
                  f"{extra}  parity={r['same_output_as_tp1_dense']}")
        for r in ring_stats:
            print(f"    ring{r['ring']}: {r['requests']} reqs  "
                  f"{r['tokens']} tokens  {r['tokens_per_s']:8.1f} tok/s  "
                  f"occ {r['occupancy']:.2f}  "
                  f"kv/rank {r['kv_bytes_per_rank']/1024:.0f} KiB")
        print_tail(tail)
    # with prefix caching on the main rows, cache-hit tails run through
    # the chunk program's pow2 buckets — a second O(log2) trace family
    trace_bound = bucket_bound * (2 if prefix_on else 1)
    assert rows[1]["prefill_traces"] <= trace_bound, \
        "bucketed prefill exceeded the log2(max_seq) trace bound"
    assert out["same_output"], "paged output diverged from dense"
    by_mode = {r["mode"]: r for r in rows}
    assert by_mode["paged-stream"]["kv_moved_bytes_per_step"] < \
        by_mode["paged-gather"]["kv_moved_bytes_per_step"], \
        "streamed kernel must move strictly fewer KV bytes than gather"
    # the MEASURED gate: the streamed decode program must contain zero
    # per-request contiguous view tensors while the gather oracle
    # materializes them (2 per attention layer)
    assert by_mode["paged-stream"]["view_tensors_in_program"] == 0, \
        "streamed decode program materialized a per-request KV view"
    assert by_mode["paged-gather"]["view_tensors_in_program"] > 0, \
        "gather oracle no longer materializes the view (shape drift? " \
        "update view_tensor_count)"
    # host-sync gates (paper C1): fused sampling must NOT ship the
    # logits row — device->host payload per token is a small O(slots)
    # constant (int32 ids + window slack), never O(vocab); the synced
    # baseline pays at least the full fp32 row per token.  Multi-step
    # dispatch must amortize the per-token sync ~Sx (compared on decode
    # syncs; prefill syncs are one per request in every mode).
    fused1 = by_mode["paged-stream"]
    synced = by_mode["paged-stream-synced"]
    fusedN = by_mode[f"paged-stream-fused-s{S}"]
    small = 16 * args.slots + 32
    for r in (fused1, fusedN):
        assert r["bytes_to_host_per_token"] <= small, \
            (r["mode"], r["bytes_to_host_per_token"],
             "fused bytes/token must exclude the logits row")
    assert synced["bytes_to_host_per_token"] >= 4 * cfg.vocab_size, \
        (synced["bytes_to_host_per_token"],
         "synced baseline should pay >= one fp32 logits row per token")
    dec_syncs_1 = fused1["host_syncs"] - fused1["prefill_syncs"]
    dec_syncs_n = fusedN["host_syncs"] - fusedN["prefill_syncs"]
    # a request decoding ~2S tokens needs >= 2 windows, so the best
    # finite-trace ratio is (2S-1)/2 — gate at S/2 to leave headroom
    # for resume rounds while still scaling with the window size
    assert dec_syncs_n * S <= dec_syncs_1 * 2, \
        (dec_syncs_n, dec_syncs_1,
         f"steps_per_sync={S} should cut decode host syncs ~{S}x "
         "(>= S/2 required)")
    # interleave gates (streamlined-dataflow latency claim): chunked
    # admission must dispatch decode windows on EVERY step — zero
    # full-prefill decode stalls even with a long prompt landing
    # mid-decode — while the monolithic standdown run stalls its
    # in-flight streams once per prefill launched; token streams must
    # be bit-identical between the two admission policies.
    sd = by_mode["paged-stream-standdown"]
    il = by_mode["paged-stream-interleaved"]
    assert il["same_output_as_dense"], \
        "chunked prefill diverged from monolithic on the same trace"
    assert il["decode_stalls"] == 0, \
        (il["decode_stalls"],
         "interleaved admission must never stall decode on a prefill")
    assert il["prefill_chunks"] > 0, "interleaved row ran no chunks"
    if args.slots > 1:
        # with a single slot every monolithic admission happens into an
        # idle engine (nothing in flight to stall), so the >=1 gate
        # only holds once streams can decode while another admits
        assert sd["decode_stalls"] >= 1, \
            (sd["decode_stalls"],
             "standdown baseline should stall decode at least once "
             "(long prompt admitted mid-decode)")
    # prefix-cache gates: on the shared-system-prompt workload the ON
    # run must map cached blocks and skip their prefill tokens; the OFF
    # run must save nothing; the token streams must be bit-identical
    # (same_output_as_dense compares the pair — ref is the OFF run).
    px_off_r = by_mode["paged-stream-prefix-off"]
    px_on_r = by_mode["paged-stream-prefix-on"]
    assert px_on_r["same_output_as_dense"], \
        "prefix-cache hit streams diverged from cold-start streams"
    assert px_on_r["prefill_tokens_saved"] > 0, \
        (px_on_r["prefill_tokens_saved"],
         "shared-system-prompt workload must save prefill tokens")
    assert px_on_r["prefix_hit_blocks"] > 0, \
        "shared-system-prompt workload must map cached blocks"
    assert px_off_r["prefill_tokens_saved"] == 0 \
        and px_off_r["prefix_hit_blocks"] == 0, \
        "prefix-cache off must save nothing"
    # speculative gates (draft-and-verify latency claim): the ON run's
    # greedy streams are BIT-IDENTICAL to the OFF run's (rejection
    # sampling's correctness contract — same_output_as_dense compares
    # the pair, ref is the OFF run), the repetitive workload accepts
    # more than one draft per verify window, and every accepted token
    # is a decode round the engine never dispatched.  The 0.5
    # acceptance-rate bar is gated on the CI smoke dims, where the
    # seeded workload's margin is widest (~0.8).
    sp_off_r = by_mode["paged-stream-spec-off"]
    sp_on_r = by_mode[f"paged-stream-spec-k{sp_k}"]
    assert sp_on_r["same_output_as_dense"], \
        "speculative streams diverged from the non-speculative engine"
    assert sp_on_r["acceptance_rate"] > 0, \
        "n-gram drafter accepted nothing on the repetitive workload"
    assert sp_on_r["accepted_per_window"] > 1.0, \
        (sp_on_r["accepted_per_window"],
         "repetitive workload should accept >1 draft per verify window")
    assert sp_on_r["decode_steps"] < sp_off_r["decode_steps"], \
        (sp_on_r["decode_steps"], sp_off_r["decode_steps"],
         "accepted drafts should cut decode rounds below 1/token")
    if args.smoke:
        assert sp_on_r["acceptance_rate"] > 0.5, \
            (sp_on_r["acceptance_rate"],
             "smoke repetitive workload should accept >half the drafts")
    assert sp_off_r["draft_tokens"] == 0 \
        and sp_off_r["accepted_tokens"] == 0 \
        and sp_off_r["spec_rounds"] == 0, \
        "speculation off must draft nothing"
    # quantized-KV gates (tentpole): under the SAME per-rank budget the
    # int8 pool must (a) stream <= 0.55x the fp16 bytes per decode step
    # (analytic: (d_head + 2 scale bytes) / (2 * d_head) = 0.531 at
    # d_head=32 — fp32 scales would land at 0.5625 and FAIL, which is
    # why the scale side-arrays are fp16), (b) pack >= 1.8x the blocks
    # (34/64 block bytes -> 1.88x), (c) still lower with ZERO gathered
    # view tensors (the dequant happens inside the streamed kernel's
    # tile loop, not via a materialized fp copy), and (d) keep the
    # greedy streams within the documented drift bound of the fp16 row.
    kf = by_mode["paged-stream-kv-fp16"]
    kq = by_mode["paged-stream-kv-int8"]
    assert kq["kv_moved_bytes_per_step"] <= \
        0.55 * kf["kv_moved_bytes_per_step"], \
        (kq["kv_moved_bytes_per_step"], kf["kv_moved_bytes_per_step"],
         "int8 KV must stream <= 0.55x the fp16 bytes per step")
    assert kq["pool_blocks"] >= 1.8 * kf["pool_blocks"], \
        (kq["pool_blocks"], kf["pool_blocks"],
         "int8 pool must fit >= 1.8x the fp16 blocks in the same budget")
    for r in (kf, kq):
        assert r["kv_bytes"] <= kv_budget, \
            (r["mode"], r["kv_bytes"], kv_budget,
             "precision row's pool (data + scales) exceeded its budget")
        assert r["view_tensors_in_program"] == 0, \
            (r["mode"], "precision row regressed to a gathered KV view")
    assert kq["greedy_prefix_agreement"] >= KV_INT8_DRIFT_BOUND, \
        (kq["greedy_prefix_agreement"],
         f"int8 greedy drift exceeded the {KV_INT8_DRIFT_BOUND} "
         "common-prefix bound vs the fp16 row")
    assert kf["greedy_prefix_agreement"] >= KV_INT8_DRIFT_BOUND, \
        (kf["greedy_prefix_agreement"],
         "fp16 row drifted from dense beyond the documented bound")
    if args.smoke:
        validate_bench(out)
        Path(args.out).write_text(json.dumps(out, indent=2),
                                  encoding="utf-8")
        print(f"[serving_bench] smoke OK -> {args.out}")
    return out


if __name__ == "__main__":
    main()
