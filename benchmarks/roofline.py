"""§Roofline — three-term roofline per (arch x shape) from dry-run artifacts.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun), computes
    t_compute    = flops/dev   / peak
    t_memory     = bytes/dev   / hbm_bw
    t_collective = wire/dev    / link_bw
identifies the dominant term, and reports MODEL_FLOPS / HLO_FLOPS (how
much compiled compute is useful — catching padding/remat/duplication
waste).  Single-pod (16x16) rows only, per the assignment.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, n_chips: int,
                           tp: int = 16) -> float:
    """Useful model flops per device for this cell (6ND / 2ND rule)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        total = 6.0 * cfg.active_params() * shape.tokens
    elif shape.kind == "prefill":
        total = 2.0 * cfg.active_params() * shape.tokens
    else:  # decode: one token per sequence
        total = 2.0 * cfg.active_params() * shape.global_batch
    # model-parallel work divides across tp; batch across the rest
    return total / n_chips


def load_rows(mesh: str = "16x16", tag: str = "") -> List[Dict]:
    rows = []
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        if tag and r.get("tag") != tag:
            continue
        if not tag and r.get("tag"):
            continue
        if not r.get("esl_overlap", True):
            continue
        rows.append(r)
    return rows


def roofline_row(r: Dict) -> Dict:
    n_chips = 1
    for s in r["mesh"].split("x"):
        n_chips *= int(s)
    t_c = r["flops_per_device"] / PEAK_FLOPS_BF16
    t_m = r["bytes_per_device"] / HBM_BW
    t_w = r.get("wire_bytes_per_device", 0.0) / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_w}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(r["arch"], r["shape"], n_chips)
    return {
        "arch": r["arch"], "shape": r["shape"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_w,
        "bottleneck": dom, "bound_s": terms[dom],
        "model_flops": mf,
        "useful_ratio": mf / max(r["flops_per_device"], 1.0),
        "roofline_frac": max(t_c, t_m, t_w) and
        terms[dom] and min(1.0, (t_c if dom == "compute" else
                                 t_m if dom == "memory" else t_w)
                           / sum(terms.values())),
        "peak_gib": r["memory"]["peak_bytes"] / 2 ** 30,
    }


def run() -> List[str]:
    rows = []
    for r in load_rows():
        rl = roofline_row(r)
        rows.append(
            f"roofline.{rl['arch']}.{rl['shape']},{rl['bound_s']*1e6:.0f},"
            f"bottleneck={rl['bottleneck']};"
            f"t_comp_ms={rl['t_compute_s']*1e3:.2f};"
            f"t_mem_ms={rl['t_memory_s']*1e3:.2f};"
            f"t_coll_ms={rl['t_collective_s']*1e3:.2f};"
            f"useful_flops_ratio={rl['useful_ratio']:.3f};"
            f"peak_GiB={rl['peak_gib']:.1f}")
    if not rows:
        rows.append("roofline.none,0,run repro.launch.dryrun --all first")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
