#!/usr/bin/env python
"""Markdown link checker (no deps, no network).

Walks the given markdown files/dirs, extracts inline links and checks
that every *relative* target resolves to an existing file (external
http(s) links and bare in-page anchors are skipped — CI has no network).
Also verifies the `file:line` anchors used by docs/ARCHITECTURE.md:
the file part must exist and the line number must be within the file.

CHANGES.md and ISSUE.md are checked by default (and by the docs CI job)
so stale `file:line` references in the PR log rot loudly instead of
silently.

    python tools/check_links.py README.md docs/ CHANGES.md ISSUE.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FILE_LINE = re.compile(r"`((?:src|tests|benchmarks|examples|tools|docs)"
                       r"/[\w/.-]+\.(?:py|md|yml)):(\d+)`")


def md_files(args):
    for a in args:
        p = Path(a)
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        else:
            yield p


def check(root: Path, files) -> int:
    bad = 0
    for f in files:
        text = f.read_text(encoding="utf-8")
        for m in LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            dest = (f.parent / rel).resolve()
            if not dest.exists():
                print(f"{f}: broken link -> {target}")
                bad += 1
        for m in FILE_LINE.finditer(text):
            path, line = root / m.group(1), int(m.group(2))
            if not path.exists():
                print(f"{f}: anchor file missing -> {m.group(0)}")
                bad += 1
                continue
            n = len(path.read_text(encoding="utf-8").splitlines())
            if line > n:
                print(f"{f}: anchor past EOF ({n} lines) -> {m.group(0)}")
                bad += 1
    return bad


def main() -> int:
    args = sys.argv[1:] or ["README.md", "docs", "CHANGES.md", "ISSUE.md"]
    root = Path.cwd()
    files = list(md_files(args))
    bad = check(root, files)
    print(f"[check_links] {len(files)} files, "
          f"{'OK' if not bad else f'{bad} broken'}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
