"""Config registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ArchConfig, MambaConfig, MoEConfig,
                                RWKVConfig, ShapeSpec, SHAPES)

from repro.configs import (deepseek_coder_33b, granite_moe_3b, jamba_v01_52b,
                           llama4_maverick, llava_next_34b, minicpm_2b, opt,
                           qwen15_4b, rwkv6_7b, smollm_135m, whisper_tiny)

# the ten assigned architectures (grading matrix rows)
ASSIGNED: Dict[str, ArchConfig] = {
    c.name: c for c in [
        whisper_tiny.CONFIG,
        qwen15_4b.CONFIG,
        deepseek_coder_33b.CONFIG,
        minicpm_2b.CONFIG,
        smollm_135m.CONFIG,
        llava_next_34b.CONFIG,
        granite_moe_3b.CONFIG,
        llama4_maverick.CONFIG,
        jamba_v01_52b.CONFIG,
        rwkv6_7b.CONFIG,
    ]
}

# the paper's own evaluation models
PAPER_MODELS: Dict[str, ArchConfig] = {
    c.name: c for c in [opt.OPT_1_3B, opt.OPT_6_7B, opt.OPT_30B,
                        opt.OPT_66B, opt.GPT3_20B]
}

REGISTRY: Dict[str, ArchConfig] = {**ASSIGNED, **PAPER_MODELS}

# short aliases accepted on the CLI
ALIASES = {
    "whisper-tiny": "whisper-tiny",
    "qwen": "qwen1.5-4b",
    "deepseek": "deepseek-coder-33b",
    "minicpm": "minicpm-2b",
    "smollm": "smollm-135m",
    "llava": "llava-next-34b",
    "granite": "granite-moe-3b-a800m",
    "llama4": "llama4-maverick-400b-a17b",
    "jamba": "jamba-v0.1-52b",
    "rwkv6": "rwkv6-7b",
    "opt-1.3b": "opt-1.3b",
    "opt-6.7b": "opt-6.7b",
    "opt-30b": "opt-30b",
    "opt-66b": "opt-66b",
    "gpt3-20b": "gpt3-20b",
}


def get_config(name: str) -> ArchConfig:
    name = ALIASES.get(name, name)
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def assigned_cells() -> List[tuple]:
    """All runnable (arch, shape) dry-run cells + the recorded skips."""
    run, skip = [], []
    for cfg in ASSIGNED.values():
        for s in SHAPES.values():
            (run if cfg.supports_shape(s.name) else skip).append(
                (cfg.name, s.name))
    return run, skip


__all__ = [
    "ArchConfig", "MoEConfig", "MambaConfig", "RWKVConfig", "ShapeSpec",
    "SHAPES", "ASSIGNED", "PAPER_MODELS", "REGISTRY", "get_config",
    "get_shape", "assigned_cells",
]
