"""The paper's own evaluation models: OPT 1.3B/6.7B/30B/66B + GPT3-20B.

These drive the paper-reproduction benchmarks (Fig. 2a bandwidth, Fig. 7a
latency, Fig. 7b efficiency, Fig. 7c scalability).  Dims from Zhang et al.,
"OPT: Open Pre-trained Transformer Language Models" (arXiv:2205.01068);
GPT3-20B matches the NVIDIA FasterTransformer benchmark model.
"""
from repro.configs.base import ArchConfig, FULL_ATTN_SKIPS


def _opt(name, n_layers, d_model, n_heads, d_ff):
    return ArchConfig(
        name=name,
        family="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads,
        d_ff=d_ff,
        vocab_size=50_272,
        qkv_bias=True,
        mlp_gated=False,
        activation="relu",
        norm="layernorm",
        positional="learned",
        tie_embeddings=True,        # OPT ties input/output embeddings
        max_seq=2048,
        shape_skips=FULL_ATTN_SKIPS,
        source="arXiv:2205.01068; hf",
    )


OPT_1_3B = _opt("opt-1.3b", 24, 2048, 32, 8192)
OPT_6_7B = _opt("opt-6.7b", 32, 4096, 32, 16_384)
OPT_30B = _opt("opt-30b", 48, 7168, 56, 28_672)
OPT_66B = _opt("opt-66b", 64, 9216, 72, 36_864)

GPT3_20B = ArchConfig(
    name="gpt3-20b",
    family="dense",
    n_layers=44,
    d_model=6144,
    n_heads=48,
    n_kv_heads=48,
    d_ff=24_576,
    vocab_size=51_200,
    qkv_bias=True,
    mlp_gated=False,
    activation="gelu",
    norm="layernorm",
    positional="learned",
    max_seq=2048,
    shape_skips=FULL_ATTN_SKIPS,
    source="NVIDIA FasterTransformer GPT benchmark; unverified",
)
