"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 -- QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ArchConfig, FULL_ATTN_SKIPS

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151_936,
    qkv_bias=True,
    mlp_gated=True,
    activation="silu",
    norm="rmsnorm",
    positional="rope",
    rope_theta=1_000_000.0,
    shape_skips=FULL_ATTN_SKIPS,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
