"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192, vocab=202048, MoE 128 experts top-1 + shared expert, early
fusion.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig, MoEConfig, FULL_ATTN_SKIPS

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    mlp_gated=True,
    activation="silu",
    norm="rmsnorm",
    positional="rope",
    rope_theta=500_000.0,
    # interleaved expert layers (every other layer is MoE), as published for
    # Maverick -- this also lands the total at ~400B as the model id states.
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, moe_every=2,
                  n_shared_experts=1),
    shape_skips=FULL_ATTN_SKIPS,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
