"""Architecture + shape configuration system.

Every selectable architecture (``--arch <id>``) is described by an
:class:`ArchConfig`.  The config is *logical*: it records the published
model dimensions exactly.  The HyperDex-analog mapper
(:mod:`repro.compiler.mapper`) derives the *physical* (padded, sharded)
configuration from it for a given mesh.

Shapes (``--shape <id>``) are the assigned (seq_len, global_batch, kind)
cells.  ``kind`` decides which program is lowered:

* ``train``   -> ``train_step``   (fwd + bwd + optimizer update)
* ``prefill`` -> ``prefill_step`` (summarization stage, KV-cache build)
* ``decode``  -> ``serve_step``   (generation stage: 1 new token against a
  KV cache of ``seq_len`` — the LPU's target regime)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


# --------------------------------------------------------------------------
# Shapes
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------
# Architecture config
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # 1 => every layer is MoE; 2 => every other layer (jamba), etc.
    moe_every: int = 1
    n_shared_experts: int = 0
    router_jitter: float = 0.0
    capacity_factor: float = 2.0


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256
    # in a hybrid stack: one attention layer per `attn_every` layers
    # (jamba: 1:7 => attn_every=8, attention at layer index `attn_offset`)
    attn_every: int = 8
    attn_offset: int = 4


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64   # low-rank dim of the data-dependent decay (w) path
    mix_lora: int = 32     # low-rank dim of token-shift mixing lerps
    gate_lora: int = 64


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 4
    enc_seq: int = 1500      # whisper: 30 s of audio -> 1500 frames
    enc_causal: bool = False


@dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 2880    # anyres: base 576 + 4 tiles * 576
    patch_embed_dim: int = 1024  # raw vision-tower output fed to projector


@dataclass(frozen=True)
class ArchConfig:
    """Logical (published) architecture description."""

    name: str
    family: str                 # dense | moe | hybrid | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # derived if 0
    qkv_bias: bool = False
    mlp_gated: bool = True      # SwiGLU-style (llama family) vs plain 2-mat
    activation: str = "silu"    # silu | gelu | relu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    positional: str = "rope"    # rope | learned | none (rwkv)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    max_seq: int = 32_768
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # which assigned shapes this arch supports (full-attention archs skip
    # long_500k; encoder-only archs would skip decode -- none assigned here)
    shape_skips: Tuple[str, ...] = ()
    source: str = ""

    # ---- derived ---------------------------------------------------------

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads > 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def group_size(self) -> int:
        """GQA group size (#query heads sharing one KV head)."""
        if self.n_kv_heads == 0:
            return 1
        return max(1, self.n_heads // self.n_kv_heads)

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return layer_idx % self.moe.moe_every == (self.moe.moe_every - 1)

    def is_attention_layer(self, layer_idx: int) -> bool:
        """Hybrid stacks (jamba) interleave attention among mamba layers."""
        if self.family != "hybrid" or self.mamba is None:
            return not self.attention_free
        m = self.mamba
        return layer_idx % m.attn_every == m.attn_offset

    # ---- parameter counting (used by roofline + latency model) -----------

    def attn_params(self) -> int:
        if self.n_heads == 0:
            return 0
        q = self.d_model * self.n_heads * self.d_head
        kv = 2 * self.d_model * self.n_kv_heads * self.d_head
        o = self.n_heads * self.d_head * self.d_model
        b = (self.n_heads + 2 * self.n_kv_heads) * self.d_head if self.qkv_bias else 0
        return q + kv + o + b

    def mlp_params(self, d_ff: Optional[int] = None) -> int:
        dff = self.d_ff if d_ff is None else d_ff
        n_mat = 3 if self.mlp_gated else 2
        return n_mat * self.d_model * dff

    def mamba_params(self) -> int:
        if self.mamba is None:
            return 0
        m = self.mamba
        d_in = m.expand * self.d_model
        in_proj = self.d_model * 2 * d_in
        conv = d_in * m.d_conv
        x_proj = d_in * (m.dt_rank + 2 * m.d_state)
        dt_proj = m.dt_rank * d_in
        a_d = d_in * m.d_state + d_in
        out_proj = d_in * self.d_model
        return in_proj + conv + x_proj + dt_proj + a_d + out_proj

    def rwkv_params(self) -> int:
        if self.rwkv is None:
            return 0
        r = self.rwkv
        # time-mix: r,k,v,g,o square mats + low-rank decay/mix paths
        tm = 5 * self.d_model * self.d_model
        tm += 2 * self.d_model * r.decay_lora          # w lora
        tm += 5 * 2 * self.d_model * r.mix_lora        # token-shift loras
        # channel-mix: two mats (d_model x d_ff) + (d_ff x d_model)
        cm = 2 * self.d_model * self.d_ff
        return tm + cm

    def layer_params(self, layer_idx: int) -> int:
        """Parameters of decoder layer `layer_idx` (norms excluded, ~0)."""
        if self.family == "rwkv":
            return self.rwkv_params()
        if self.family == "hybrid":
            core = (self.attn_params() if self.is_attention_layer(layer_idx)
                    else self.mamba_params())
        else:
            core = self.attn_params()
        if self.is_moe_layer(layer_idx):
            moe = self.moe
            router = self.d_model * moe.n_experts
            experts = moe.n_experts * self.mlp_params(moe.d_ff_expert)
            shared = moe.n_shared_experts * self.mlp_params(moe.d_ff_expert)
            return core + router + experts + shared
        return core + self.mlp_params()

    def active_layer_params(self, layer_idx: int) -> int:
        """Per-token *activated* parameters (MoE: top_k experts only)."""
        if self.family == "rwkv":
            return self.rwkv_params()
        if self.family == "hybrid":
            core = (self.attn_params() if self.is_attention_layer(layer_idx)
                    else self.mamba_params())
        else:
            core = self.attn_params()
        if self.is_moe_layer(layer_idx):
            moe = self.moe
            router = self.d_model * moe.n_experts
            act = (moe.top_k + moe.n_shared_experts) * self.mlp_params(moe.d_ff_expert)
            return core + router + act
        return core + self.mlp_params()

    def embed_params(self) -> int:
        pos = self.max_seq * self.d_model if self.positional == "learned" else 0
        n = self.vocab_size * self.d_model + pos
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        return n

    def encoder_params(self) -> int:
        if self.encdec is None:
            return 0
        per = self.attn_params() + self.mlp_params()
        # decoder cross-attention adds one more attention block per dec layer
        cross = self.n_layers * self.attn_params()
        return self.encdec.n_enc_layers * per + cross

    def total_params(self) -> int:
        body = sum(self.layer_params(i) for i in range(self.n_layers))
        return body + self.embed_params() + self.encoder_params()

    def active_params(self) -> int:
        body = sum(self.active_layer_params(i) for i in range(self.n_layers))
        return body + self.embed_params() + self.encoder_params()

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes appended per generated token (all layers)."""
        if self.attention_free:
            return 0
        n_attn = sum(1 for i in range(self.n_layers) if self.is_attention_layer(i))
        return n_attn * 2 * self.n_kv_heads * self.d_head * dtype_bytes

    def supports_shape(self, shape_name: str) -> bool:
        return shape_name not in self.shape_skips

    # ---- smoke-test reduction --------------------------------------------

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes: dict = dict(
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 8),
            d_model=128,
            d_ff=256,
            vocab_size=512,
            max_seq=128,
            d_head=32,
        )
        if self.family == "rwkv":
            # heads = d_model / head_dim must hold at any tp
            changes["n_heads"] = changes["d_model"] // 32
            changes["n_kv_heads"] = 0
            changes["d_head"] = 32
        elif self.n_heads > 0:
            # preserve the GQA *ratio* so the mapper path is exercised
            g = max(1, self.group_size)
            changes["n_kv_heads"] = max(1, min(self.n_kv_heads, 2))
            changes["n_heads"] = changes["n_kv_heads"] * g
            changes["d_head"] = 128 // max(changes["n_heads"], 4) * 2 or 16
            changes["d_head"] = max(16, min(32, changes["d_head"]))
        if self.moe is not None:
            # capacity 8x: smoke tests assert exact train/decode parity,
            # so the reduced config must never drop a token
            changes["moe"] = replace(
                self.moe, n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2), d_ff_expert=128,
                capacity_factor=8.0)
        if self.mamba is not None:
            changes["mamba"] = replace(
                self.mamba, d_state=8, dt_rank=16,
                attn_every=4, attn_offset=2)
            changes["n_layers"] = 8
        if self.rwkv is not None:
            changes["rwkv"] = replace(
                self.rwkv, head_dim=32, decay_lora=16, mix_lora=8,
                gate_lora=16)
            changes["n_layers"] = 2
        if self.encdec is not None:
            changes["encdec"] = replace(self.encdec, n_enc_layers=2, enc_seq=16)
        if self.vlm is not None:
            changes["vlm"] = replace(self.vlm, n_patches=8, patch_embed_dim=64)
        return replace(self, name=self.name + "-reduced", **changes)


# shapes skipped by pure full-attention archs (quadratic 512k decode)
FULL_ATTN_SKIPS = ("long_500k",)
