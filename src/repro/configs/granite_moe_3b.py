"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512,
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig, FULL_ATTN_SKIPS

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    mlp_gated=True,
    activation="silu",
    norm="rmsnorm",
    positional="rope",
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, moe_every=1),
    shape_skips=FULL_ATTN_SKIPS,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
