"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336,
vocab=65536, MoE 16 experts top-2, Mamba+attention 1:7 interleave.
[arXiv:2403.19887; hf]

Layer schedule (published): blocks of 8 layers -- attention at in-block
index 4, mamba elsewhere; MoE replaces the MLP on every other layer.
Sub-quadratic overall => runs the long_500k shape.
"""
from repro.configs.base import ArchConfig, MoEConfig, MambaConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    mlp_gated=True,
    activation="silu",
    norm="rmsnorm",
    positional="none",          # jamba uses no explicit positional encoding
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14_336, moe_every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=256,
                      attn_every=8, attn_offset=4),
    max_seq=524_288,
    shape_skips=(),             # hybrid: long_500k runs
    source="arXiv:2403.19887; hf",
)
