"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 -- Finch, data-dependent decay.  [arXiv:2404.05892; hf]

No KV cache: per-layer state is (heads, head_dim, head_dim) + shift
vectors => constant-memory decode; runs long_500k.
"""
from repro.configs.base import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,                 # d_model / head_dim(64)
    n_kv_heads=0,               # attention-free
    d_ff=14_336,
    vocab_size=65_536,
    mlp_gated=False,            # rwkv channel-mix is its own structure
    activation="relu",          # channel-mix uses relu^2
    norm="layernorm",
    positional="none",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, gate_lora=64),
    max_seq=524_288,
    shape_skips=(),
    source="arXiv:2404.05892; hf",
)
