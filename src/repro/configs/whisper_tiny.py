"""whisper-tiny [audio]: enc-dec transformer backbone, conv frontend stubbed.

4L decoder (+4L encoder), d_model=384, 6H MHA (kv=6), d_ff=1536,
vocab=51865.  [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig, EncDecConfig, FULL_ATTN_SKIPS

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    qkv_bias=True,              # whisper uses biased q/v projections
    mlp_gated=False,
    activation="gelu",
    norm="layernorm",
    positional="learned",
    tie_embeddings=True,
    max_seq=32_768,             # assigned shapes exceed the published 448 ctx
    encdec=EncDecConfig(n_enc_layers=4, enc_seq=1500, enc_causal=False),
    shape_skips=FULL_ATTN_SKIPS,
    source="arXiv:2212.04356; unverified",
)
