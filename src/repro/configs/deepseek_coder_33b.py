"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 -- llama-arch.  [arXiv:2401.14196; hf]
"""
from repro.configs.base import ArchConfig, FULL_ATTN_SKIPS

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19_200,
    vocab_size=32_256,
    mlp_gated=True,
    activation="silu",
    norm="rmsnorm",
    positional="rope",
    rope_theta=100_000.0,
    shape_skips=FULL_ATTN_SKIPS,
    source="arXiv:2401.14196; hf",
)
