"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 -- anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Backbone only per assignment: the vision tower is a stub; ``input_specs()``
supplies precomputed patch embeddings that the projector maps to d_model.
"""
from repro.configs.base import ArchConfig, VLMConfig, FULL_ATTN_SKIPS

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
    mlp_gated=True,
    activation="silu",
    norm="rmsnorm",
    positional="rope",
    vlm=VLMConfig(n_patches=2880, patch_embed_dim=1024),
    shape_skips=FULL_ATTN_SKIPS,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
