"""minicpm-2b [dense]: 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753 -- WSD schedule (arch=llama-like).  [arXiv:2404.06395; hf]

The WSD (warmup-stable-decay) schedule is implemented in
``repro.optim.schedule`` and selected by this config's ``train_schedule``.
"""
from repro.configs.base import ArchConfig, FULL_ATTN_SKIPS

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    mlp_gated=True,
    activation="silu",
    norm="rmsnorm",
    positional="rope",
    tie_embeddings=True,
    shape_skips=FULL_ATTN_SKIPS,
    source="arXiv:2404.06395; hf",
)

TRAIN_SCHEDULE = "wsd"
