"""JAX version compatibility for the manual-collective layer.

The repo spans JAX releases on both sides of two API moves:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` to
  ``jax.shard_map``, renaming ``check_rep`` -> ``check_vma`` on the way;
* ``jax.make_mesh`` grew an ``axis_types`` keyword (explicit/auto axis
  semantics) that older releases reject.

Every mesh construction and every ``shard_map`` wrap in the repo goes
through this module so the serving engine, the step builders and the
multi-device tests run unmodified on either side.  The semantics we rely
on (ppermute rings, grouped collectives, Auto axis types) are identical
across the supported range — only the spelling moved.
"""
from __future__ import annotations

from typing import Sequence

import jax

try:  # newer jax: explicit axis types on the mesh
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None

if hasattr(jax, "shard_map"):  # jax >= 0.6 spelling
    _shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename folded."""
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    kw = {} if devices is None else {"devices": devices}
    if _AxisType is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(_AxisType.Auto,) * len(tuple(axis_names)), **kw)
        except TypeError:  # pragma: no cover
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)
