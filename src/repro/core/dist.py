"""Distribution environment for fully-manual (shard_map) model execution.

The whole model step runs inside one ``shard_map`` spanning the production
mesh.  :class:`AxisEnv` carries the axis names visible inside; every
collective in the model is explicit (ESL rings, FSDP gathers, EP
all-to-all, loss psum) so the collective schedule is deterministic and
auditable — the JAX analog of the LPU's compiled NET instruction stream.

Degrades gracefully: with ``model=None``/empty axes all helpers become
no-ops and the identical model code runs on one device (smoke tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class AxisEnv:
    model: Optional[str]            # tensor-parallel ring axis
    tp: int
    fsdp: Tuple[str, ...]           # param-gather axes (train ZeRO-3)
    fsdp_width: int
    dp: Tuple[str, ...]             # axes the batch is actually split over
    kv_seq_axis: Optional[str] = None   # long-context KV sequence sharding
    kv_seq_width: int = 1

    @property
    def dp_name(self):
        return self.dp if self.dp else None


def make_axis_env(plan, *, batch: Optional[int] = None) -> AxisEnv:
    """Build the AxisEnv for a plan; batch decides usable DP axes."""
    if plan.mesh_axes is None:
        return AxisEnv(None, 1, (), 1, ())
    sizes = dict(zip(plan.mesh_axes, plan.mesh_shape))
    dp: Tuple[str, ...] = ()
    if batch is None:
        dp = plan.dp_axes
    else:
        # use the largest prefix of dp axes that divides the batch
        width = 1
        for a in plan.dp_axes:
            if batch % (width * sizes[a]) == 0:
                dp = dp + (a,)
                width *= sizes[a]
    fsdp = plan.fsdp_axes
    fw = 1
    for a in fsdp:
        fw *= sizes[a]
    kv_axis, kv_w = None, 1
    if getattr(plan, "kv_seq_axis", None):
        kv_axis = plan.kv_seq_axis
        kv_w = sizes[kv_axis]
    return AxisEnv(plan.tp_axis, plan.tp, fsdp, fw, dp,
                   kv_seq_axis=kv_axis, kv_seq_width=kv_w)


# ---------------------------------------------------------------------------
# FSDP (ZeRO-3) parameter gathering
# ---------------------------------------------------------------------------

def fsdp_dim(shape: Sequence[int], width: int,
             skip_dims: Sequence[int] = ()) -> Optional[int]:
    """First dim divisible by the FSDP width (the mapper's ZeRO rule)."""
    if width <= 1:
        return None
    for i, s in enumerate(shape):
        if i in skip_dims:
            continue
        if s % width == 0 and s >= width:
            return i
    return None


def gather_param(w: jax.Array, env: AxisEnv, dim: Optional[int]) -> jax.Array:
    """All-gather one FSDP-sharded param (reverse-mode: grads psum-scatter
    back to the shard automatically — ZeRO gradient sharding for free)."""
    if dim is None or not env.fsdp or env.fsdp_width <= 1:
        return w
    return lax.all_gather(w, env.fsdp, axis=dim, tiled=True)


def gather_tree(tree, env: AxisEnv, dims_tree):
    """Gather a whole (sub)tree of params given its fsdp-dims tree."""
    return jax.tree.map(lambda w, d: gather_param(w, env, d), tree, dims_tree,
                        is_leaf=lambda x: x is None)


def psum_dp(x, env: AxisEnv):
    return lax.psum(x, env.dp) if env.dp else x


def pmean_dp(x, env: AxisEnv):
    return lax.pmean(x, env.dp) if env.dp else x


def model_rank(env: AxisEnv) -> jax.Array:
    if env.model is None:
        return jnp.int32(0)
    return lax.axis_index(env.model)
