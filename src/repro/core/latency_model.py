"""Analytic token-latency simulator — the ASIC-simulator analog (C5).

The LPU evaluation rests on a cycle-accurate simulator; we reproduce its
*published* numbers with a three-term analytic model derived from the
same reasoning the paper uses:

    t_token = stream_time + vector_time + exposed_sync_time

* stream_time   = (active_param_bytes + kv_bytes) / (N * BW)
                  — the C1 term: decode is weight streaming.
* vector_time   = L * (a + b * d_model / N)
                  — per-layer VXE work (norms, softmax, residual) that
                  does not overlap the stream; it tensor-parallelizes
                  with the ring (d/N), with a fixed per-layer issue cost
                  ``a``.  (a, b) are calibrated on the paper's four OPT
                  latencies — our analog of their RTL calibration.
* exposed_sync  = overlap ? per-layer ring *tail* (one chunk hop)
                          : full ring all-reduce per sync point
                  — the C2 term; 2 sync points per layer (attn out + FC2).

The same model produces Fig. 2a (bandwidth utilization), Fig. 7a
(ms/token), Fig. 7b (energy efficiency via system power), and Fig. 7c
(strong scaling), each validated against the paper's claims in
EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class HWConfig:
    name: str
    mem_bw: float                  # bytes/s per device
    link_bw: float                 # bytes/s per ring direction per device
    system_power_w: float = 0.0    # full-system wall power (fig 7b)
    peak_flops: float = 0.0

    def scaled(self, n: int) -> "HWConfig":
        return self


# paper hardware points
LPU_ASIC = HWConfig("lpu-asic-3.28TBps", mem_bw=3.28e12, link_bw=12.5e9,
                    system_power_w=86.0, peak_flops=32 * 64 * 2 * 1e9)
LPU_FPGA = HWConfig("lpu-fpga-460GBps", mem_bw=460e9, link_bw=12.5e9,
                    system_power_w=76.0, peak_flops=16 * 64 * 2 * 220e6)
H100 = HWConfig("h100", mem_bw=3.35e12, link_bw=450e9,
                system_power_w=550.0, peak_flops=989e12)
L4 = HWConfig("l4", mem_bw=300e9, link_bw=32e9, system_power_w=72.0,
              peak_flops=121e12)
TPU_V5E = HWConfig("tpu-v5e", mem_bw=819e9, link_bw=50e9,
                   system_power_w=200.0, peak_flops=197e12)

# calibrated on the paper's OPT table (see fit_vector_params)
VEC_A_S = 6.0e-6
VEC_B_S_PER_DIM = 4.0e-9


def decode_stream_bytes(cfg: ArchConfig, kv_len: int,
                        dtype_bytes: int = 2) -> float:
    """Weight bytes read from HBM per generated token (all devices)."""
    return cfg.active_params() * dtype_bytes


def kv_stream_bytes(cfg: ArchConfig, kv_len: int,
                    dtype_bytes: int = 2) -> float:
    return cfg.kv_bytes_per_token(dtype_bytes) * kv_len


def token_latency(cfg: ArchConfig, n_devices: int, hw: HWConfig, *,
                  overlap: bool = True, kv_len: int = 1024,
                  vec_a: float = VEC_A_S, vec_b: float = VEC_B_S_PER_DIM,
                  vec_c: float = 0.0, dtype_bytes: int = 2,
                  shard_kv: bool = False) -> Dict[str, float]:
    """ms/token of the generation stage + per-term breakdown.

    ``shard_kv=False`` models the LPU's memory map: the mapper shards
    *weights* across the ring; the KV stream is per-device (this is the
    only reading under which the paper's 66B/2-dev latency, the OPT
    table and the 5.43x scaling figure are mutually consistent).  Our
    TPU mapper shards KV by heads (`shard_kv=True`) — a beyond-paper
    improvement quantified in the fig7c benchmark.
    """
    stream = decode_stream_bytes(cfg, kv_len, dtype_bytes) \
        / (n_devices * hw.mem_bw)
    kv_div = n_devices if shard_kv else 1
    stream += kv_stream_bytes(cfg, kv_len, dtype_bytes) \
        / (kv_div * hw.mem_bw)
    L = cfg.n_layers
    vec = vec_c + L * (vec_a + vec_b * cfg.d_model / n_devices)
    sync_points = 2 * L
    if n_devices == 1:
        sync = 0.0
    elif overlap:
        # ESL: only the last chunk's hop is exposed per sync point
        chunk = cfg.d_model * dtype_bytes / n_devices
        sync = sync_points * chunk / hw.link_bw
    else:
        # blocking ring all-reduce per sync point
        full = cfg.d_model * dtype_bytes
        sync = sync_points * 2 * (n_devices - 1) / n_devices \
            * full / hw.link_bw
        # plus kernel-relaunch/stall overhead per sync (GPU-style)
        sync += sync_points * 5e-6
    total = stream + vec + sync
    return {
        "ms_per_token": total * 1e3,
        "stream_ms": stream * 1e3,
        "vector_ms": vec * 1e3,
        "sync_ms": sync * 1e3,
        "bandwidth_util": stream / total,
        "tokens_per_s": 1.0 / total,
    }


def step_time_prior(cfg: ArchConfig, n_devices: int, hw: HWConfig, *,
                    kv_len: int = 1024, steps_per_sync: int = 1,
                    overlap: bool = True, dtype_bytes: int = 2) -> float:
    """Expected SECONDS one serving engine ``step()`` takes on ``hw``.

    The serving fault-tolerance layer seeds each ring's
    :class:`repro.serving.ft.StragglerMonitor` with this prior
    (``mu0``), so step-time outlier detection is armed from the first
    measured step instead of treating whatever the first step costs as
    the baseline.  A fused window runs ``steps_per_sync`` decode steps
    per host sync, so the prior scales linearly with the window.
    """
    if steps_per_sync < 1:
        raise ValueError(f"steps_per_sync={steps_per_sync} must be >= 1")
    lat = token_latency(cfg, n_devices, hw, overlap=overlap,
                        kv_len=kv_len, dtype_bytes=dtype_bytes)
    return lat["ms_per_token"] * 1e-3 * steps_per_sync


def fit_vector_params(points: Sequence[Tuple[ArchConfig, int, HWConfig,
                                             int, float]]
                      ) -> Tuple[float, float, float, float]:
    """Least-squares (a, b, c) from published (cfg, N, hw, kv_len, ms).

    Returns (a, b, c, max_rel_err) — reported in the benchmark.
    """
    rows, targets = [], []
    for cfg, n, hw, kv_len, ms in points:
        stream = (decode_stream_bytes(cfg, kv_len) / n
                  + kv_stream_bytes(cfg, kv_len)) / hw.mem_bw
        chunk = cfg.d_model * 2 / n
        sync = 0.0 if n == 1 else 2 * cfg.n_layers * chunk / hw.link_bw
        resid = ms / 1e3 - stream - sync
        rows.append([cfg.n_layers, cfg.n_layers * cfg.d_model / n, 1.0])
        targets.append(resid)
    A = np.asarray(rows)
    t = np.asarray(targets)
    # non-negative least squares via active-set elimination (3 params)
    best, best_err = None, np.inf
    import itertools as _it
    for active in _it.chain.from_iterable(
            _it.combinations(range(3), r) for r in (3, 2, 1)):
        Aa = A[:, list(active)]
        sol, *_ = np.linalg.lstsq(Aa, t, rcond=None)
        if np.any(sol < 0):
            continue
        full = np.zeros(3)
        full[list(active)] = sol
        err = float(np.max(np.abs(A @ full - t) / np.maximum(t, 1e-9)))
        if err < best_err:
            best, best_err = full, err
    if best is None:
        best = np.maximum(np.linalg.lstsq(A, t, rcond=None)[0], 0)
    a, b, c = (float(v) for v in best)
    errs = []
    for cfg, n, hw, kv_len, ms in points:
        got = token_latency(cfg, n, hw, kv_len=kv_len, vec_a=a,
                            vec_b=b, vec_c=c)["ms_per_token"]
        errs.append(abs(got - ms) / ms)
    return a, b, c, max(errs)


def scaling_curve(cfg: ArchConfig, hw: HWConfig, max_devices: int = 8, *,
                  overlap: bool = True, kv_len: int = 1024,
                  vec_a: float = VEC_A_S, vec_b: float = VEC_B_S_PER_DIM,
                  vec_c: float = 0.0, shard_kv: bool = False) -> List[float]:
    """Speedup vs 1 device for 1,2,4,...,max_devices."""
    base = token_latency(cfg, 1, hw, overlap=overlap, kv_len=kv_len,
                         vec_a=vec_a, vec_b=vec_b, vec_c=vec_c,
                         shard_kv=shard_kv)["ms_per_token"]
    out = []
    n = 1
    while n <= max_devices:
        t = token_latency(cfg, n, hw, overlap=overlap, kv_len=kv_len,
                          vec_a=vec_a, vec_b=vec_b, vec_c=vec_c,
                          shard_kv=shard_kv)["ms_per_token"]
        out.append(base / t)
        n *= 2
    return out


def energy_per_token(cfg: ArchConfig, n_devices: int, hw: HWConfig, *,
                     kv_len: int = 1024, overlap: bool = True,
                     vec_a: float = VEC_A_S, vec_b: float = VEC_B_S_PER_DIM,
                     vec_c: float = 0.0) -> Dict[str, float]:
    lat = token_latency(cfg, n_devices, hw, overlap=overlap, kv_len=kv_len,
                        vec_a=vec_a, vec_b=vec_b, vec_c=vec_c)
    power = hw.system_power_w * n_devices
    tps = lat["tokens_per_s"]
    return {
        "tokens_per_s": tps,
        "watts": power,
        "tokens_per_s_per_kw": tps / (power / 1e3),
        "joules_per_token": power / tps,
    }
