"""Reconfigurable network (C3): split the ring into independent sub-rings.

The ESL router splits an 8-device ring into 2x4 or 4x2 rings so several
models serve concurrently with no cross-ring interference and no
rewiring.  On a TPU mesh the same capability is mesh partitioning: the
``model`` axis factors into (tenant, ring) and every collective runs
with ``axis_index_groups`` confined to its sub-ring — disjoint groups
are guaranteed non-intersecting, exactly the paper's property.

``RingConfig`` computes the groups; ``ring_spec``/``submeshes`` give the
two consumption styles (grouped collectives inside one program, or truly
independent programs on device subsets — used by the multi-tenant
serving example).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import numpy as np


@dataclass(frozen=True)
class RingConfig:
    total: int                  # devices on the model axis
    ring_size: int              # devices per sub-ring

    def __post_init__(self):
        assert self.total % self.ring_size == 0, (self.total, self.ring_size)

    @property
    def n_rings(self) -> int:
        return self.total // self.ring_size

    def groups(self) -> List[List[int]]:
        """axis_index_groups for collectives confined to each sub-ring."""
        return [list(range(r * self.ring_size, (r + 1) * self.ring_size))
                for r in range(self.n_rings)]

    def ring_of(self, idx: int) -> int:
        return idx // self.ring_size

    def perm_within_rings(self, up: bool = True) -> List[Tuple[int, int]]:
        """ppermute pairs that never cross a ring boundary."""
        pairs = []
        for g in self.groups():
            n = len(g)
            for i, src in enumerate(g):
                dst = g[(i + 1) % n] if up else g[(i - 1) % n]
                pairs.append((src, dst))
        return pairs

    def validate_disjoint(self) -> bool:
        seen = set()
        for g in self.groups():
            if seen & set(g):
                return False
            seen |= set(g)
        return True


def reconfigure(total: int, ring_size: int) -> RingConfig:
    """Paper's 2/4/8-device reconfiguration, generalized to any divisor."""
    return RingConfig(total, ring_size)


def submeshes(mesh: jax.sharding.Mesh, ring_size: int
              ) -> List[jax.sharding.Mesh]:
    """Split the `model` axis of a mesh into independent sub-meshes.

    Each sub-mesh serves its own model (multi-tenant); collectives of one
    tenant are physically confined to its devices.
    """
    axes = mesh.axis_names
    assert axes[-1] == "model"
    devs = mesh.devices
    total = devs.shape[-1]
    cfgs = reconfigure(total, ring_size)
    out = []
    for g in cfgs.groups():
        sub = devs[..., g]
        out.append(jax.sharding.Mesh(sub, axes))
    return out
