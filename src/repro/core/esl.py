"""ESL — Expandable Synchronization Link, as TPU-native collective matmuls.

The paper (C2): tensor-parallel vector-matrix products are split into
column chunks; each chunk's partial product is *immediately* streamed
around a ring of peers while the next chunk computes, so compute,
transmit and receive fully overlap and only a small tail remains — which
itself hides under the next FC layer.

TPU mapping: inside ``shard_map`` over the ``model`` mesh axis we
interleave per-chunk ``dot`` ops with ring ``ppermute`` steps
(`collective-permute` on ICI).  Two primitives cover a transformer:

* :func:`ag_matmul` — column-parallel matmul consuming a *scattered*
  activation (each rank holds D/tp of the input vector).  The input chunks
  rotate around the ring while each rank multiplies the chunk it currently
  holds with the matching row block of its local weight tile.  This is the
  paper's "receive overlaps with compute".

* :func:`rs_matmul` — row-parallel matmul producing a *scattered* output.
  Each rank walks the output column blocks in ring order, adding its own
  contribution to the partial sum it just received and passing it on: the
  partial products stream to peers while the next chunk computes — the
  paper's Figure 4(a) timeline, literally.

Keeping activations scattered between the two (and across layer
boundaries) is what hides the tail: the RS tail of FC2 overlaps the AG
head of the next layer's FC1, exactly the paper's "FC1 followed by FC2"
argument.

``overlap=False`` gives the *typical processor* baseline the paper
compares against: full matmul followed by a blocking ``psum``
(all-reduce) / ``all_gather``.  Both modes are numerically identical —
property-tested — and differ only in collective schedule.

**Sub-rings (C3)**: passing ``ring=RingConfig(total, ring_size)``
confines every collective to the caller's sub-ring *inside one
program*: ppermute pairs come from ``ring.perm_within_rings`` (never
crossing a ring boundary) and gathers/reductions use the disjoint
``axis_index_groups``, so ``n_rings`` independent tensor-parallel
matmuls share one mesh axis.  ``tp`` is then the RING size, and the
weight-block index is the rank *within* the ring.  (The other C3 style
— truly independent programs on ``rings.submeshes`` — needs no special
support here; the serving engine uses that one.)

All functions degrade to plain local matmuls when ``axis is None``
(single-device smoke mode).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.rings import RingConfig


def _ring_perm(tp: int, up: bool = True):
    if up:
        return [(i, (i + 1) % tp) for i in range(tp)]
    return [(i, (i - 1) % tp) for i in range(tp)]


def _ring_env(axis: str, tp: int, ring: Optional[RingConfig], up: bool):
    """(local rank, ppermute pairs, axis_index_groups) for a maybe-grouped
    ring.  Sub-ring groups are contiguous index ranges, so the in-ring
    rank is just ``global % ring_size``."""
    r = lax.axis_index(axis)
    if ring is None:
        return r, _ring_perm(tp, up), None
    assert tp == ring.ring_size, (tp, ring.ring_size)
    return r % ring.ring_size, ring.perm_within_rings(up), ring.groups()


def _take_block(w_blocks: jax.Array, idx) -> jax.Array:
    """w_blocks: (tp, ...) -> dynamic block select."""
    return lax.dynamic_index_in_dim(w_blocks, idx, axis=0, keepdims=False)


# ---------------------------------------------------------------------------
# column-parallel: scattered input -> head/ffn-sharded output
# ---------------------------------------------------------------------------

def ag_matmul(x: jax.Array, w: jax.Array, *, axis: Optional[str], tp: int,
              overlap: bool = True, scattered_in: Optional[bool] = None,
              b: Optional[jax.Array] = None,
              ring: Optional[RingConfig] = None) -> jax.Array:
    """y_loc = (allgather(x) @ w_loc) + b_loc.

    x: (..., D/tp) scattered on the last dim when ``scattered_in`` (the ESL
    convention), or already-full (..., D) otherwise (blocking baseline /
    raw model inputs).  w: (D, N_loc) local column tile. -> (..., N_loc).
    ``ring``: confine the collective to this rank's sub-ring (C3 grouped
    style); ``tp`` must equal ``ring.ring_size``.
    """
    if axis is None or tp == 1:
        y = jnp.einsum("...d,dn->...n", x, w)
        return y + b if b is not None else y
    if scattered_in is None:
        scattered_in = overlap
    if not scattered_in:
        y = jnp.einsum("...d,dn->...n", x, w)
        return y + b if b is not None else y
    d_loc = x.shape[-1]
    w_blocks = w.reshape(tp, d_loc, w.shape[-1])
    r, perm, groups = _ring_env(axis, tp, ring, up=True)
    if not overlap:
        xf = lax.all_gather(x, axis, axis=x.ndim - 1, tiled=True,
                            axis_index_groups=groups)
        y = jnp.einsum("...d,dn->...n", xf, w)
        return y + b if b is not None else y
    # ESL: rotate input chunks around the ring; multiply the chunk we hold.
    acc = jnp.einsum("...d,dn->...n", x, _take_block(w_blocks, r))
    chunk = x
    for s in range(1, tp):
        # stream the chunk to the next peer while the dot above executes
        chunk = lax.ppermute(chunk, axis, perm)
        src = (r - s) % tp  # rank whose chunk we now hold
        acc = acc + jnp.einsum("...d,dn->...n", chunk,
                               _take_block(w_blocks, src))
    return acc + b if b is not None else acc


# ---------------------------------------------------------------------------
# row-parallel: sharded input -> scattered (or replicated) output
# ---------------------------------------------------------------------------

def rs_matmul(x: jax.Array, w: jax.Array, *, axis: Optional[str], tp: int,
              overlap: bool = True, scatter_out: bool = True,
              b: Optional[jax.Array] = None,
              ring: Optional[RingConfig] = None) -> jax.Array:
    """y = sum_over_ranks(x_loc @ w_loc), reduced across the ring.

    x: (..., M_loc); w: (M_loc, D_out).  scatter_out=True returns
    (..., D_out/tp) (reduce-scatter semantics — the ESL-native form);
    False returns the full (..., D_out) via psum (baseline).
    ``ring``: confine the reduction to this rank's sub-ring (C3).
    """
    if axis is None or tp == 1:
        y = jnp.einsum("...m,md->...d", x, w)
        return y + b if b is not None else y
    if not overlap:
        r, _, groups = _ring_env(axis, tp, ring, up=False)
        y = jnp.einsum("...m,md->...d", x, w)
        if scatter_out:
            y = lax.psum_scatter(y, axis, scatter_dimension=y.ndim - 1,
                                 tiled=True, axis_index_groups=groups)
            if b is not None:
                y = y + _bias_slice(b, axis, tp, ring=ring)
            return y
        y = lax.psum(y, axis, axis_index_groups=groups)
        return y + b if b is not None else y
    d_out = w.shape[-1]
    c = d_out // tp
    w_blocks = w.reshape(w.shape[0], tp, c).transpose(1, 0, 2)  # (tp, M, c)
    r, perm, groups = _ring_env(axis, tp, ring, up=False)
    # ring reduce-scatter fused with the matmul: at each step add our
    # contribution for the block that is travelling toward its home rank.
    acc = jnp.einsum("...m,mc->...c", x, _take_block(w_blocks, (r + 1) % tp))
    for s in range(1, tp):
        acc = lax.ppermute(acc, axis, perm)
        blk = (r + 1 + s) % tp
        acc = acc + jnp.einsum("...m,mc->...c", x, _take_block(w_blocks, blk))
    # acc now holds block r (scattered output)
    if b is not None:
        acc = acc + _bias_slice(b, axis, tp, ring=ring)
    if scatter_out:
        return acc
    return lax.all_gather(acc, axis, axis=acc.ndim - 1, tiled=True,
                          axis_index_groups=groups)


def _bias_slice(b: jax.Array, axis: Optional[str], tp: int,
                ring: Optional[RingConfig] = None) -> jax.Array:
    if axis is None or tp == 1:
        return b
    r = lax.axis_index(axis)
    if ring is not None:
        r = r % ring.ring_size
    c = b.shape[-1] // tp
    return lax.dynamic_slice_in_dim(b, r * c, c, axis=-1)


# ---------------------------------------------------------------------------
# helpers for scattered-activation mode
# ---------------------------------------------------------------------------

def gather_scattered(x: jax.Array, *, axis: Optional[str],
                     tp: int) -> jax.Array:
    """(..., D/tp) -> (..., D): explicit all-gather (used at mode edges)."""
    if axis is None or tp == 1:
        return x
    return lax.all_gather(x, axis, axis=x.ndim - 1, tiled=True)


def scatter_full(x: jax.Array, *, axis: Optional[str], tp: int) -> jax.Array:
    """(..., D) -> (..., D/tp): slice the local shard of a replicated array."""
    if axis is None or tp == 1:
        return x
    r = lax.axis_index(axis)
    c = x.shape[-1] // tp
    return lax.dynamic_slice_in_dim(x, r * c, c, axis=-1)


def vec_slice(v: jax.Array, *, axis: Optional[str], tp: int) -> jax.Array:
    """Local shard of a replicated vector parameter (norm scales etc.)."""
    return scatter_full(v, axis=axis, tp=tp)


def full_vec(v: jax.Array, *, axis: Optional[str], tp: int,
             scattered_activations: bool) -> jax.Array:
    """Vector params are stored model-sharded (rule 'vec'); in scattered
    mode the local shard is exactly what elementwise ops need; in the
    blocking baseline (full activations) gather it."""
    if axis is None or tp == 1 or scattered_activations:
        return v
    return lax.all_gather(v, axis, axis=v.ndim - 1, tiled=True)


# ---------------------------------------------------------------------------
# collective-latency accounting (consumed by core.latency_model)
# ---------------------------------------------------------------------------

def sync_bytes_per_token(d_model: int, tp: int, dtype_bytes: int = 2,
                         overlap: bool = True) -> dict:
    """Wire bytes/activation-vector for one row-parallel sync on a ring.

    ring all-reduce moves 2*(tp-1)/tp * D bytes per device; the ESL form
    (RS while computing + AG folded into the next layer's ag_matmul) moves
    the same bytes but off the critical path — only the tail (one chunk
    hop, D/tp bytes) remains serialized.
    """
    full = d_model * dtype_bytes
    wire = 2 * (tp - 1) / tp * full
    exposed = (full / tp) if overlap else wire
    return {"wire_bytes": wire, "exposed_bytes": exposed}
