"""Step builders: wrap the pure model functions in shard_map + jit.

This is the "instruction generation" layer of the HyperDex analog: given
(model, mesh, shape) it emits the compiled programs —

* ``train_step``   — fwd + bwd (manual ZeRO-3 gathers, ESL rings) +
                     optimizer update (elementwise on sharded state).
* ``prefill_step`` — summarization stage: builds the KV cache.
* ``serve_step``   — generation stage: one token against the cache
                     (greedy head; the engine's sampled variant takes rng).

All collectives are explicit inside one shard_map spanning the mesh; the
optimizer update runs outside (elementwise on identically-sharded trees).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.dist import AxisEnv, gather_param, make_axis_env, psum_dp
from repro.models.transformer import sharded_xent

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# FSDP bookkeeping derived from the mapper's specs
# ---------------------------------------------------------------------------

def fsdp_dims_tree(specs, plan):
    """Per-leaf index of the FSDP-sharded dim (None if not FSDP'd)."""
    fsdp = tuple(plan.fsdp_axes)

    def leaf_dim(spec):
        if not fsdp:
            return None
        for i, e in enumerate(spec):
            if e == fsdp or e == (fsdp if len(fsdp) > 1 else fsdp[0]):
                return i
            if isinstance(e, tuple) and tuple(e) == fsdp:
                return i
            if isinstance(e, str) and (e,) == fsdp:
                return i
        return None

    return jax.tree.map(leaf_dim, specs,
                        is_leaf=lambda x: isinstance(x, P))


def _drop_lead(dims):
    """Stacked-block dims -> dims after lax.scan slices the lead axis."""
    return jax.tree.map(lambda d: None if d is None else d - 1, dims,
                        is_leaf=lambda x: x is None or isinstance(x, int))


def make_gather_fn(plan, env: AxisEnv, specs):
    """gather_fn(group, subtree) closing over per-group fsdp-dim trees."""
    dims_full = fsdp_dims_tree(specs, plan)
    groups: Dict[str, Any] = {}
    for key in ("blocks", "enc_blocks", "dec_blocks"):
        if isinstance(dims_full, dict) and key in dims_full:
            groups[{"blocks": "block", "enc_blocks": "enc_block",
                    "dec_blocks": "dec_block"}[key]] = \
                _drop_lead(dims_full[key])
    emb = {k: v for k, v in dims_full.items()
           if k in ("embed", "embed_in", "head", "pos_embed", "projector")}
    groups["embed"] = emb

    cdt = jnp.dtype(plan.compute_dtype)

    def gather_cast(w, d):
        w = gather_param(w, env, d)
        if jnp.issubdtype(w.dtype, jnp.floating) and w.dtype != cdt:
            w = w.astype(cdt)          # master stays f32; compute in bf16
        return w

    def gather_fn(group: str, subtree):
        dims = groups[group]
        if group == "embed":
            dims = {k: dims[k] for k in subtree}
        return jax.tree.map(gather_cast, subtree, dims,
                            is_leaf=lambda x: x is None or isinstance(x, int))

    return gather_fn


def _sync_grads(grads, dims, env: AxisEnv, compress_pod: bool = False):
    """Replicated-over-dp leaves need an explicit psum; FSDP'd leaves are
    already reduce-scattered by the all_gather transpose.

    ``compress_pod``: when 'pod' is among the dp axes, its share of the
    sync runs as an int8+error-feedback all-reduce (DCI is ~8x slower
    than ICI); the intra-pod share stays full-precision.
    """
    from repro.optim.adamw import compressed_psum

    pod_in_dp = compress_pod and "pod" in env.dp
    intra = tuple(a for a in env.dp if a != "pod") if pod_in_dp else env.dp

    def sync(g, d):
        if d is not None:
            return g
        if not pod_in_dp:
            return psum_dp(g, env)
        if intra:
            g = jax.lax.psum(g, intra)
        g, _err = compressed_psum(g, "pod")   # residual fed back per step
        return g
    return jax.tree.map(sync, grads, dims,
                        is_leaf=lambda x: x is None or isinstance(x, int))


# ---------------------------------------------------------------------------
# batch specs / input specs
# ---------------------------------------------------------------------------

def batch_specs(model, env: AxisEnv, kind: str):
    dp = tuple(env.dp) if env.dp else None
    cfg = model.cfg
    s: Dict[str, P] = {"tokens": P(dp, None)}
    if kind == "train":
        s["labels"] = P(dp, None)
    if kind in ("decode",):
        s["positions"] = P(dp)
    if cfg.family == "encdec" and kind != "decode":
        s["frames"] = P(dp, None, None)
    if cfg.family == "vlm" and kind != "decode":
        s["patch_embeds"] = P(dp, None, None)
    return s


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(model, optimizer, mesh, global_batch: int,
                     aux_weight: float = 0.01, accum_steps: int = 1,
                     compress_pod_grads: bool = False):
    """fwd+bwd (+ microbatched gradient accumulation) + optimizer.

    ``accum_steps > 1``: the per-device batch is split into microbatches
    scanned sequentially with an f32 gradient accumulator — the standard
    remedy when the assigned global batch exceeds per-device activation
    memory (EXPERIMENTS.md §Dry-run memory-fit note).

    ``compress_pod_grads``: int8 + error-feedback all-reduce for the
    replicated-parameter gradient sync on the slow cross-pod axis
    (optim/adamw.py::compressed_psum); FSDP'd parameters already sync via
    the all-gather transpose on intra-pod links.
    """
    cfg, plan = model.cfg, model.plan
    env = make_axis_env(plan, batch=global_batch)
    specs, _ = model.param_specs()
    dims = fsdp_dims_tree(specs, plan)
    bspecs = batch_specs(model, env, "train")

    def inner(params, batch):
        gather_fn = make_gather_fn(plan, env, specs)

        def loss_fn(p, mb):
            logits, _, aux = model.forward(
                p, mb["tokens"], env=env, mode="train",
                frames=mb.get("frames"),
                patch_embeds=mb.get("patch_embeds"),
                gather_fn=gather_fn)
            labels = mb["labels"]
            if "patch_embeds" in mb:
                # image prefix carries no next-token loss
                pad = jnp.full(mb["patch_embeds"].shape[:2], -1,
                               labels.dtype)
                labels = jnp.concatenate([pad, labels], axis=1)
            lsum, cnt = sharded_xent(logits, labels, env)
            lsum, cnt = psum_dp(lsum, env), psum_dp(cnt, env)
            loss = lsum / jnp.maximum(cnt, 1.0)
            aux_m = aux / max(cfg.n_layers, 1)
            total = loss + aux_weight * aux_m
            return total, (loss, aux_m)

        if accum_steps <= 1:
            grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            def split_mb(t):
                b = t.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return t.reshape(accum_steps, b // accum_steps,
                                 *t.shape[1:])
            mbs = {k: split_mb(v) for k, v in batch.items()}

            def acc_body(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                g, (l, a) = jax.grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + l, aux_acc + a), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (g0, jnp.float32(0), jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss, aux = loss / accum_steps, aux / accum_steps

        grads = _sync_grads(grads, dims, env,
                            compress_pod=compress_pod_grads)
        return grads, {"loss": loss, "aux": aux}

    if mesh is not None:
        inner_sm = shard_map(
            inner, mesh=mesh, in_specs=(specs, bspecs),
            out_specs=(specs, {"loss": P(), "aux": P()}), check_vma=False)
    else:
        inner_sm = inner

    def step(params, opt_state, batch):
        grads, metrics = inner_sm(params, batch)
        params, opt_state, gmetrics = optimizer.apply(params, grads,
                                                      opt_state)
        metrics.update(gmetrics)
        return params, opt_state, metrics

    return step, {"param_specs": specs, "batch_specs": bspecs, "env": env}


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def _greedy(logits, env: AxisEnv):
    """(B,1,Vloc) vocab-sharded -> (B,) global argmax token ids."""
    lg = logits[:, -1].astype(jnp.float32)
    v_loc = lg.shape[-1]
    loc_idx = jnp.argmax(lg, -1)
    loc_val = jnp.max(lg, -1)
    if env.model is None:
        return loc_idx.astype(jnp.int32)
    r = lax.axis_index(env.model)
    glob = loc_idx + r * v_loc
    vals = lax.all_gather(loc_val, env.model, axis=1)      # (B, tp)
    globs = lax.all_gather(glob, env.model, axis=1)        # (B, tp)
    best = jnp.argmax(vals, -1)
    return jnp.take_along_axis(globs, best[:, None], 1)[:, 0].astype(jnp.int32)


def build_serve_step(model, mesh, batch: int, max_seq: int):
    """One-token generation step (the LPU's target loop)."""
    cfg, plan = model.cfg, model.plan
    env = make_axis_env(plan, batch=batch)
    specs, _ = model.param_specs()
    cspecs = model.cache_specs(env)
    bspecs = batch_specs(model, env, "decode")

    def inner(params, cache, tokens, positions):
        gather_fn = make_gather_fn(plan, env, specs)
        logits, new_cache, _ = model.forward(
            params, tokens, env=env, mode="decode", positions=positions,
            cache=cache, gather_fn=gather_fn)
        nxt = _greedy(logits, env)
        return nxt, new_cache

    if mesh is not None:
        dp = tuple(env.dp) if env.dp else None
        inner_sm = shard_map(
            inner, mesh=mesh,
            in_specs=(specs, cspecs, bspecs["tokens"], bspecs["positions"]),
            out_specs=(P(dp), cspecs), check_vma=False)
    else:
        inner_sm = inner

    return inner_sm, {"param_specs": specs, "cache_specs": cspecs,
                      "batch_specs": bspecs, "env": env}


def build_prefill_step(model, mesh, batch: int, max_seq: int):
    """Summarization stage: consume the prompt, emit cache + last logits."""
    cfg, plan = model.cfg, model.plan
    env = make_axis_env(plan, batch=batch)
    specs, _ = model.param_specs()
    cspecs = model.cache_specs(env)
    bspecs = batch_specs(model, env, "prefill")

    def inner(params, cache, tokens, frames, patch_embeds):
        gather_fn = make_gather_fn(plan, env, specs)
        # dummy scalars stand in for absent modality inputs (shard_map
        # needs a static arg list); route None for non-matching families
        frames = frames if cfg.family == "encdec" else None
        patch_embeds = patch_embeds if cfg.family == "vlm" else None
        logits, new_cache, _ = model.forward(
            params, tokens, env=env, mode="prefill", cache=cache,
            frames=frames, patch_embeds=patch_embeds, gather_fn=gather_fn)
        nxt = _greedy(logits, env)
        return nxt, new_cache

    if mesh is not None:
        dp = tuple(env.dp) if env.dp else None
        fspec = bspecs.get("frames", P())
        pspec = bspecs.get("patch_embeds", P())
        inner_sm = shard_map(
            inner, mesh=mesh,
            in_specs=(specs, cspecs, bspecs["tokens"], fspec, pspec),
            out_specs=(P(dp), cspecs), check_vma=False)

        def wrapped(params, cache, tokens, frames=None, patch_embeds=None):
            frames = frames if frames is not None else jnp.zeros((), jnp.bfloat16)
            patch_embeds = (patch_embeds if patch_embeds is not None
                            else jnp.zeros((), jnp.bfloat16))
            return inner_sm(params, cache, tokens, frames, patch_embeds)
        return wrapped, {"param_specs": specs, "cache_specs": cspecs,
                         "batch_specs": bspecs, "env": env}

    def wrapped_local(params, cache, tokens, frames=None, patch_embeds=None):
        return inner(params, cache, tokens, frames, patch_embeds)
    return wrapped_local, {"param_specs": specs, "cache_specs": cspecs,
                           "batch_specs": bspecs, "env": env}
