"""Trip-count-aware cost model over optimized HLO text.

``Compiled.cost_analysis()`` counts a ``while`` body exactly once, so any
scan-over-layers (or chunked flash-attention scan) is undercounted by its
trip count.  This parser rebuilds per-computation costs from the HLO text
and multiplies each while body by its trip count (recovered from the loop
condition's comparison constant), nesting included.

Per-computation terms:
* flops        — dot/convolution ops (symbol-table lookup for operand
                 shapes): 2 * prod(result) * prod(contracted dims).
* hbm_bytes    — sum over *top-level* instructions of result+operand
                 bytes for memory-touching op kinds (fusion internals
                 stay in registers/VMEM, matching XLA's accounting).
* wire_bytes   — ring-algorithm wire bytes of every collective.

Validated against ``cost_analysis()`` on fully-unrolled modules (no
whiles), where both must agree on flops (tests/test_hlo_cost.py).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_KIND_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_HBM_KINDS = {
    "fusion", "dot", "copy", "copy-start", "all-reduce", "all-gather",
    "reduce-scatter", "all-to-all", "collective-permute", "dynamic-slice",
    "dynamic-update-slice", "convolution", "sort", "gather", "scatter",
    "transpose", "concatenate", "pad", "reduce", "convert", "broadcast",
    "slice", "select", "add", "multiply", "subtract", "exponential",
    "custom-call", "rng-bit-generator", "compare", "divide", "tanh",
    "rsqrt", "maximum", "minimum",
}
for _c in list(_COLLECTIVES):
    _HBM_KINDS.add(_c + "-start")


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jax returns a dict; newer versions return a one-element list of
    per-device dicts.  Always hand back a flat ``{metric: value}`` dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_counts: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.wire_bytes += o.wire_bytes
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.hbm_bytes * t, self.wire_bytes * t,
                    {k: v * t for k, v in self.coll_counts.items()})

    def row(self) -> Dict[str, float]:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "wire_bytes": self.wire_bytes,
                "coll_counts": dict(self.coll_counts)}


@dataclass
class _Instr:
    name: str
    kind: str
    result_shapes: List[Tuple[str, str]]
    operands: List[str]
    line: str


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _parse_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            # header: `%name (params) -> type {` (may contain /*index=N*/)
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{\s*$", line)
            if m and not re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=", line):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        km = _KIND_RE.search(rhs)
        if not km:
            continue
        kind = km.group(1)
        result_part = rhs[:km.start()]
        result_shapes = _SHAPE_RE.findall(result_part)
        rest = rhs[km.end():]
        args_part = rest.split("), ")[0] if "), " in rest else rest
        operands = _OPERAND_RE.findall(args_part)
        comps[cur].append(
            _Instr(name, kind, result_shapes, operands, line))
    return comps


def _first_shape_bytes(shapes: List[Tuple[str, str]]) -> int:
    return sum(_shape_bytes(dt, dm) for dt, dm in shapes)


def _collective_wire(kind: str, rbytes: float, line: str,
                     default_group: int) -> float:
    g = default_group
    m = _GROUPS_RE.search(line)
    if m:
        g = len(m.group(1).split(","))
    else:
        m2 = _GROUPS2_RE.search(line)
        if m2:
            g = int(m2.group(2))
    if kind == "all-reduce":
        return 2.0 * (g - 1) / max(g, 1) * rbytes
    if kind == "all-gather":
        return (g - 1) / max(g, 1) * rbytes
    if kind == "reduce-scatter":
        return (g - 1) * rbytes
    if kind == "all-to-all":
        return (g - 1) / max(g, 1) * rbytes
    return float(rbytes)


def module_cost(text: str, default_group: int = 1) -> Cost:
    comps = _parse_computations(text)
    # symbol tables: per computation, name -> result shapes
    tables: Dict[str, Dict[str, List[Tuple[str, str]]]] = {
        c: {i.name: i.result_shapes for i in instrs}
        for c, instrs in comps.items()
    }
    memo: Dict[str, Cost] = {}
    kinds: Dict[str, Dict[str, str]] = {
        c: {i.name: i.kind for i in instrs} for c, instrs in comps.items()
    }
    # computations reached as while bodies (carry copies elidable there)
    while_bodies = set()
    for instrs in comps.values():
        for i in instrs:
            if i.kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", i.line)
                if mb:
                    while_bodies.add(mb.group(1))

    def dot_flops(ins: _Instr, table) -> float:
        out = 1
        for dt, dm in ins.result_shapes:
            for d in dm.split(","):
                if d:
                    out *= int(d)
        lhs_shapes = table.get(ins.operands[0] if ins.operands else "", [])
        if not lhs_shapes:
            return 0.0
        lhs = [int(d) for d in lhs_shapes[0][1].split(",") if d]
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        contract = 1
        if m and m.group(1):
            for i in m.group(1).split(","):
                if int(i) < len(lhs):
                    contract *= lhs[int(i)]
        return 2.0 * out * contract

    def conv_flops(ins: _Instr, table) -> float:
        out = 1
        for dt, dm in ins.result_shapes:
            for d in dm.split(","):
                if d:
                    out *= int(d)
        if len(ins.operands) < 2:
            return 0.0
        ker_shapes = table.get(ins.operands[1], [])
        if not ker_shapes:
            return 0.0
        ker = [int(d) for d in ker_shapes[0][1].split(",") if d]
        k = 1
        for d in ker[:-1]:
            k *= d
        return 2.0 * out * k

    def _sliced_params(ins: _Instr) -> Dict[int, int]:
        """fusion operand index -> bytes actually read, for operands the
        fused computation only dynamic-slices (stacked scan xs etc.)."""
        m = re.search(r"calls=%?([\w.\-]+)", ins.line)
        if not m or m.group(1) not in comps:
            return {}
        called = comps[m.group(1)]
        ctable = tables[m.group(1)]
        param_idx = {i.name: int(re.search(r"parameter\((\d+)\)",
                                           i.line).group(1))
                     for i in called if i.kind == "parameter"}
        out: Dict[int, int] = {}
        consumed: Dict[str, List[int]] = {}
        for i in called:
            for op in i.operands:
                consumed.setdefault(op, []).append(0)
        for i in called:
            if i.kind != "dynamic-slice" or not i.operands:
                continue
            src = i.operands[0]
            if src in param_idx and len(consumed.get(src, [])) == 1:
                out[param_idx[src]] = _first_shape_bytes(i.result_shapes)
        return out

    def trip_count(cond: str) -> int:
        best = 1
        for ins in comps.get(cond, []):
            for m in _CONST_RE.finditer(ins.line):
                best = max(best, int(m.group(1)))
        return best

    def comp_cost(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        table = tables[name]
        kt = kinds[name]
        total = Cost()
        for ins in comps[name]:
            kind = ins.kind
            if kind == "copy" and name in while_bodies and ins.operands:
                src_kind = kt.get(ins.operands[0])
                consumers = [j for j in comps[name]
                             if ins.name in j.operands]
                inplace_sink = consumers and all(
                    j.kind in ("tuple", "copy")
                    or "scatter" in j.name
                    or "dynamic-update-slice" in j.name
                    for j in consumers)
                if (src_kind in ("get-tuple-element", "parameter")
                        or inplace_sink):
                    # while-carry / scatter-destination bookkeeping copy:
                    # elided by TPU buffer assignment (in-place loop
                    # carries + aliased scatter); not HBM traffic
                    continue
            if kind == "dot":
                total += Cost(flops=dot_flops(ins, table))
            elif kind == "convolution":
                total += Cost(flops=conv_flops(ins, table))
            base = kind.replace("-start", "")
            if base in _COLLECTIVES:
                rb = _first_shape_bytes(ins.result_shapes)
                total += Cost(
                    wire_bytes=_collective_wire(base, rb, ins.line,
                                                default_group),
                    coll_counts={base: 1})
            if kind in _HBM_KINDS:
                if ("dynamic-update-slice" in ins.name
                        or kind in ("dynamic-update-slice", "scatter")
                        or "scatter" in ins.name):
                    # in-place update (DUS / scatter): traffic = read+write
                    # of the updated region (the non-destination operands),
                    # not the aliased destination buffer
                    sizes = sorted((_first_shape_bytes(table[op])
                                    for op in ins.operands if op in table),
                                   reverse=True)
                    upd = sum(sizes[1:]) if len(sizes) > 1 else \
                        (sizes[0] if sizes else 0)
                    total += Cost(hbm_bytes=2 * upd)
                else:
                    b = _first_shape_bytes(ins.result_shapes)
                    sliced = _sliced_params(ins) if kind == "fusion" else {}
                    for idx, op in enumerate(ins.operands):
                        if op not in table:
                            continue
                        if idx in sliced:
                            # the fused computation dynamic-slices this
                            # operand: traffic = the slice, not the buffer
                            b += sliced[idx]
                        else:
                            b += _first_shape_bytes(table[op])
                    total += Cost(hbm_bytes=b)
            if kind == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                trips = trip_count(mc.group(1)) if mc else 1
                if mb:
                    total += comp_cost(mb.group(1),
                                       stack + (name,)).scaled(trips)
            elif kind in ("fusion", "call", "custom-call", "conditional"):
                for called in re.findall(
                        r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-]+)",
                        ins.line):
                    sub = comp_cost(called, stack + (name,))
                    if kind == "call":
                        # a plain call is inlined code (e.g. CPU
                        # outer-dimension parallelization wrappers): its
                        # body's HBM traffic is real, unlike a fusion's
                        total += sub
                    else:
                        total += Cost(flops=sub.flops,
                                      wire_bytes=sub.wire_bytes,
                                      coll_counts=dict(sub.coll_counts))
        memo[name] = total
        return total

    entry = None
    for cand in comps:
        if cand.startswith("main"):
            entry = cand
            break
    if entry is None and comps:
        entry = max(comps, key=lambda k: len(comps[k]))
    return comp_cost(entry) if entry else Cost()
