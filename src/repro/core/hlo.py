"""Compiled-HLO analysis: collective wire bytes, op census, roofline terms.

``cost_analysis()`` gives per-device FLOPs and HBM bytes but not
collective traffic; we parse the optimized HLO text and sum operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, converted to ring-algorithm wire bytes per device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_TUPLE_COLL_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    result_bytes: Dict[str, int] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def row(self) -> Dict[str, float]:
        return {"counts": dict(self.counts),
                "result_bytes": dict(self.result_bytes),
                "wire_bytes": {k: round(v) for k, v in
                               self.wire_bytes.items()},
                "total_wire_bytes": round(self.total_wire_bytes)}


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS2_RE.search(line)
    if m:  # replica_groups=[n_groups,group_size]
        return int(m.group(2))
    return default


def collective_stats(hlo_text: str, default_group: int = 1
                     ) -> CollectiveStats:
    """Sum per-device ring wire bytes of every collective in the module."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        tuple_elems: List[Tuple[str, str]] = []
        if not m:
            mt = _TUPLE_COLL_RE.search(line)
            if not mt:
                continue
            op = mt.group(2)
            for em in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]",
                                  mt.group(1)):
                tuple_elems.append((em.group(1), em.group(2)))
        else:
            op = m.group(3)
            tuple_elems = [(m.group(1), m.group(2))]
        rbytes = sum(_shape_bytes(d, s) for d, s in tuple_elems)
        g = _group_size(line, default_group)
        if op == "all-reduce":
            wire = 2.0 * (g - 1) / max(g, 1) * rbytes
        elif op == "all-gather":
            wire = (g - 1) / max(g, 1) * rbytes        # result = gathered
        elif op == "reduce-scatter":
            wire = (g - 1) * rbytes                    # result = shard
        elif op == "all-to-all":
            wire = (g - 1) / max(g, 1) * rbytes
        else:                                          # collective-permute
            wire = float(rbytes)
        st.counts[op] = st.counts.get(op, 0) + 1
        st.result_bytes[op] = st.result_bytes.get(op, 0) + rbytes
        st.wire_bytes[op] = st.wire_bytes.get(op, 0.0) + wire
    return st


def op_census(hlo_text: str, ops=("fusion", "dot", "convolution",
                                  "dynamic-slice", "dynamic-update-slice",
                                  "transpose", "copy", "while")) -> Dict[str, int]:
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"\s{op}(?:\.\d+)?\(", hlo_text))
    return out


@dataclass
class Roofline:
    """Three-term roofline (per device) in seconds."""
    flops: float
    hbm_bytes: float
    wire_bytes: float
    peak_flops: float
    hbm_bw: float
    link_bw: float

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / self.link_bw

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def row(self) -> Dict[str, float]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }
