"""Streamlined decode engine (C1) — kernel-backed generation step.

The LPU's thesis: generation-stage latency == weight-stream time, so the
decode path must be a chain of bandwidth-saturating streamed ops with
zero reshaping between them.  This module is that chain on TPU:

    gemv(QKV, fused)  ->  decode_attention (fused flash, SXE||VXE)
 -> gemv(O) -> gemv(FC1 gate|up, fused) -> gemv(FC2)

Every matmul is the Pallas GEMV (``kernels/gemv``) whose BlockSpecs
realize the ``I x v x 2B x freq = BW`` balance; attention is the fused
``kernels/decode_attention``.  ``use_kernels=False`` routes to the jnp
oracles — bit-compatible (tests/test_streamline.py), used by the
dry-run so XLA's fusion stands in for the hand kernels on CPU.

This is the single-device inner loop; the ESL ring (core/esl.py) wraps
it for tensor parallelism (the kernels consume rank-local tiles).  The
KV side accepts either the dense per-slot cache or the serving engine's
shared block pool (``block_table``) — same streamed chain, the table
only redirects where KV tiles live (tests/test_streamline.py proves
dense/paged parity).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention,
                                                resolve_paged_kernel)
from repro.kernels.gemv.ops import gemv, quantize_weight
from repro.models.common import apply_norm, apply_rope

Params = Dict[str, jax.Array]

W_DTYPES = ("auto", "int8")


def _mm(x2d: jax.Array, w: jax.Array, b: Optional[jax.Array], *,
        use_kernels: bool, interpret: bool = True,
        quantize: bool = False) -> jax.Array:
    if quantize:
        # int8 weight stream: per-output-column absmax quantization, the
        # scale applied once at the kernel's f32 flush — halves the HBM
        # bytes of the dominant weight stream (C1's balance knob)
        qw, ws = quantize_weight(w)
        return gemv(x2d, qw, b, w_scale=ws, use_pallas=use_kernels,
                    interpret=interpret)
    return gemv(x2d, w, b, use_pallas=use_kernels, interpret=interpret)


def decode_layer(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
                 positions: jax.Array, *, cfg, plan,
                 use_kernels: bool = True, interpret: bool = True,
                 block_table: Optional[jax.Array] = None,
                 paged_kernel: str = "auto",
                 w_dtype: str = "auto"
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decoder layer, one token, single device (tp folded outside).

    x: (B, D); cache: {'k','v': (B, S, G, dh)}; positions: (B,).
    Returns (y (B, D), new cache).  Weights in the mapper's stored layout.

    Paged mode (``block_table`` (B, T) given): cache k/v are the shared
    block pool (N, bs, G, dh).  The new token's KV scatters into
    physical block ``table[b, pos // bs]`` at offset ``pos % bs`` — the
    serving engine's pool layout, tp-folded just like the weights (each
    rank holds its head shard of every block).  ``paged_kernel``:
    ``"stream"`` keeps the chain gather-free — the paged kernel consumes
    KV tiles straight from the updated pool through the block table;
    ``"gather"`` materializes the per-request contiguous view first (the
    reference oracle); ``"auto"`` streams when the stored GQA layout is
    block-regular.

    Quantized pool (``k_scale``/``v_scale`` leaves present): the new
    token's KV rows quantize at scatter time and the kernel dequantizes
    in its tile loop — the decode chain reads the pool post-update, so
    the current token is attended via its quantized round-trip (same
    stored-value contract as the full model path).  ``w_dtype="int8"``
    streams every gemv's weights int8 with per-output-column scales.
    """
    if w_dtype not in W_DTYPES:
        raise ValueError(f"w_dtype={w_dtype!r} not in {W_DTYPES}")
    qw = w_dtype == "int8"
    a = plan.attn
    B, D = x.shape
    qpr, kpr, dh = a.q_per_rank, a.kv_per_rank, a.d_head

    h = apply_norm(p["ln1"], x, cfg.norm)
    wq = p["attn"]["wq"].reshape(D, qpr * dh)
    wk = p["attn"]["wk"].reshape(D, kpr * dh)
    wv = p["attn"]["wv"].reshape(D, kpr * dh)
    wqkv = jnp.concatenate([wq, wk, wv], -1)     # ONE weight stream (C1)
    bqkv = None
    if "bq" in p["attn"]:
        bqkv = jnp.concatenate([p["attn"][k].reshape(-1)
                                for k in ("bq", "bk", "bv")])
    qkv = _mm(h, wqkv, bqkv, use_kernels=use_kernels, interpret=interpret,
              quantize=qw)
    q, k_new, v_new = jnp.split(qkv, [qpr * dh, (qpr + kpr) * dh], -1)
    q = q.reshape(B, qpr, dh)
    k_new = k_new.reshape(B, kpr, dh)
    v_new = v_new.reshape(B, kpr, dh)
    if cfg.positional == "rope":
        q = apply_rope(q[:, None], positions[:, None],
                       cfg.rope_theta)[:, 0]
        k_new = apply_rope(k_new[:, None], positions[:, None],
                           cfg.rope_theta)[:, 0]

    quantized = block_table is not None and "k_scale" in cache
    ks = vs = None
    if block_table is not None:
        # pool scatter: one (G, dh) row per sequence; inactive slots all
        # target the null block 0 (don't-care, masked by valid length)
        bs_blk = cache["k"].shape[1]
        blk = jnp.take_along_axis(block_table,
                                  (positions // bs_blk)[:, None],
                                  axis=1)[:, 0]
        off = positions % bs_blk
        if quantized:
            from repro.serving.kv_cache import quantize_kv_rows
            kq, ksc = quantize_kv_rows(k_new, cache["k"].dtype,
                                       cache["k_scale"].dtype)
            vq, vsc = quantize_kv_rows(v_new, cache["v"].dtype,
                                       cache["v_scale"].dtype)
            kc = cache["k"].at[blk, off].set(kq)
            vc = cache["v"].at[blk, off].set(vq)
            ks = cache["k_scale"].at[blk, off].set(ksc)
            vs = cache["v_scale"].at[blk, off].set(vsc)
        else:
            kc = cache["k"].at[blk, off].set(k_new.astype(cache["k"].dtype))
            vc = cache["v"].at[blk, off].set(v_new.astype(cache["v"].dtype))
        mode = resolve_paged_kernel(plan, bs_blk, paged_kernel,
                                    interpret=interpret)
        if mode == "stream":
            # gather-free: the kernel's scalar-prefetched table resolves
            # each KV tile's pool address — the streamed chain never
            # materializes a per-request contiguous copy
            attn = paged_decode_attention(
                q, kc, vc, block_table, positions + 1,
                k_scale=ks, v_scale=vs,
                use_pallas=use_kernels, interpret=interpret)
            attn_done = True
        else:
            T = block_table.shape[1]
            k_view = kc[block_table].reshape(B, T * bs_blk, *kc.shape[2:])
            v_view = vc[block_table].reshape(B, T * bs_blk, *vc.shape[2:])
            if quantized:
                k_view = k_view.astype(jnp.float32) * ks[
                    block_table].reshape(B, T * bs_blk, kpr)[..., None]
                v_view = v_view.astype(jnp.float32) * vs[
                    block_table].reshape(B, T * bs_blk, kpr)[..., None]
            attn_done = False
    else:
        def upd(c, n, pos):
            return jax.lax.dynamic_update_slice_in_dim(
                c, n[None].astype(c.dtype), pos, axis=0)
        kc = jax.vmap(upd)(cache["k"], k_new, positions)
        vc = jax.vmap(upd)(cache["v"], v_new, positions)
        k_view, v_view = kc, vc
        attn_done = False

    if not attn_done:
        attn = decode_attention(q, k_view, v_view, positions + 1,
                                use_pallas=use_kernels, interpret=interpret)
    wo = p["attn"]["wo"].reshape(qpr * dh, D)
    x = x + _mm(attn.reshape(B, -1), wo, None, use_kernels=use_kernels,
                interpret=interpret, quantize=qw)

    h = apply_norm(p["ln2"], x, cfg.norm)
    if "wg" in p["mlp"]:
        w1 = jnp.concatenate([p["mlp"]["wg"], p["mlp"]["wu"]], -1)
        gu = _mm(h, w1, None, use_kernels=use_kernels, interpret=interpret,
                 quantize=qw)
        g, u = jnp.split(gu, 2, -1)
        act = jax.nn.silu(g) * u if cfg.activation == "silu" else \
            jax.nn.gelu(g) * u
    else:
        act = _mm(h, p["mlp"]["wi"], p["mlp"].get("bi"),
                  use_kernels=use_kernels, interpret=interpret, quantize=qw)
        act = jax.nn.relu(act) if cfg.activation == "relu" else \
            jax.nn.gelu(act)
    y = _mm(act, p["mlp"]["wd"], p["mlp"].get("bd"),
            use_kernels=use_kernels, interpret=interpret, quantize=qw)
    new_cache = {"k": kc, "v": vc}
    if quantized:
        new_cache["k_scale"], new_cache["v_scale"] = ks, vs
    return x + y, new_cache


def chunk_prefill_layer(p: Params, x: jax.Array,
                        cache: Dict[str, jax.Array],
                        block_table: jax.Array, start: jax.Array,
                        n_valid: jax.Array, *, cfg, plan,
                        use_kernels: bool = True, interpret: bool = True,
                        paged_kernel: str = "auto"
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decoder layer over ONE prefill chunk, single device.

    The prefill-with-initial-carry entry of the streamed chain: a chunk
    of C prompt tokens runs through the SAME streamed ops as decode —
    the fused QKV / O / FC gemvs simply carry C rows instead of one,
    and attention REUSES the paged decode kernel's online-softmax fold
    by treating the chunk as a batch of C single-token queries with
    per-query valid lengths ``start + i + 1`` over the request's
    (broadcast) block table.  Causality over history + the chunk's own
    causal prefix falls out of the kernel's length masking; nothing new
    is lowered for prefill.

    x: (C, D) chunk activations; cache: {'k','v': (N, bs, G, dh)} the
    shared pool; block_table: (T,); start: absolute offset of the chunk;
    n_valid: valid rows (padded tail rows land in the null block 0).
    Returns (y (C, D), updated pool).
    """
    a = plan.attn
    C, D = x.shape
    qpr, kpr, dh = a.q_per_rank, a.kv_per_rank, a.d_head

    h = apply_norm(p["ln1"], x, cfg.norm)
    wq = p["attn"]["wq"].reshape(D, qpr * dh)
    wk = p["attn"]["wk"].reshape(D, kpr * dh)
    wv = p["attn"]["wv"].reshape(D, kpr * dh)
    wqkv = jnp.concatenate([wq, wk, wv], -1)
    bqkv = None
    if "bq" in p["attn"]:
        bqkv = jnp.concatenate([p["attn"][k].reshape(-1)
                                for k in ("bq", "bk", "bv")])
    qkv = _mm(h, wqkv, bqkv, use_kernels=use_kernels, interpret=interpret)
    q, k_new, v_new = jnp.split(qkv, [qpr * dh, (qpr + kpr) * dh], -1)
    q = q.reshape(C, qpr, dh)
    k_new = k_new.reshape(C, kpr, dh)
    v_new = v_new.reshape(C, kpr, dh)
    positions = start + jnp.arange(C, dtype=jnp.int32)
    if cfg.positional == "rope":
        q = apply_rope(q[None], positions[None], cfg.rope_theta)[0]
        k_new = apply_rope(k_new[None], positions[None], cfg.rope_theta)[0]

    from repro.serving.kv_cache import scatter_chunk_rows
    valid = positions < start + n_valid
    kc = scatter_chunk_rows(cache["k"], k_new, block_table, positions,
                            valid)
    vc = scatter_chunk_rows(cache["v"], v_new, block_table, positions,
                            valid)
    bs_blk = kc.shape[1]
    lens = jnp.minimum(positions + 1, start + n_valid)
    mode = resolve_paged_kernel(plan, bs_blk, paged_kernel,
                                interpret=interpret)
    if mode == "stream":
        tabs = jnp.broadcast_to(block_table[None],
                                (C, block_table.shape[0]))
        attn = paged_decode_attention(q, kc, vc, tabs, lens,
                                      use_pallas=use_kernels,
                                      interpret=interpret)
    else:
        T = block_table.shape[0]
        k_view = jnp.broadcast_to(
            kc[block_table].reshape(1, T * bs_blk, *kc.shape[2:]),
            (C, T * bs_blk) + kc.shape[2:])
        v_view = jnp.broadcast_to(
            vc[block_table].reshape(1, T * bs_blk, *vc.shape[2:]),
            (C, T * bs_blk) + vc.shape[2:])
        attn = decode_attention(q, k_view, v_view, lens,
                                use_pallas=use_kernels,
                                interpret=interpret)
    wo = p["attn"]["wo"].reshape(qpr * dh, D)
    x = x + _mm(attn.reshape(C, -1), wo, None, use_kernels=use_kernels,
                interpret=interpret)

    h = apply_norm(p["ln2"], x, cfg.norm)
    if "wg" in p["mlp"]:
        w1 = jnp.concatenate([p["mlp"]["wg"], p["mlp"]["wu"]], -1)
        gu = _mm(h, w1, None, use_kernels=use_kernels, interpret=interpret)
        g, u = jnp.split(gu, 2, -1)
        act = jax.nn.silu(g) * u if cfg.activation == "silu" else \
            jax.nn.gelu(g) * u
    else:
        act = _mm(h, p["mlp"]["wi"], p["mlp"].get("bi"),
                  use_kernels=use_kernels, interpret=interpret)
        act = jax.nn.relu(act) if cfg.activation == "relu" else \
            jax.nn.gelu(act)
    y = _mm(act, p["mlp"]["wd"], p["mlp"].get("bd"),
            use_kernels=use_kernels, interpret=interpret)
    return x + y, {"k": kc, "v": vc}


def verify_layer(p: Params, x: jax.Array, cache: Dict[str, jax.Array],
                 block_tables: jax.Array, positions: jax.Array, *, cfg,
                 plan, use_kernels: bool = True, interpret: bool = True,
                 paged_kernel: str = "auto", w_dtype: str = "auto"
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decoder layer over one speculative verify window.

    A verify window flattens every slot's (last token + k drafts) into
    Q = B*(k+1) single-token queries, each with its OWN block table and
    absolute position.  That is EXACTLY the streamed decode dataflow:
    :func:`decode_layer` scatters all Q new KV rows into the pool first
    and then attends each query over ``positions + 1`` tokens through
    its table — so draft i sees drafts < i of the same window (their
    positions are smaller) plus all resident history, with zero new
    kernel code.  This delegate exists to name that contract; the
    full-model analogue is :func:`repro.models.attention.verify_attention`.

    x: (Q, D); block_tables: (Q, T); positions: (Q,).
    """
    return decode_layer(p, x, cache, positions, cfg=cfg, plan=plan,
                        use_kernels=use_kernels, interpret=interpret,
                        block_table=block_tables,
                        paged_kernel=paged_kernel, w_dtype=w_dtype)


def stream_bytes_per_layer(cfg, plan, kv_len: int) -> int:
    """Analytic bytes streamed per token per layer (latency model input)."""
    a = plan.attn
    d = cfg.d_model
    wbytes = 2 * (d * (a.hp + 2 * a.gp) * a.d_head // plan.tp
                  + a.hp * a.d_head * d // plan.tp)
    n_mat = 3 if cfg.mlp_gated else 2
    wbytes += 2 * n_mat * d * plan.d_ff_padded // plan.tp
    kv_bytes = 2 * 2 * kv_len * (a.gp // plan.tp) * a.d_head
    return wbytes + kv_bytes
