"""Pure-jnp oracle for the selective (S6) scan."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def mamba_scan_ref(da: jax.Array, bx: jax.Array, c: jax.Array,
                   h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """h_t = da_t * h_{t-1} + bx_t ; y_t[c] = sum_n C_t[n] * h_t[c,n].

    da, bx: (B,S,C,N) f32; c: (B,S,N) f32; h0: (B,C,N) f32.
    Returns (y (B,S,C), h_final (B,C,N)).
    """
    def step(h, inp):
        a_t, b_t, c_t = inp
        h = a_t * h + b_t
        y = jnp.einsum("bcn,bn->bc", h, c_t)
        return h, y

    xs = (da.transpose(1, 0, 2, 3), bx.transpose(1, 0, 2, 3),
          c.transpose(1, 0, 2))
    h_fin, ys = lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h_fin
