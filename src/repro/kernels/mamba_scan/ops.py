"""jit'd wrapper for the selective scan kernel."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from repro.kernels.mamba_scan.mamba_scan import mamba_scan_pallas
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def mamba_scan(da: jax.Array, bx: jax.Array, c: jax.Array, h0: jax.Array,
               *, use_pallas: bool = True, interpret: bool = True
               ) -> Tuple[jax.Array, jax.Array]:
    B, S, C, N = da.shape
    if not use_pallas or S % 8 or C % 8:
        return mamba_scan_ref(da, bx, c, h0)
    bs = 128
    while S % bs:
        bs //= 2
    bc = 128
    while C % bc:
        bc //= 2
    return mamba_scan_pallas(da, bx, c, h0, block_s=max(bs, 8),
                             block_c=max(bc, 8), interpret=interpret)
