"""Selective-scan (Mamba S6) Pallas kernel.

TPU adaptation of the CUDA selective-scan: instead of one thread block
per channel chunk with warp-level scans, we give each (batch, channel
tile) a *sequential walk over seq chunks* (grid minor axis) with the
recurrent state held in VMEM scratch — the TPU idiom for carried state
(same pattern as the LPU's output-stationary accumulators).  Within a
chunk the recurrence is a short fori_loop over VREG-resident rows; the
channel tile (C_blk x N) keeps the VPU lanes full.

Streaming structure mirrors C1: (da, bx, c) tiles stream HBM->VMEM once,
state never leaves VMEM — byte traffic is exactly the input size, i.e.
the kernel sits on the bandwidth roofline like everything else in the
decode path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(da_ref, bx_ref, c_ref, h0_ref, y_ref, hout_ref, h_ref,
                 *, s_tiles: int, block_s: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    da = da_ref[0]                                   # (block_s, C_blk, N)
    bx = bx_ref[0]
    cc = c_ref[0]                                    # (block_s, N)

    def step(i, h):
        h = da[i] * h + bx[i]                        # (C_blk, N)
        y_ref[0, i] = jnp.sum(h * cc[i][None, :], axis=-1)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h

    @pl.when(t == s_tiles - 1)
    def _flush():
        hout_ref[0] = h_ref[...]


def mamba_scan_pallas(da: jax.Array, bx: jax.Array, c: jax.Array,
                      h0: jax.Array, *, block_s: int = 128,
                      block_c: int = 128, interpret: bool = True
                      ) -> Tuple[jax.Array, jax.Array]:
    """da, bx: (B,S,C,N); c: (B,S,N); h0: (B,C,N) -> (y (B,S,C), h (B,C,N))."""
    B, S, C, N = da.shape
    block_s = min(block_s, S)
    block_c = min(block_c, C)
    assert S % block_s == 0 and C % block_c == 0
    s_tiles = S // block_s
    c_tiles = C // block_c

    kernel = functools.partial(_scan_kernel, s_tiles=s_tiles,
                               block_s=block_s)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(B, c_tiles, s_tiles),
        in_specs=[
            pl.BlockSpec((1, block_s, block_c, N),
                         lambda b, cb, t: (b, t, cb, 0)),
            pl.BlockSpec((1, block_s, block_c, N),
                         lambda b, cb, t: (b, t, cb, 0)),
            pl.BlockSpec((1, block_s, N), lambda b, cb, t: (b, t, 0)),
            pl.BlockSpec((1, block_c, N), lambda b, cb, t: (b, cb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_c), lambda b, cb, t: (b, t, cb)),
            pl.BlockSpec((1, block_c, N), lambda b, cb, t: (b, cb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, C), da.dtype),
            jax.ShapeDtypeStruct((B, C, N), h0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_c, N), jnp.float32)],
        interpret=interpret,
    )(da, bx, c, h0)
    return y, h_fin
