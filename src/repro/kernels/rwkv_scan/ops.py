"""jit'd wrapper for the WKV kernel."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from repro.kernels.rwkv_scan.ref import rwkv_scan_ref
from repro.kernels.rwkv_scan.rwkv_scan import rwkv_scan_pallas


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rwkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, s0: jax.Array, *, use_pallas: bool = True,
              interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    B, S, H, dh = r.shape
    if not use_pallas or S % 8:
        return rwkv_scan_ref(r, k, v, w, u, s0)
    bs = 128
    while S % bs:
        bs //= 2
    return rwkv_scan_pallas(r, k, v, w, u, s0, block_s=max(bs, 8),
                            interpret=interpret)
