"""RWKV6 WKV recurrence as a Pallas TPU kernel.

The data-dependent-decay state update is a rank-1 outer-product
accumulation per head — on a GPU this is a per-warp shared-memory loop;
the TPU-native form keeps the (dh x dh) state matrix resident in VMEM
scratch per (batch, head-tile) while (r,k,v,w) stream through in seq
chunks (grid minor axis), and expresses each step as VPU outer products.
Like the LPU's generation stage, bytes moved = the streamed operands;
the state never touches HBM until the final flush.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                s_ref, *, s_tiles: int, block_s: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0]

    u = u_ref[0]                                     # (dh,)

    def step(i, s):
        rt = r_ref[0, i, 0]                          # (dh,)
        kt = k_ref[0, i, 0]
        vt = v_ref[0, i, 0]
        wt = w_ref[0, i, 0]
        kv = kt[:, None] * vt[None, :]               # (dh, dh)
        y = jnp.sum((s + u[:, None] * kv) * rt[:, None], axis=0)
        y_ref[0, i, 0] = y
        return wt[:, None] * s + kv

    s = jax.lax.fori_loop(0, block_s, step, s_ref[...])
    s_ref[...] = s

    @pl.when(t == s_tiles - 1)
    def _flush():
        sout_ref[0, 0] = s_ref[...]


def rwkv_scan_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                     u: jax.Array, s0: jax.Array, *, block_s: int = 128,
                     interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,S,H,dh) f32; u: (H,dh); s0: (B,H,dh,dh)."""
    B, S, H, dh = r.shape
    block_s = min(block_s, S)
    assert S % block_s == 0
    s_tiles = S // block_s

    kernel = functools.partial(_wkv_kernel, s_tiles=s_tiles,
                               block_s=block_s)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(B, H, s_tiles),
        in_specs=[
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, dh), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, dh), r.dtype),
            jax.ShapeDtypeStruct((B, H, dh, dh), s0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_fin
