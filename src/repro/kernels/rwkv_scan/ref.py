"""Pure-jnp oracle for the RWKV6 WKV recurrence."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def rwkv_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array, s0: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S + k v^T.

    r,k,v,w: (B,S,H,dh) f32; u: (H,dh); s0: (B,H,dh,dh).
    Returns (y (B,S,H,dh), s_final).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    s_fin, ys = lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s_fin
