from repro.kernels.rwkv_scan.ops import rwkv_scan
from repro.kernels.rwkv_scan.ref import rwkv_scan_ref

__all__ = ["rwkv_scan", "rwkv_scan_ref"]
