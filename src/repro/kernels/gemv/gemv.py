"""Streamlined decode GEMV — the SXE/SMA dataflow as a Pallas TPU kernel.

LPU C1: during generation the operand is one activation *vector* per
sequence; performance == how fast weights stream HBM -> compute.  The
kernel keeps the activation block **stationary in VMEM** (the LPU's
register-file operand) while weight tiles stream through a
(K_blk, N_blk) VMEM window (the SMA burst), accumulating
output-stationary f32 partials in scratch — the MAC-tree accumulator.

Grid: (N_tiles, K_tiles); K is the *minor* (fastest) axis so each output
tile sees its full reduction before the next begins — the paper's
"vertical tile order [that] reduces partial-sum buffers".

Tile sizing (ops.py): the (K_blk, N_blk) window is chosen so the weight
stream saturates HBM while fitting VMEM — the LPU's
``I x v x 2B x freq = BW`` balance condition expressed as a BlockSpec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemv_kernel(x_ref, w_ref, b_ref, s_ref, o_ref, acc_ref, *,
                 k_tiles: int, has_bias: bool, has_scale: bool):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (B, K_blk)  stationary
    w = w_ref[...].astype(jnp.float32)          # (K_blk, N_blk) streamed
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == k_tiles - 1)
    def _flush():
        acc = acc_ref[...]
        if has_scale:
            # int8 weight tiles: one absmax scale per output column,
            # applied ONCE at the f32 flush (before the fp bias) so the
            # stream stays quantized end to end
            acc = acc * s_ref[...].astype(jnp.float32)
        if has_bias:
            acc = acc + b_ref[...].astype(jnp.float32)
        o_ref[...] = acc.astype(o_ref.dtype)


def gemv_pallas(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
                w_scale: jax.Array | None = None,
                block_n: int = 512, block_k: int = 512,
                interpret: bool = True) -> jax.Array:
    """x: (B, K); w: (K, N); optional b: (N,) -> (B, N).

    B (decode batch per device) stays whole — it is tiny by design.
    ``w_scale`` (N,) marks ``w`` as int8 per-output-column quantized;
    the scale tile rides the same (1, N_blk) window as the bias.
    """
    B, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    block_k = min(block_k, K)
    block_n = min(block_n, N)
    assert K % block_k == 0 and N % block_n == 0, (K, N, block_k, block_n)
    k_tiles = K // block_k
    n_tiles = N // block_n
    has_bias = b is not None
    has_scale = w_scale is not None
    if b is None:
        b = jnp.zeros((N,), x.dtype)
    b2 = b.reshape(1, N)
    s2 = (w_scale if w_scale is not None
          else jnp.ones((N,), jnp.float32)).reshape(1, N)

    kernel = functools.partial(_gemv_kernel, k_tiles=k_tiles,
                               has_bias=has_bias, has_scale=has_scale)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles, k_tiles),
        in_specs=[
            pl.BlockSpec((B, block_k), lambda n, k: (0, k)),
            pl.BlockSpec((block_k, block_n), lambda n, k: (k, n)),
            pl.BlockSpec((1, block_n), lambda n, k: (0, n)),
            pl.BlockSpec((1, block_n), lambda n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((B, block_n), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((B, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, b2, s2)
