from repro.kernels.gemv.ops import gemv
from repro.kernels.gemv.ref import gemv_ref

__all__ = ["gemv", "gemv_ref"]
