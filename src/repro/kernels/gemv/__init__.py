from repro.kernels.gemv.ops import gemv, plan_blocks, quantize_weight
from repro.kernels.gemv.ref import gemv_ref

__all__ = ["gemv", "gemv_ref", "plan_blocks", "quantize_weight"]
