"""jit'd wrapper + tile planner for the decode GEMV kernel.

``plan_blocks`` realizes the LPU balance condition on TPU: pick the
largest (K_blk, N_blk) weight window that (a) fits the VMEM budget with
double-buffering and (b) keeps both dims 128-aligned so the MXU runs at
full tile occupancy.  The weight stream then saturates HBM — arithmetic
intensity of GEMV is ~1 flop/byte, far below the ridge, so bandwidth is
the roofline and the only job of the BlockSpec is to never stall the
stream.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.gemv.gemv import gemv_pallas
from repro.kernels.gemv.ref import gemv_ref

VMEM_BYTES = 64 * 2 ** 20          # ~64 MiB/core budget (v5e: 128 MiB/chip)
LANE = 128


def plan_blocks(B: int, K: int, N: int, dtype_bytes: int = 2,
                vmem_budget: int = VMEM_BYTES // 2) -> Tuple[int, int]:
    """Largest aligned (block_k, block_n) with 2x buffering in budget."""
    def fits(bk, bn):
        w_tile = bk * bn * dtype_bytes * 2          # double-buffered stream
        x_tile = B * bk * dtype_bytes
        acc = B * bn * 4
        return w_tile + x_tile + acc <= vmem_budget

    best = (LANE, LANE)
    bk = min(K, 2048)
    while bk >= LANE:
        if K % bk == 0:
            bn = min(N, 2048)
            while bn >= LANE:
                if N % bn == 0 and fits(bk, bn):
                    if bk * bn > best[0] * best[1]:
                        best = (bk, bn)
                    break
                bn //= 2
        bk //= 2
    return best


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gemv(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
         use_pallas: bool = True, interpret: bool = True) -> jax.Array:
    """Decode GEMV: (B,K) x (K,N) -> (B,N), f32 accumulation."""
    if not use_pallas:
        return gemv_ref(x, w, b)
    B, K = x.shape
    N = w.shape[1]
    if K % LANE or N % LANE:
        return gemv_ref(x, w, b)                   # unaligned: oracle path
    bk, bn = plan_blocks(B, K, N, dtype_bytes=w.dtype.itemsize)
    return gemv_pallas(x, w, b, block_k=bk, block_n=bn,
                       interpret=interpret)
