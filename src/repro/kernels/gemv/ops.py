"""jit'd wrapper + tile planner for the decode GEMV kernel.

``plan_blocks`` realizes the LPU balance condition on TPU: pick the
largest (K_blk, N_blk) weight window that (a) fits the VMEM budget with
double-buffering and (b) keeps both dims 128-aligned so the MXU runs at
full tile occupancy.  The weight stream then saturates HBM — arithmetic
intensity of GEMV is ~1 flop/byte, far below the ridge, so bandwidth is
the roofline and the only job of the BlockSpec is to never stall the
stream.

Int8 weight streaming: ``quantize_weight`` folds a weight matrix to int8
with one absmax scale per output column, ``gemv(..., w_scale=...)``
streams the int8 tiles and applies the scale once per output tile at the
f32 flush — the weight stream (the decode roofline term) halves while
the activation stays fp, which is why ``plan_blocks`` sizes the two
operands from their OWN itemsizes.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.gemv.gemv import gemv_pallas
from repro.kernels.gemv.ref import gemv_ref

VMEM_BYTES = 64 * 2 ** 20          # ~64 MiB/core budget (v5e: 128 MiB/chip)
LANE = 128


def plan_blocks(B: int, K: int, N: int, w_bytes: int = 2,
                x_bytes: int = 0,
                vmem_budget: int = VMEM_BYTES // 2) -> Tuple[int, int]:
    """Largest aligned (block_k, block_n) with 2x buffering in budget.

    The double-buffered weight stream and the stationary activation tile
    are sized from their OWN itemsizes (``x_bytes`` defaults to
    ``w_bytes`` for uniform-precision callers): with int8 weights and
    fp16/fp32 activations a shared byte width either starves the window
    (activation width applied to the stream) or overflows VMEM (weight
    width applied to the activation).
    """
    x_bytes = x_bytes or w_bytes

    def fits(bk, bn):
        w_tile = bk * bn * w_bytes * 2              # double-buffered stream
        x_tile = B * bk * x_bytes
        acc = B * bn * 4
        return w_tile + x_tile + acc <= vmem_budget

    best = (LANE, LANE)
    bk = min(K, 2048)
    while bk >= LANE:
        if K % bk == 0:
            bn = min(N, 2048)
            while bn >= LANE:
                if N % bn == 0 and fits(bk, bn):
                    if bk * bn > best[0] * best[1]:
                        best = (bk, bn)
                    break
                bn //= 2
        bk //= 2
    return best


def quantize_weight(w: jax.Array,
                    store_dtype=jnp.int8) -> Tuple[jax.Array, jax.Array]:
    """Absmax-quantize a (K, N) weight matrix per OUTPUT column.

    Returns ``(q, scale)``: ``q`` is (K, N) in ``store_dtype`` and
    ``scale`` is (N,) f32 — one scale per output tile column, applied at
    the kernel's f32 flush so the streamed bytes halve while the
    accumulation precision is unchanged.  All-zero columns get scale 0.
    """
    qmax = 127.0
    x = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=0)
    scale = amax / qmax
    y = x / jnp.where(scale > 0, scale, 1.0)[None, :]
    q = jnp.clip(jnp.round(y), -qmax, qmax).astype(store_dtype)
    return q, scale


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gemv(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
         w_scale: Optional[jax.Array] = None, use_pallas: bool = True,
         interpret: bool = True) -> jax.Array:
    """Decode GEMV: (B,K) x (K,N) -> (B,N), f32 accumulation.

    ``w_scale`` (N,) marks ``w`` as int8-quantized per output column;
    the kernel multiplies it into the f32 accumulator before the bias.
    """
    if not use_pallas:
        return gemv_ref(x, w, b, w_scale=w_scale)
    B, K = x.shape
    N = w.shape[1]
    if K % LANE or N % LANE:
        return gemv_ref(x, w, b, w_scale=w_scale)  # unaligned: oracle path
    bk, bn = plan_blocks(B, K, N, w_bytes=w.dtype.itemsize,
                         x_bytes=x.dtype.itemsize)
    return gemv_pallas(x, w, b, w_scale=w_scale, block_k=bk, block_n=bn,
                       interpret=interpret)
