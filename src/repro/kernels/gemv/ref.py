"""Pure-jnp oracle for the streamlined decode GEMV."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemv_ref(x: jax.Array, w: jax.Array,
             b: jax.Array | None = None, *,
             w_scale: jax.Array | None = None) -> jax.Array:
    """x: (B, K) activation vectors; w: (K, N) streamed weights.

    f32 accumulation, output in x.dtype — matches the kernel contract.
    ``w_scale`` (N,) dequantizes int8 weights at the accumulator, the
    same order of operations as the kernel's flush (scale, then bias).
    """
    y = jnp.einsum("bk,kn->bn", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    if w_scale is not None:
        y = y * w_scale.astype(jnp.float32)[None, :]
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)
