"""Pure-jnp oracle for the streamlined decode GEMV."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemv_ref(x: jax.Array, w: jax.Array,
             b: jax.Array | None = None) -> jax.Array:
    """x: (B, K) activation vectors; w: (K, N) streamed weights.

    f32 accumulation, output in x.dtype — matches the kernel contract.
    """
    y = jnp.einsum("bk,kn->bn", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)
