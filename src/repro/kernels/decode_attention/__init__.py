from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                gather_kv_pages,
                                                paged_decode_attention_ref)

__all__ = ["decode_attention", "decode_attention_ref",
           "paged_decode_attention", "paged_decode_attention_ref",
           "gather_kv_pages"]
