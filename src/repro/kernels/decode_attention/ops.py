"""jit'd wrapper for fused decode attention with KV-tile planning.

``block_s`` sizing: the KV stream tile is (block_s, dh) per K and V; the
kernel is bandwidth-bound (intensity ~ 1 flop/byte), so like the GEMV we
choose the largest 128-aligned tile fitting the double-buffered VMEM
budget — keeping the cache stream saturated is the whole game.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas, paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, gather_kv_pages)

LANE = 128
VMEM_BUDGET = 32 * 2 ** 20


def default_interpret() -> bool:
    """Pallas interpret mode off-TPU (CPU CI runs the same kernel code)."""
    return jax.default_backend() != "tpu"


def paged_stream_supported(plan, block_size: Optional[int] = None,
                           interpret: Optional[bool] = None) -> bool:
    """True when paged decode can stream through the Pallas kernel.

    Two conditions: the plan's stored GQA layout must be block-regular
    (the kernel maps q head ``h`` to kv head ``h // gs`` with no
    per-head gather), and — compiled on TPU only — the KV tile must be
    LANE-aligned (``block_size`` and ``d_head`` multiples of 128).
    ``interpret=None`` derives the mode from the backend, matching what
    the kernel call will actually do.  Resolving eligibility *here*
    keeps the dispatch honest: a misaligned "stream" request resolves
    to gather up front (and is accounted as gather) instead of
    silently falling back inside the kernel wrapper while the engine
    reports streamed statistics."""
    a = plan.attn
    if a is None or not a.block_regular:
        return False
    if interpret is None:
        interpret = default_interpret()
    if block_size is not None and not interpret and \
            (block_size % LANE or a.d_head % LANE):
        return False
    return True


def resolve_paged_kernel(plan, block_size: int, requested: str,
                         interpret: Optional[bool] = None) -> str:
    """Resolve a ``paged_kernel`` request to the dataflow that will run.

    ``"auto"`` becomes ``"stream"`` when :func:`paged_stream_supported`
    allows it, else ``"gather"``; an explicit ``"stream"`` on an
    ineligible plan raises instead of silently degrading.  Every
    dispatch site (model decode, streamline decode_layer, the serving
    engine) resolves through this one function so they can never
    disagree."""
    if requested not in ("auto", "stream", "gather"):
        raise ValueError(f"paged_kernel={requested!r} not in "
                         "('auto', 'stream', 'gather')")
    ok = paged_stream_supported(plan, block_size, interpret)
    if requested == "auto":
        return "stream" if ok else "gather"
    if requested == "stream" and not ok:
        raise ValueError(
            "paged_kernel='stream' needs a block-regular stored GQA "
            "layout and (compiled on TPU) LANE-aligned block_size/"
            f"d_head; plan for {plan.arch} with block_size={block_size} "
            "cannot stream (use 'gather' or 'auto')")
    return requested


def plan_block_s(S: int, dh: int, gs: int, dtype_bytes: int = 2,
                 override: int = 0) -> int:
    """Pick the KV stream tile: largest 128-aligned divisor of ``S``
    whose double-buffered K+V footprint fits the VMEM budget.

    ``override`` (the ``--block-s`` knob) short-circuits the search so
    real-hardware runs can sweep tile sizes against this planner — the
    ROADMAP's tune-on-TPU item.  It is clamped to ``S`` and must divide
    it (the kernels' grids assume exact tiling).
    """
    if override:
        bs = min(override, S)
        if S % bs:
            raise ValueError(
                f"block_s override {override} does not tile S={S}")
        if bs % LANE and bs != S:
            # the compiled kernel's KV tiles must be LANE-aligned (a
            # full-span tile is exempt: the kernel clamps to S) — reject
            # here so a TPU sweep fails at plan time, not Mosaic lowering
            raise ValueError(
                f"block_s override {override} is not LANE({LANE})-"
                f"aligned (or the full span {S})")
        return bs
    bs = min(S, 4096)
    while bs > LANE:
        tile = 2 * bs * dh * dtype_bytes * 2     # K+V, double-buffered
        if S % bs == 0 and tile <= VMEM_BUDGET:
            return bs
        bs //= 2
    return max(LANE, bs)


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "block_s"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, use_pallas: bool = True,
                     interpret: bool = True,
                     block_s: int = 0) -> jax.Array:
    """q: (B,H,dh); k,v: (B,S,G,dh); lengths: (B,) -> (B,H,dh).

    ``block_s`` overrides the planned KV stream tile (0 = let
    :func:`plan_block_s` choose).
    """
    B, H, dh = q.shape
    S, G = k.shape[1], k.shape[2]
    if (not use_pallas) or H % G or S % LANE or dh % LANE:
        # oracle fallback (expand KV to H heads)
        gs = max(H // G, 1)
        ke = jnp.repeat(k, gs, axis=2)[:, :, :H]
        ve = jnp.repeat(v, gs, axis=2)[:, :, :H]
        return decode_attention_ref(q, ke, ve, lengths)
    bs = plan_block_s(S, dh, H // G, k.dtype.itemsize, override=block_s)
    return decode_attention_pallas(q, k, v, lengths, block_s=bs,
                                   interpret=interpret)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           k_new: Optional[jax.Array] = None,
                           v_new: Optional[jax.Array] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           use_pallas: bool = True,
                           interpret: bool = True) -> jax.Array:
    """Paged decode attention over a shared block pool.

    q: (B,H,dh); k_pages,v_pages: (N,bs,G,dh); block_tables: (B,T);
    lengths: (B,) -> (B,H,dh).  The pallas path streams KV tiles straight
    from the pool through the block-table indirection (no contiguous copy);
    the fallback gathers the per-request view and reuses the dense oracle.

    ``k_new/v_new`` ((B,G,dh)): the current token's K/V, attended *in
    addition to* the ``lengths`` resident positions — the pool is read
    pre-update and the caller scatters the new row afterwards, so decode
    never rewrites (or copies) the pool to append one token.  The LANE
    alignment guard only applies to compiled TPU tiles; interpret mode
    (CPU CI) streams any block size.

    ``k_scale/v_scale`` ((N,bs,G)): absmax scale side-arrays of a
    quantized (int8/fp8) pool.  The stream path dequantizes inside the
    kernel's tile loop; the gather-oracle path dequantizes the gathered
    view — both read the pool at the quantized byte width.
    """
    B, H, dh = q.shape
    bs, G = k_pages.shape[1], k_pages.shape[2]
    misaligned = (bs % LANE or dh % LANE) and not interpret
    if (not use_pallas) or H % G or misaligned:
        gs = max(H // G, 1)
        kg = gather_kv_pages(k_pages, block_tables)
        vg = gather_kv_pages(v_pages, block_tables)
        if k_scale is not None:
            kg = kg.astype(jnp.float32) * gather_kv_pages(
                k_scale, block_tables).astype(jnp.float32)[..., None]
            vg = vg.astype(jnp.float32) * gather_kv_pages(
                v_scale, block_tables).astype(jnp.float32)[..., None]
        ke = jnp.repeat(kg, gs, axis=2)[:, :, :H]
        ve = jnp.repeat(vg, gs, axis=2)[:, :, :H]
        if k_new is not None:
            # oracle fold: mask-scatter the new token at its position in
            # the gathered view, extend the valid length by one
            kn = jnp.repeat(k_new, gs, axis=1)[:, :H]
            vn = jnp.repeat(v_new, gs, axis=1)[:, :H]

            def put(view, row, pos):
                return jax.lax.dynamic_update_slice(
                    view, row[None].astype(view.dtype), (pos, 0, 0))
            ke = jax.vmap(put)(ke, kn, lengths)
            ve = jax.vmap(put)(ve, vn, lengths)
            return decode_attention_ref(q, ke, ve, lengths + 1)
        return decode_attention_ref(q, ke, ve, lengths)
    return paged_decode_attention_pallas(q, k_pages, v_pages, block_tables,
                                         lengths, k_new=k_new, v_new=v_new,
                                         k_scale=k_scale, v_scale=v_scale,
                                         interpret=interpret)
