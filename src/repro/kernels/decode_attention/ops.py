"""jit'd wrapper for fused decode attention with KV-tile planning.

``block_s`` sizing: the KV stream tile is (block_s, dh) per K and V; the
kernel is bandwidth-bound (intensity ~ 1 flop/byte), so like the GEMV we
choose the largest 128-aligned tile fitting the double-buffered VMEM
budget — keeping the cache stream saturated is the whole game.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas, paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, gather_kv_pages)

LANE = 128
VMEM_BUDGET = 32 * 2 ** 20


def plan_block_s(S: int, dh: int, gs: int, dtype_bytes: int = 2) -> int:
    bs = min(S, 4096)
    while bs > LANE:
        tile = 2 * bs * dh * dtype_bytes * 2     # K+V, double-buffered
        if S % bs == 0 and tile <= VMEM_BUDGET:
            return bs
        bs //= 2
    return max(LANE, bs)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, use_pallas: bool = True,
                     interpret: bool = True) -> jax.Array:
    """q: (B,H,dh); k,v: (B,S,G,dh); lengths: (B,) -> (B,H,dh)."""
    B, H, dh = q.shape
    S, G = k.shape[1], k.shape[2]
    if (not use_pallas) or H % G or S % LANE or dh % LANE:
        # oracle fallback (expand KV to H heads)
        gs = max(H // G, 1)
        ke = jnp.repeat(k, gs, axis=2)[:, :, :H]
        ve = jnp.repeat(v, gs, axis=2)[:, :, :H]
        return decode_attention_ref(q, ke, ve, lengths)
    bs = plan_block_s(S, dh, H // G, k.dtype.itemsize)
    return decode_attention_pallas(q, k, v, lengths, block_s=bs,
                                   interpret=interpret)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *, use_pallas: bool = True,
                           interpret: bool = True) -> jax.Array:
    """Paged decode attention over a shared block pool.

    q: (B,H,dh); k_pages,v_pages: (N,bs,G,dh); block_tables: (B,T);
    lengths: (B,) -> (B,H,dh).  The pallas path streams KV tiles straight
    from the pool through the block-table indirection (no contiguous copy);
    the fallback gathers the per-request view and reuses the dense oracle.
    """
    B, H, dh = q.shape
    bs, G = k_pages.shape[1], k_pages.shape[2]
    if (not use_pallas) or H % G or bs % LANE or dh % LANE:
        gs = max(H // G, 1)
        ke = jnp.repeat(gather_kv_pages(k_pages, block_tables), gs,
                        axis=2)[:, :, :H]
        ve = jnp.repeat(gather_kv_pages(v_pages, block_tables), gs,
                        axis=2)[:, :, :H]
        return decode_attention_ref(q, ke, ve, lengths)
    return paged_decode_attention_pallas(q, k_pages, v_pages, block_tables,
                                         lengths, interpret=interpret)
