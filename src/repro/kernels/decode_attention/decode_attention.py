"""Fused decode attention — the SXE∥VXE dual-path timeline (Fig. 3b).

One generated token attends a streamed KV cache.  The paper's dataflow:
the Key tile streams into the matmul path (SXE) producing a Score, the
softmax (VXE) of tile *i* runs while tile *i+1*'s dot executes, and the
Value product accumulates output-stationary.  On TPU the same overlap
falls out of a single fused kernel: MXU dots and VPU exp/max/sum issue
concurrently per KV tile with an online-softmax carry in VMEM scratch.

Grid: (B, G, S_tiles) — S minor, so the carry (m, l, acc) lives in
scratch across the KV stream.  GQA: all ``gs`` query heads of a KV head
are processed together, so each KV tile is read exactly once per group —
and the cache layout is already (seq-major, head-minor), the mapper's
"natural transpose": no transpose op ever materializes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, s_tiles: int, block_s: int,
                   scale: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (gs, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (block_s, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (gs,S_blk)
    length = len_ref[0]
    pos = t * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, -1e30)

    m_prev = m_ref[...]                                  # (gs, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (gs, dh)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(t == s_tiles - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, *rest,
                         t_blocks: int, block_s: int, scale: float,
                         quantized: bool = False):
    """Paged variant: same online-softmax stream as ``_decode_kernel`` but
    KV tiles are fetched through the block table (scalar-prefetched, so the
    DMA address is known before the body runs — the LPU's address-generator
    indirection).  Tile ``t`` covers logical positions [t*bs, (t+1)*bs).

    With the optional ``kn_ref/vn_ref`` inputs (decode streaming: the cache
    is read *pre-update*), the just-generated token's K/V is folded into
    the online-softmax carry after the last pool tile — the model path's
    read-then-scatter contract, so the pool is never copied to append one
    row.

    ``quantized``: the pool tiles are int8/fp8 and ``ks_ref/vs_ref``
    carry one absmax scale per (row, kv head); dequantization happens
    HERE, inside the tile loop right after the VMEM load, so fp KV
    values never round-trip through HBM — the stream stays at the
    quantized byte width end to end.  The folded new token's K/V stays
    full precision (it is a fresh activation, not pool storage)."""
    rest = list(rest)
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    fold_new = len(rest) == 6
    if fold_new:
        kn_ref, vn_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (gs, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (block_s, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    length = len_ref[b]
    pos = t * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(t == t_blocks - 1)
    def _flush():
        if fold_new:
            kn = kn_ref[0].astype(jnp.float32)          # (1, dh)
            vn = vn_ref[0].astype(jnp.float32)
            s_self = jax.lax.dot_general(
                q, kn, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)     # (gs, 1)
            m_p = m_ref[...]
            m_f = jnp.maximum(m_p, s_self)
            p_self = jnp.exp(s_self - m_f)
            c = jnp.exp(m_p - m_f)
            l_ref[...] = l_ref[...] * c + p_self
            acc_ref[...] = acc_ref[...] * c + p_self * vn
            m_ref[...] = m_f
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_pallas(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array,
                                  block_tables: jax.Array,
                                  lengths: jax.Array, *,
                                  k_new: jax.Array = None,
                                  v_new: jax.Array = None,
                                  k_scale: jax.Array = None,
                                  v_scale: jax.Array = None,
                                  interpret: bool = True) -> jax.Array:
    """q: (B,H,dh); k_pages,v_pages: (N,bs,G,dh) shared pool with H = G*gs;
    block_tables: (B,T) physical block per logical block; lengths: (B,).
    Returns (B,H,dh).  The block table rides scalar prefetch so each KV
    tile's pool address is resolved before its DMA issues.

    ``k_new/v_new`` ((B,G,dh), both or neither): the current token's K/V,
    folded into the softmax carry *after* the streamed pool tiles — used
    by the decode path that reads the cache pre-update and lets the
    caller scatter the new row into the pool afterwards.

    ``k_scale/v_scale`` ((N,bs,G), both or neither): the quantized
    pool's absmax scale side-arrays; their tiles ride the SAME
    block-table indirection as the value tiles and dequantization runs
    inside the tile loop."""
    B, H, dh = q.shape
    N, bs, G, _ = k_pages.shape
    T = block_tables.shape[1]
    assert H % G == 0, (H, G)
    assert (k_new is None) == (v_new is None)
    assert (k_scale is None) == (v_scale is None)
    gs = H // G
    qg = q.reshape(B * G, gs, dh)

    kernel = functools.partial(_paged_decode_kernel, t_blocks=T, block_s=bs,
                               scale=1.0 / math.sqrt(dh),
                               quantized=k_scale is not None)
    in_specs = [
        pl.BlockSpec((1, gs, dh),
                     lambda b, g, t, lens, tbl: (b * G + g, 0, 0)),
        pl.BlockSpec((1, bs, 1, dh),
                     lambda b, g, t, lens, tbl: (tbl[b, t], 0, g, 0)),
        pl.BlockSpec((1, bs, 1, dh),
                     lambda b, g, t, lens, tbl: (tbl[b, t], 0, g, 0)),
    ]
    operands = [lengths, block_tables, qg, k_pages, v_pages]
    if k_scale is not None:
        scale_spec = pl.BlockSpec(
            (1, bs, 1), lambda b, g, t, lens, tbl: (tbl[b, t], 0, g))
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    if k_new is not None:
        new_spec = pl.BlockSpec((1, 1, dh),
                                lambda b, g, t, lens, tbl: (b * G + g, 0, 0))
        in_specs += [new_spec, new_spec]
        operands += [k_new.reshape(B * G, 1, dh),
                     v_new.reshape(B * G, 1, dh)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, G, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, gs, dh),
                               lambda b, g, t, lens, tbl: (b * G + g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gs, 1), jnp.float32),
            pltpu.VMEM((gs, 1), jnp.float32),
            pltpu.VMEM((gs, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * G, gs, dh), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(B, H, dh)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: jax.Array, *, block_s: int = 512,
                            interpret: bool = True) -> jax.Array:
    """q: (B,H,dh); k,v: (B,S,G,dh) with H = G*gs (block-regular GQA);
    lengths: (B,) valid cache length.  Returns (B,H,dh)."""
    B, H, dh = q.shape
    _, S, G, _ = k.shape
    assert H % G == 0, (H, G)
    gs = H // G
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    s_tiles = S // block_s
    qg = q.reshape(B * G, gs, dh)

    kernel = functools.partial(_decode_kernel, s_tiles=s_tiles,
                               block_s=block_s,
                               scale=1.0 / math.sqrt(dh))
    out = pl.pallas_call(
        kernel,
        grid=(B, G, s_tiles),
        in_specs=[
            pl.BlockSpec((1,), lambda b, g, t: (b,)),
            pl.BlockSpec((1, gs, dh), lambda b, g, t: (b * G + g, 0, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, g, t: (b, t, g, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, g, t: (b, t, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, gs, dh), lambda b, g, t: (b * G + g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * G, gs, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gs, 1), jnp.float32),
            pltpu.VMEM((gs, 1), jnp.float32),
            pltpu.VMEM((gs, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, dh)
