"""Pure-jnp oracle for fused decode (flash-decode) attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B,H,dh); k,v: (B,S,H,dh); lengths: (B,) valid cache length.

    Softmax over positions [0, length); f32 accumulation.
    """
    B, S, H, dh = k.shape
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, jnp.finfo(jnp.float32).min / 2)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
