"""Pure-jnp oracle for fused decode (flash-decode) attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gather_kv_pages(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Materialize the contiguous per-request view of a paged KV pool.

    pages: (N, bs, G, dh) shared block pool; block_tables: (B, T) physical
    block id per logical block (entries past the used length point at the
    reserved null block 0 and are masked by ``lengths`` downstream).
    Returns (B, T*bs, G, dh).
    """
    B, T = block_tables.shape
    bs = pages.shape[1]
    g = pages[block_tables]                       # (B, T, bs, G, dh)
    return g.reshape(B, T * bs, *pages.shape[2:])


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array, *,
                               k_scale: jax.Array | None = None,
                               v_scale: jax.Array | None = None
                               ) -> jax.Array:
    """Oracle paged decode attention: gather blocks, run the dense oracle.

    q: (B,H,dh); k_pages,v_pages: (N,bs,H,dh) (head count already expanded
    to H like ``decode_attention_ref``); block_tables: (B,T); lengths: (B,).
    ``k_scale/v_scale`` ((N,bs,H)) dequantize a quantized pool's gathered
    view before the dense oracle runs.
    """
    k = gather_kv_pages(k_pages, block_tables)
    v = gather_kv_pages(v_pages, block_tables)
    if k_scale is not None:
        k = k.astype(jnp.float32) * gather_kv_pages(
            k_scale, block_tables).astype(jnp.float32)[..., None]
        v = v.astype(jnp.float32) * gather_kv_pages(
            v_scale, block_tables).astype(jnp.float32)[..., None]
    return decode_attention_ref(q, k, v, lengths)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B,H,dh); k,v: (B,S,H,dh); lengths: (B,) valid cache length.

    Softmax over positions [0, length); f32 accumulation.
    """
    B, S, H, dh = k.shape
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, jnp.finfo(jnp.float32).min / 2)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
