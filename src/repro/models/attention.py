"""GQA attention under the mapper's stored head layout.

Weights live in the *stored* (padded / duplicated) layout computed by
``repro.compiler.plan.plan_attention``:

* query weights:  (D, Hp, dh)  — Hp stored q heads, padded columns zeroed
* kv weights:     (D, Gp, dh)  — Gp stored kv heads; when ``dup > 1``
  adjacent ranks hold *identical* copies of their shard's kv columns, so
  attention never crosses ranks: every stored q head finds its kv head on
  its own rank (``AttnPlan.q_to_kv_local``).
* output weights: (Hp, dh, D)  — rows of padded q heads zeroed, so padded
  heads contribute exactly nothing.

The QKV projection is issued as ONE streamed matmul (weights concatenated
column-wise) — the LPU's "streamlined" C1 dataflow: a single continuous
weight stream through the ESL ``ag_matmul``.  The core softmax/PV loop is
an online-softmax (flash) chunked scan — the SXE∥VXE overlap of Fig. 3(b).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import esl
from repro.core.dist import AxisEnv, model_rank
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention.ops import (paged_decode_attention,
                                                paged_stream_supported,
                                                resolve_paged_kernel)
from repro.models.common import InitCtx, apply_rope, big_neg

Params = Dict[str, Any]

# paged_stream_supported / resolve_paged_kernel are re-exported here for
# model-level callers (engine, tests); they live next to the kernel in
# kernels/decode_attention/ops.py so every dispatch site shares ONE
# eligibility rule.


# ---------------------------------------------------------------------------
# init (stored layout)
# ---------------------------------------------------------------------------

def _stored_q_builder(attn, d_model, logical_heads, scale):
    def build(key):
        w = jax.random.normal(key, (d_model, logical_heads, attn.d_head),
                              jnp.float32) * scale
        cols = np.asarray(attn.q_orig, np.int64)
        out = jnp.where((cols >= 0)[None, :, None],
                        jnp.take(w, np.clip(cols, 0, logical_heads - 1), axis=1),
                        0.0)
        return out
    return build


def _stored_kv_builder(attn, d_model, scale):
    def build(key):
        g = max(attn.n_kv_heads, 1)
        w = jax.random.normal(key, (d_model, g, attn.d_head),
                              jnp.float32) * scale
        cols = np.asarray(attn.kv_orig, np.int64)
        return jnp.where((cols >= 0)[None, :, None],
                         jnp.take(w, np.clip(cols, 0, g - 1), axis=1), 0.0)
    return build


def _stored_o_builder(attn, d_model, scale):
    def build(key):
        w = jax.random.normal(key, (attn.n_heads, attn.d_head, d_model),
                              jnp.float32) * scale
        rows = np.asarray(attn.q_orig, np.int64)
        return jnp.where((rows >= 0)[:, None, None],
                         jnp.take(w, np.clip(rows, 0, attn.n_heads - 1), axis=0),
                         0.0)
    return build


def init_attention(ctx: InitCtx, cfg, plan, name: str = "attn") -> Params:
    a = plan.attn
    D = cfg.d_model
    s_in = 1.0 / math.sqrt(D)
    s_out = 1.0 / math.sqrt(max(a.n_heads * a.d_head, 1))
    with ctx.scope(name):
        p: Params = {
            "wq": ctx.param_from("wq", (D, a.hp, a.d_head),
                                 ("embed", "q_heads", "head_dim"),
                                 _stored_q_builder(a, D, a.n_heads, s_in)),
            "wk": ctx.param_from("wk", (D, a.gp, a.d_head),
                                 ("embed", "kv_heads", "head_dim"),
                                 _stored_kv_builder(a, D, s_in)),
            "wv": ctx.param_from("wv", (D, a.gp, a.d_head),
                                 ("embed", "kv_heads", "head_dim"),
                                 _stored_kv_builder(a, D, s_in)),
            "wo": ctx.param_from("wo", (a.hp, a.d_head, D),
                                 ("q_heads", "head_dim", "embed"),
                                 _stored_o_builder(a, D, s_out)),
        }
        if cfg.qkv_bias:
            p["bq"] = ctx.param("bq", (a.hp, a.d_head),
                                ("q_heads", "head_dim"), init="zeros")
            p["bk"] = ctx.param("bk", (a.gp, a.d_head),
                                ("kv_heads", "head_dim"), init="zeros")
            p["bv"] = ctx.param("bv", (a.gp, a.d_head),
                                ("kv_heads", "head_dim"), init="zeros")
    return p


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def qkv_proj(p: Params, x: jax.Array, env: AxisEnv, plan
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,D/tp) scattered (ESL) or (B,S,D) full.  One streamed matmul.

    Returns local q (B,S,qpr,dh), k,v (B,S,kpr,dh).
    """
    a = plan.attn
    D = p["wq"].shape[0]
    qpr, kpr, dh = a.q_per_rank, a.kv_per_rank, a.d_head
    wq = p["wq"].reshape(D, qpr * dh)
    wk = p["wk"].reshape(D, kpr * dh)
    wv = p["wv"].reshape(D, kpr * dh)
    w = jnp.concatenate([wq, wk, wv], axis=-1)
    b = None
    if "bq" in p:
        b = jnp.concatenate([p["bq"].reshape(-1), p["bk"].reshape(-1),
                             p["bv"].reshape(-1)])
    y = esl.ag_matmul(x, w, axis=env.model, tp=env.tp,
                      overlap=plan.esl_overlap, b=b)
    B, S = y.shape[0], y.shape[1]
    q, k, v = jnp.split(y, [qpr * dh, (qpr + kpr) * dh], axis=-1)
    return (q.reshape(B, S, qpr, dh), k.reshape(B, S, kpr, dh),
            v.reshape(B, S, kpr, dh))


def out_proj(p: Params, attn_out: jax.Array, env: AxisEnv, plan) -> jax.Array:
    """attn_out: (B,S,qpr,dh) -> (B,S,D/tp) scattered (or full baseline)."""
    a = plan.attn
    B, S = attn_out.shape[0], attn_out.shape[1]
    w = p["wo"].reshape(a.q_per_rank * a.d_head, -1)
    return esl.rs_matmul(attn_out.reshape(B, S, -1), w, axis=env.model,
                         tp=env.tp, overlap=plan.esl_overlap,
                         scatter_out=plan.esl_overlap)


def local_kmap(plan, env: AxisEnv) -> jax.Array:
    """(qpr,) local-kv index per local q head for this rank."""
    table = jnp.asarray(plan.attn.q_to_kv_local)       # (tp, qpr)
    return lax.dynamic_index_in_dim(table, model_rank(env), 0, keepdims=False)


def _expand_kv(k: jax.Array, kmap: jax.Array, qpr: int) -> jax.Array:
    """(B,S,kpr,dh) -> (B,S,qpr,dh) per the local q->kv map."""
    if k.shape[2] == 1:
        return jnp.broadcast_to(k, k.shape[:2] + (qpr,) + k.shape[3:])
    return jnp.take(k, kmap, axis=2)


# ---------------------------------------------------------------------------
# flash (online-softmax) core — the SXE||VXE overlapped dataflow
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool,
                    q_offset: Optional[jax.Array] = None,
                    kv_valid_len: Optional[jax.Array] = None,
                    kv_base: int = 0,
                    chunk: int = 512,
                    scale: Optional[float] = None) -> jax.Array:
    """Chunked online-softmax attention.

    q: (B,Sq,H,dh); k,v: (B,Skv,H,dh) (same head count — pre-expanded).
    causal uses absolute positions ``q_offset + i`` vs ``kv_base + j``.
    ``kv_valid_len``: (B,) valid kv length (decode against a ring cache).
    """
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    scale = scale or (1.0 / math.sqrt(dh))
    chunk = min(chunk, Skv)
    n_chunks = (Skv + chunk - 1) // chunk
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, dh).transpose(1, 0, 2, 3, 4)

    q32 = q.astype(jnp.float32) * scale
    q_pos = (jnp.arange(Sq) if q_offset is None
             else q_offset[..., None] + jnp.arange(Sq))  # (Sq,) or (B,Sq)

    def body(carry, inputs):
        m, l, acc, cidx = carry
        kb, vb = inputs
        kv_pos = kv_base + cidx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kb.astype(jnp.float32))
        neg = big_neg(jnp.float32)
        if causal:
            qp = q_pos if q_pos.ndim == 2 else q_pos[None]
            mask = qp[:, None, :, None] >= kv_pos[None, None, None, :]
            s = jnp.where(mask, s, neg)
        if kv_valid_len is not None:
            ok = kv_pos[None, :] < kv_valid_len[:, None]      # (B, chunk)
            s = jnp.where(ok[:, None, None, :], s, neg)
        if Skv % chunk:
            inb = (cidx * chunk + jnp.arange(chunk)) < Skv
            s = jnp.where(inb[None, None, None, :], s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, cidx + 1), None

    m0 = jnp.full((B, H, Sq), big_neg(jnp.float32), jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    (m, l, acc, _), _ = lax.scan(body, (m0, l0, a0, jnp.int32(0)), (kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)     # (B,Sq,H,dh)


# ---------------------------------------------------------------------------
# full layers: self-attention (train/prefill), decode, cross-attention
# ---------------------------------------------------------------------------

def self_attention(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
                   positions: jax.Array, causal: bool = True) -> jax.Array:
    """Training/prefill self-attention.  x scattered or full per plan."""
    a = plan.attn
    q, k, v = qkv_proj(p, x, env, plan)
    if cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kmap = local_kmap(plan, env)
    k = _expand_kv(k, kmap, a.q_per_rank)
    v = _expand_kv(v, kmap, a.q_per_rank)
    out = flash_attention(q, k, v, causal=causal)
    return out_proj(p, out, env, plan)


def prefill_attention(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
                      positions: jax.Array, cache: Dict[str, jax.Array],
                      causal: bool = True
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: same as self-attention but fills the KV cache."""
    a = plan.attn
    q, k, v = qkv_proj(p, x, env, plan)
    if cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    S = k.shape[1]
    new_cache = dict(cache)
    new_cache["k"] = _cache_insert_prefix(cache["k"], k, env)
    new_cache["v"] = _cache_insert_prefix(cache["v"], v, env)
    kmap = local_kmap(plan, env)
    ke = _expand_kv(k, kmap, a.q_per_rank)
    ve = _expand_kv(v, kmap, a.q_per_rank)
    out = flash_attention(q, ke, ve, causal=causal)
    return out_proj(p, out, env, plan), new_cache


def chunk_prefill_attention(p: Params, x: jax.Array, *, cfg, plan,
                            env: AxisEnv, positions: jax.Array,
                            cache: Dict[str, jax.Array],
                            block_table: jax.Array,
                            kv_valid_len: jax.Array,
                            paged_kernel: str = "auto"
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill ONE chunk of a partially-resident prompt against the pool.

    The entry point behind the engine's ``--prefill-chunk`` interleave:
    instead of one monolithic bucketed prefill, the prompt arrives in
    fixed-size chunks whose KV is written *incrementally* into the
    shared block pool, and each chunk's queries attend to the full
    resident history (earlier chunks, or a preempted request's
    recomputed tokens) plus the causal prefix of the chunk itself.

    x:            (1, C, D[/tp]) the chunk activations (C is static —
                  ONE trace total, vs O(log2 max_seq) pow2 buckets)
    positions:    (1, C) absolute positions ``start + [0..C)``
    cache:        {'k','v': (N, bs, kpr, dh)} the shared block pool
                  (rank-local head shard under ring tp, like decode)
    block_table:  (1, T) this request's physical block ids
    kv_valid_len: scalar — total resident tokens AFTER this chunk
                  (start + valid rows; padded tail rows beyond it are
                  routed to the null block and masked on read).

    Dataflow mirrors decode's ``paged_kernel`` seam:

    * ``"stream"`` — the chunk IS a batch for the paged Pallas kernel:
      C queries with per-query valid lengths ``pos + 1`` share the
      request's (broadcast) block table, so causality falls out of the
      kernel's own length masking and the per-position online-softmax
      fold — the same no-copy KV stream as decode, reused for prefill.
    * ``"gather"`` — reference oracle: materialize the contiguous view
      through the table and run the chunked flash prefill with
      ``q_offset`` carrying the chunk's absolute position.

    Both scatter the chunk's K/V into the pool FIRST (the fold then
    covers self + history through one length mask), and both return the
    full updated pool as the new cache — the scan carry aliases it in
    place, so per chunk only the C new rows are written.
    """
    from repro.serving.kv_cache import quantize_kv_rows, scatter_chunk_rows
    a = plan.attn
    q, k, v = qkv_proj(p, x, env, plan)
    if cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    table = block_table[0]
    pos = positions[0]
    valid = pos < kv_valid_len
    quantized = "k_scale" in cache
    ks = vs = None
    if quantized:
        # quantize at pool-write time; the chunk's own rows are read
        # back dequantized (post-update-read contract), so every later
        # mode sees the SAME stored values this chunk attended
        kq, ksc = quantize_kv_rows(k[0], cache["k"].dtype,
                                   cache["k_scale"].dtype)
        vq, vsc = quantize_kv_rows(v[0], cache["v"].dtype,
                                   cache["v_scale"].dtype)
        kc = scatter_chunk_rows(cache["k"], kq, table, pos, valid)
        vc = scatter_chunk_rows(cache["v"], vq, table, pos, valid)
        ks = scatter_chunk_rows(cache["k_scale"], ksc, table, pos, valid)
        vs = scatter_chunk_rows(cache["v_scale"], vsc, table, pos, valid)
    else:
        kc = scatter_chunk_rows(cache["k"], k[0], table, pos, valid)
        vc = scatter_chunk_rows(cache["v"], v[0], table, pos, valid)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = kc, vc
    if quantized:
        new_cache["k_scale"], new_cache["v_scale"] = ks, vs

    C = q.shape[1]
    bs = kc.shape[1]
    mode = resolve_paged_kernel(plan, bs, paged_kernel)
    if mode == "stream":
        # per-query causal span: history + self (clamped for pad rows)
        lens = jnp.minimum(pos + 1, kv_valid_len)
        tabs = jnp.broadcast_to(table[None], (C, table.shape[0]))
        out = paged_decode_attention(
            q[0], kc, vc, tabs, lens, k_scale=ks, v_scale=vs,
            use_pallas=True,
            interpret=da_ops.default_interpret())[None]
    else:
        T = table.shape[0]
        kview = kc[table].reshape(1, T * bs, kc.shape[2], kc.shape[3])
        vview = vc[table].reshape(1, T * bs, vc.shape[2], vc.shape[3])
        if quantized:
            kview = kview.astype(jnp.float32) * \
                ks[table].reshape(1, T * bs, ks.shape[2])[..., None]
            vview = vview.astype(jnp.float32) * \
                vs[table].reshape(1, T * bs, vs.shape[2])[..., None]
        kmap = local_kmap(plan, env)
        ke = _expand_kv(kview, kmap, a.q_per_rank)
        ve = _expand_kv(vview, kmap, a.q_per_rank)
        out = flash_attention(q, ke, ve, causal=True, q_offset=pos[:1],
                              kv_valid_len=kv_valid_len[None])
    return out_proj(p, out, env, plan), new_cache


def verify_attention(p: Params, x: jax.Array, *, cfg, plan,
                     env: AxisEnv, positions: jax.Array,
                     cache: Dict[str, jax.Array],
                     block_tables: jax.Array,
                     kv_valid_len: jax.Array,
                     paged_kernel: str = "auto"
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Score a speculative verify window against the block pool.

    Every decode slot's (last committed token + k draft tokens) are
    flattened into ONE batch of single-token queries — the chunk-as-
    batch trick of :func:`chunk_prefill_attention`, generalized to
    per-query block tables so many requests verify in one kernel call.

    x:            (1, Q, D[/tp]) flattened queries, Q = B*(k+1)
    positions:    (1, Q) each query's absolute position
    block_tables: (Q, T) each query's OWN table (a slot's k+1 rows
                  repeat its table; idle slots ride the null block)
    kv_valid_len: (Q,) per-query causal span INCLUDING self
                  (``pos + 1``; clamped >= 1 for idle rows).

    Draft K/V scatters into the pool FIRST (per-query tables via
    :func:`repro.serving.kv_cache.scatter_spec_rows`), then each query
    attends its own length-masked span — so draft i sees drafts < i of
    the same window plus all resident history, exactly the sequential
    decode dataflow.  This is why verify cannot reuse decode's
    pre-update-read contract (the in-kernel fold only covers a query's
    OWN new token, not its window predecessors).  Rejected drafts need
    no undo: their rows land past the accepted resident length, stay
    masked, and are overwritten idempotently by later windows.
    """
    from repro.serving.kv_cache import quantize_kv_rows, scatter_spec_rows
    a = plan.attn
    q, k, v = qkv_proj(p, x, env, plan)
    if cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    pos = positions[0]
    lens = kv_valid_len
    valid = lens > pos
    quantized = "k_scale" in cache
    ks = vs = None
    if quantized:
        kq, ksc = quantize_kv_rows(k[0], cache["k"].dtype,
                                   cache["k_scale"].dtype)
        vq, vsc = quantize_kv_rows(v[0], cache["v"].dtype,
                                   cache["v_scale"].dtype)
        kc = scatter_spec_rows(cache["k"], kq, block_tables, pos, valid)
        vc = scatter_spec_rows(cache["v"], vq, block_tables, pos, valid)
        ks = scatter_spec_rows(cache["k_scale"], ksc, block_tables, pos,
                               valid)
        vs = scatter_spec_rows(cache["v_scale"], vsc, block_tables, pos,
                               valid)
    else:
        kc = scatter_spec_rows(cache["k"], k[0], block_tables, pos, valid)
        vc = scatter_spec_rows(cache["v"], v[0], block_tables, pos, valid)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = kc, vc
    if quantized:
        new_cache["k_scale"], new_cache["v_scale"] = ks, vs

    bs = kc.shape[1]
    mode = resolve_paged_kernel(plan, bs, paged_kernel)
    if mode == "stream":
        out = paged_decode_attention(
            q[0], kc, vc, block_tables, lens, k_scale=ks, v_scale=vs,
            use_pallas=True,
            interpret=da_ops.default_interpret())[None]
    else:
        Q, T = block_tables.shape
        kview = kc[block_tables].reshape(Q, T * bs, kc.shape[2],
                                         kc.shape[3])
        vview = vc[block_tables].reshape(Q, T * bs, vc.shape[2],
                                         vc.shape[3])
        if quantized:
            kview = kview.astype(jnp.float32) * \
                ks[block_tables].reshape(Q, T * bs, ks.shape[2])[..., None]
            vview = vview.astype(jnp.float32) * \
                vs[block_tables].reshape(Q, T * bs, vs.shape[2])[..., None]
        kmap = local_kmap(plan, env)
        ke = _expand_kv(kview, kmap, a.q_per_rank)
        ve = _expand_kv(vview, kmap, a.q_per_rank)
        out = flash_attention(q[0][:, None], ke, ve, causal=True,
                              q_offset=pos, kv_valid_len=lens)
        out = out.swapaxes(0, 1)
    return out_proj(p, out, env, plan), new_cache


def decode_attention(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
                     cache: Dict[str, jax.Array], positions: jax.Array,
                     block_table: Optional[jax.Array] = None,
                     paged_kernel: str = "auto",
                     block_s: int = 0
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token generation step against the KV cache.

    ``block_s`` overrides the KV stream chunk of the dense / gathered
    flash-decode path (0 = the default 2048); the streamed paged kernel's
    tile is structurally the pool block size, so the override does not
    apply there (the engine rejects conflicting requests up front).

    x: (B,1,D[/tp]);  positions: (B,) current position of each sequence.
    cache['k'/'v']: local (B, Smax[/kvseq], kpr, dh); cache['len'] == positions
    handled by the caller (engine).  This is the LPU's target regime: one
    activation vector against streamed weights + streamed KV.

    Paged mode (``block_table`` given): cache['k'/'v'] is the shared block
    pool (N, bs, kpr, dh).  ``paged_kernel`` selects the dataflow:

    * ``"stream"`` — the Pallas paged kernel streams KV tiles straight
      from the pool via the scalar-prefetched block table; the new
      token's K/V folds into the online-softmax carry in-kernel.  No
      per-request contiguous view is EVER materialized — the paper's
      no-copy decode stream (Fig. 3b).
    * ``"gather"`` — the reference oracle: materialize the contiguous
      (B, T*bs, ...) view through the table, then run the same chunked
      flash decode as the dense cache (an O(resident-tokens) HBM copy
      per layer per step — kept as the bit-trustworthy baseline).
    * ``"auto"`` — stream when the stored GQA layout allows it
      (:func:`paged_stream_supported`), else gather.

    Both modes mask by ``positions`` (null blocks past the valid length
    never contribute) and return the same pre-update cache contract: the
    caller scatters (k_new, v_new) into the pool afterwards.  Under ring
    tp the pool arrives head-sharded (kpr = Gp/tp local heads) with the
    SAME block ids on every rank, so the replicated table drives all
    shards — paged decode composes with the ESL ring, but not with
    kv-seq sharding (the pool's block dim already replaces the seq dim).
    """
    a = plan.attn
    q, k_new, v_new = qkv_proj(p, x, env, plan)
    if cfg.positional == "rope":
        q = apply_rope(q, positions[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, positions[:, None], cfg.rope_theta)

    kc, vc = cache["k"], cache["v"]
    if block_table is not None:
        assert env.kv_seq_axis is None, \
            "paged KV shards heads over the model ring, not the seq axis"
        mode = resolve_paged_kernel(plan, kc.shape[1], paged_kernel)
        quantized = "k_scale" in cache
        if quantized:
            from repro.serving.kv_cache import (dequantize_kv,
                                                quantize_kv_rows)
            # quantize FIRST, attend the dequantized round-trip: decode
            # must see the exact value the pool will store, or later
            # reads of this row (verify windows, chunked prefill) would
            # diverge from the step that emitted it
            kq, ksc = quantize_kv_rows(k_new, kc.dtype,
                                       cache["k_scale"].dtype)
            vq, vsc = quantize_kv_rows(v_new, vc.dtype,
                                       cache["v_scale"].dtype)
            k_fold, v_fold = dequantize_kv(kq, ksc), dequantize_kv(vq, vsc)
            updates = {"k_new": kq, "v_new": vq,
                       "k_scale_new": ksc, "v_scale_new": vsc,
                       "pos": positions,
                       "mask": jnp.ones(positions.shape, bool)}
        else:
            k_fold, v_fold = k_new, v_new
            updates = {"k_new": k_new.astype(kc.dtype),
                       "v_new": v_new.astype(vc.dtype),
                       "pos": positions,
                       "mask": jnp.ones(positions.shape, bool)}
        if mode == "stream":
            out = paged_decode_attention(
                q[:, 0], kc, vc, block_table, positions,
                k_new=k_fold[:, 0], v_new=v_fold[:, 0],
                k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
                use_pallas=True,
                interpret=da_ops.default_interpret())[:, None]
            return out_proj(p, out, env, plan), updates
        B, T = block_table.shape
        bs = kc.shape[1]
        kc = kc[block_table].reshape(B, T * bs, kc.shape[2], kc.shape[3])
        vc = vc[block_table].reshape(B, T * bs, vc.shape[2], vc.shape[3])
        if quantized:
            G = cache["k_scale"].shape[2]
            kc = kc.astype(jnp.float32) * cache["k_scale"][
                block_table].reshape(B, T * bs, G)[..., None]
            vc = vc.astype(jnp.float32) * cache["v_scale"][
                block_table].reshape(B, T * bs, G)[..., None]
        kmap = local_kmap(plan, env)
        out = _flash_decode_chunked(q, kc, vc, kmap,
                                    kv_valid_len=positions,
                                    chunk=block_s or 2048,
                                    k_new=k_fold, v_new=v_fold)
        return out_proj(p, out, env, plan), updates
    if env.kv_seq_axis is None:
        # read the cache pre-update; the new token folds into the online
        # softmax and the caller scatters (k_new, v_new) into the scan
        # CARRY in place — no full-cache rewrite per layer (§Perf it. 1b)
        kmap = local_kmap(plan, env)
        out = _flash_decode_chunked(q, kc, vc, kmap,
                                    kv_valid_len=positions,
                                    chunk=block_s or 2048,
                                    k_new=k_new, v_new=v_new)
        updates = {"k_new": k_new.astype(kc.dtype),
                   "v_new": v_new.astype(vc.dtype),
                   "pos": positions,
                   "mask": jnp.ones(positions.shape, bool)}
    else:
        # long-context: KV sequence sharded across `kv_seq_axis`; the
        # global cache is rank-major (B, width, S/width, kpr, dh) and the
        # local shard carries a singleton width dim -- squeeze it here.
        kc_l, vc_l = kc[:, 0], vc[:, 0]
        out = _seq_sharded_decode(q, kc_l, vc_l, k_new, v_new, positions,
                                  plan, env)
        r = lax.axis_index(env.kv_seq_axis)
        s_loc = kc_l.shape[1]
        local_pos = positions - r * s_loc
        mine = (local_pos >= 0) & (local_pos < s_loc)
        updates = {"k_new": k_new.astype(kc.dtype),
                   "v_new": v_new.astype(vc.dtype),
                   "pos": jnp.clip(local_pos, 0, s_loc - 1),
                   "mask": mine}
    return out_proj(p, out, env, plan), updates


def cross_attention(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
                    enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Decoder cross-attention to (precomputed) encoder K/V (whisper)."""
    a = plan.attn
    # only the query projection of x; enc_k/enc_v already per-head local
    D = p["wq"].shape[0]
    qpr, dh = a.q_per_rank, a.d_head
    wq = p["wq"].reshape(D, qpr * dh)
    bq = p["bq"].reshape(-1) if "bq" in p else None
    q = esl.ag_matmul(x, wq, axis=env.model, tp=env.tp,
                      overlap=plan.esl_overlap, b=bq)
    B, S = q.shape[0], q.shape[1]
    q = q.reshape(B, S, qpr, dh)
    kmap = local_kmap(plan, env)
    ke = _expand_kv(enc_k, kmap, qpr)
    ve = _expand_kv(enc_v, kmap, qpr)
    out = flash_attention(q, ke, ve, causal=False)
    return out_proj(p, out, env, plan)


def encode_cross_kv(p: Params, enc_x: jax.Array, *, cfg, plan, env: AxisEnv
                    ) -> Tuple[jax.Array, jax.Array]:
    """K/V of encoder states for cross-attention (computed once)."""
    a = plan.attn
    D = p["wk"].shape[0]
    kpr, dh = a.kv_per_rank, a.d_head
    wk = p["wk"].reshape(D, kpr * dh)
    wv = p["wv"].reshape(D, kpr * dh)
    w = jnp.concatenate([wk, wv], -1)
    b = (jnp.concatenate([p["bk"].reshape(-1), p["bv"].reshape(-1)])
         if "bk" in p else None)
    y = esl.ag_matmul(enc_x, w, axis=env.model, tp=env.tp,
                      overlap=plan.esl_overlap, b=b)
    B, S = y.shape[0], y.shape[1]
    k, v = jnp.split(y, 2, axis=-1)
    return k.reshape(B, S, kpr, dh), v.reshape(B, S, kpr, dh)


def _flash_decode_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                          kmap: jax.Array, *, kv_valid_len: jax.Array,
                          chunk: int = 2048,
                          k_new: Optional[jax.Array] = None,
                          v_new: Optional[jax.Array] = None) -> jax.Array:
    """Generation-stage flash attention with ZERO cache materialization.

    §Perf iteration 1 (deepseek x decode_32k): the generic path
    up-converted the whole KV cache to f32 and pre-transposed it into
    chunk-major layout — ~3 full-cache HBM copies per layer.  Here the
    cache is consumed *in place*: chunks are dynamic-sliced from the
    stored (B,S,kpr,dh) layout, dots run on bf16 operands with f32
    accumulation (preferred_element_type), and GQA needs no expansion —
    scores are computed against all kpr local KV heads (kpr<=5) and the
    per-q-head row is selected by the static-shape ``kmap`` gather.

    q: (B,1,qpr,dh); k,v: (B,S,kpr,dh); -> (B,1,qpr,dh).
    """
    B, _, qpr, dh = q.shape
    S, kpr = k.shape[1], k.shape[2]
    chunk = min(chunk, S)
    n_chunks = (S + chunk - 1) // chunk
    scale = 1.0 / math.sqrt(dh)
    qs = (q[:, 0].astype(jnp.float32) * scale).astype(k.dtype)  # (B,qpr,dh)
    sel = kmap[None, :, None, None]                   # (1,qpr,1,1) gather

    def body(carry, cidx):
        m, l, acc = carry
        start = cidx * chunk
        kb = lax.dynamic_slice_in_dim(k, start, chunk, axis=1)
        vb = lax.dynamic_slice_in_dim(v, start, chunk, axis=1)
        # scores vs ALL local kv heads, f32 accumulation, bf16 stream
        s_all = jnp.einsum("bqd,bkgd->bqgk", qs, kb,
                           preferred_element_type=jnp.float32)
        s = jnp.take_along_axis(
            s_all, jnp.broadcast_to(sel, (B, qpr, 1, chunk)),
            axis=2)[:, :, 0]
        pos = start + jnp.arange(chunk)
        ok = pos[None, :] < kv_valid_len[:, None]
        s = jnp.where(ok[:, None, :], s, big_neg(jnp.float32))
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, -1)
        pv_all = jnp.einsum("bqk,bkgd->bqgd", p.astype(k.dtype), vb,
                            preferred_element_type=jnp.float32)
        pv = jnp.take_along_axis(
            pv_all, jnp.broadcast_to(sel, (B, qpr, 1, dh)), axis=2)[:, :, 0]
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, qpr), big_neg(jnp.float32), jnp.float32)
    l0 = jnp.zeros((B, qpr), jnp.float32)
    a0 = jnp.zeros((B, qpr, dh), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    if k_new is not None:
        # fold in the just-generated token (the cache is read pre-update;
        # the caller scatters (k_new, v_new) into the carry afterwards)
        s_all = jnp.einsum("bqd,bkgd->bqgk", qs, k_new,
                           preferred_element_type=jnp.float32)
        s_self = jnp.take_along_axis(
            s_all, jnp.broadcast_to(sel, (B, qpr, 1, 1)), axis=2)[:, :, 0, 0]
        m_new = jnp.maximum(m, s_self)
        p_self = jnp.exp(s_self - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p_self
        # v_new: (B,1,kpr,dh) -> per-q-head row via kmap
        vn = jnp.take_along_axis(
            jnp.broadcast_to(v_new[:, 0][:, None], (B, qpr, kpr, dh)),
            jnp.broadcast_to(kmap[None, :, None, None], (B, qpr, 1, dh)),
            axis=2)[:, :, 0]
        acc = acc * corr[..., None] + p_self[..., None] * \
            vn.astype(jnp.float32)
        m = m_new
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out[:, None].astype(q.dtype)


# ---------------------------------------------------------------------------
# cache plumbing
# ---------------------------------------------------------------------------

def _cache_insert_prefix(cache: jax.Array, kv: jax.Array,
                         env: AxisEnv) -> jax.Array:
    """Write the prefill K/V into cache[:, :S]."""
    if env.kv_seq_axis is None:
        return lax.dynamic_update_slice_in_dim(
            cache, kv.astype(cache.dtype), 0, axis=1)
    # seq-sharded cache: rank holds slice [r*Sloc, (r+1)*Sloc)
    r = lax.axis_index(env.kv_seq_axis)
    s_loc = cache.shape[1]
    start = r * s_loc
    sl = lax.dynamic_slice_in_dim(
        jnp.pad(kv, ((0, 0), (0, max(0, s_loc * env.kv_seq_width - kv.shape[1])),
                     (0, 0), (0, 0))),
        start, s_loc, axis=1)
    return sl.astype(cache.dtype)


def _cache_update(cache: jax.Array, kv_new: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """Per-sequence scatter of the new token's K/V at its position."""
    def upd(c, n, p):
        return lax.dynamic_update_slice_in_dim(c, n.astype(c.dtype), p, axis=0)
    return jax.vmap(upd)(cache, kv_new, positions)


def _seq_sharded_update(kc, vc, k_new, v_new, positions, env: AxisEnv):
    """Scatter the new token into whichever rank owns its seq slot."""
    r = lax.axis_index(env.kv_seq_axis)
    s_loc = kc.shape[1]
    local_pos = positions - r * s_loc
    mine = (local_pos >= 0) & (local_pos < s_loc)
    safe = jnp.clip(local_pos, 0, s_loc - 1)

    def upd(c, n, p, m):
        new = lax.dynamic_update_slice_in_dim(c, n.astype(c.dtype), p, axis=0)
        return jnp.where(m, new, c)
    kc = jax.vmap(upd)(kc, k_new, safe, mine)
    vc = jax.vmap(upd)(vc, v_new, safe, mine)
    return kc, vc


def _seq_sharded_decode(q, kc, vc, k_new, v_new, positions, plan,
                        env: AxisEnv):
    """Flash-decode with the KV sequence sharded over ``env.kv_seq_axis``:
    each rank reduces its slice; partial (acc, l, m) merge via psum —
    the sequence-parallel analog of ESL's partial-product streaming."""
    a = plan.attn
    r = lax.axis_index(env.kv_seq_axis)
    s_loc = kc.shape[1]
    kmap = local_kmap(plan, env)
    # include the *new* token separately (it may belong to another rank's
    # slice; adding it once on rank 0 keeps the psum exact)
    ke = _expand_kv(kc, kmap, a.q_per_rank)
    ve = _expand_kv(vc, kmap, a.q_per_rank)
    scale = 1.0 / math.sqrt(a.d_head)
    q32 = q.astype(jnp.float32) * scale                   # (B,1,H,dh)
    kv_pos = r * s_loc + jnp.arange(s_loc)
    s = jnp.einsum("bqhd,bkhd->bhqk", q32, ke.astype(jnp.float32))
    ok = kv_pos[None, :] < positions[:, None]             # strictly past
    s = jnp.where(ok[:, None, None, :], s, big_neg(jnp.float32))
    m_loc = jnp.max(s, -1)
    # current token attends itself: fold in on every rank after global max
    kn = _expand_kv(k_new, kmap, a.q_per_rank).astype(jnp.float32)
    vn = _expand_kv(v_new, kmap, a.q_per_rank).astype(jnp.float32)
    s_self = jnp.einsum("bqhd,bkhd->bhqk", q32, kn)       # (B,H,1,1)
    m_glob = lax.pmax(jnp.maximum(m_loc, s_self[..., 0]), env.kv_seq_axis)
    p = jnp.exp(s - m_glob[..., None])
    l_loc = jnp.sum(p, -1)
    acc = jnp.einsum("bhqk,bkhd->bhqd", p, ve.astype(jnp.float32))
    l = lax.psum(l_loc, env.kv_seq_axis)
    acc = lax.psum(acc, env.kv_seq_axis)
    p_self = jnp.exp(s_self[..., 0] - m_glob)
    l = l + p_self
    acc = acc + p_self[..., None] * vn.transpose(0, 2, 1, 3)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def init_cache(plan, batch: int, max_seq: int, dtype=jnp.bfloat16,
               abstract: bool = False, kv_seq_width: int = 1,
               paged: bool = False, num_blocks: int = 0,
               block_size: int = 0, scale_dtype=None):
    """Per-layer KV cache in the stored (local-head) layout.

    Dense: global logical shape (B, max_seq, Gp, dh); under kv-seq
    sharding the stored seq dim is max_seq/width per rank (global held
    as rank-major).

    Paged (``paged=True``): a shared pool (num_blocks, block_size, Gp,
    dh) with **no batch dimension** — requests own disjoint block sets
    via block tables (block 0 reserved as the null block).  Memory
    scales with resident tokens, not slots x worst-case length.

    ``scale_dtype`` (paged only) marks the pool as quantized: ``dtype``
    is the int8/fp8 storage type and two scale side-arrays
    ``k_scale``/``v_scale`` of shape (num_blocks, block_size, Gp) ride
    alongside the values — one absmax scale per stored row per head,
    zero-initialized so the null block dequantizes to exact zeros.
    """
    a = plan.attn
    gp = a.gp
    if paged:
        assert kv_seq_width == 1, "paged cache is single-rank (no kv-seq)"
        assert num_blocks >= 2 and block_size > 0, (num_blocks, block_size)
        shape = (num_blocks, block_size, gp, a.d_head)
    else:
        assert scale_dtype is None, \
            "quantized KV storage needs the paged pool (row scatters " \
            "carry the scales; the dense cache has no side arrays)"
        s = max_seq // kv_seq_width
        shape = (batch, max_seq, gp, a.d_head) if kv_seq_width == 1 else \
            (batch, kv_seq_width, s, gp, a.d_head)

    def leaf(shp, dt):
        return (jax.ShapeDtypeStruct(shp, dt) if abstract
                else jnp.zeros(shp, dt))

    out = {"k": leaf(shape, dtype), "v": leaf(shape, dtype)}
    if scale_dtype is not None:
        out["k_scale"] = leaf(shape[:-1], scale_dtype)
        out["v_scale"] = leaf(shape[:-1], scale_dtype)
    return out
