"""Model substrate: parameter init context, norms, rope, basic ops.

Parameters are plain nested dicts of ``jnp`` arrays.  Init runs through an
:class:`InitCtx` which (a) can run *abstract* (ShapeDtypeStruct only -- used
by the dry-run so a 400B model never allocates) and (b) records the
*logical axes* of every parameter by tree path.  The HyperDex-analog mapper
turns logical axes into mesh ``PartitionSpec``s.

Logical axis vocabulary (the mapper's rule table keys):
  'embed'      d_model-sized dims
  'q_heads'    stored (padded/duplicated) query-head dim        -> model
  'kv_heads'   stored KV-head dim                               -> model
  'head_dim'   per-head dim                                     -> none
  'ffn'        padded FFN hidden dim                            -> model
  'vocab'      padded vocabulary dim                            -> model
  'experts'    expert dim                                       -> model (EP)
  'expert_ffn' per-expert FFN dim (possibly split)              -> model part
  'layers'     stacked-layer leading dim                        -> none
  'conv'/'state'/'lora'/'pos'/None  misc small dims             -> none
"""
from __future__ import annotations

import contextlib
import math
import zlib
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Params = Dict[str, Any]


class InitCtx:
    """Parameter factory recording logical axes by path.

    ``abstract=True`` produces ``jax.ShapeDtypeStruct`` leaves (dry-run).
    """

    def __init__(self, key: jax.Array, *, abstract: bool = False,
                 param_dtype=jnp.float32):
        self._key = key
        self.abstract = abstract
        self.param_dtype = param_dtype
        self.axes: Dict[str, Tuple[Optional[str], ...]] = {}
        self._stack: list = []

    # -- scoping ------------------------------------------------------------

    @contextlib.contextmanager
    def scope(self, name: str):
        self._stack.append(str(name))
        try:
            yield self
        finally:
            self._stack.pop()

    def _path(self, name: str) -> str:
        return "/".join(self._stack + [name])

    def fold(self, name: str) -> jax.Array:
        """Deterministic per-path key (abstract mode never consumes RNG).

        crc32, NOT ``hash()``: Python string hashing is salted per
        process (PYTHONHASHSEED), which silently made params — and
        every greedy token stream — unreproducible across runs."""
        h = np.uint32(zlib.crc32(self._path(name).encode()) & 0x7FFFFFFF)
        return jax.random.fold_in(self._key, h)

    # -- creation -----------------------------------------------------------

    def param(self, name: str, shape: Sequence[int],
              axes: Sequence[Optional[str]], init: str = "normal",
              scale: float = 1.0, dtype=None) -> jax.Array:
        shape = tuple(int(s) for s in shape)
        if len(shape) != len(tuple(axes)):
            raise ValueError(
                f"{self._path(name)}: shape {shape} vs axes {tuple(axes)}")
        dtype = dtype or self.param_dtype
        self.axes[self._path(name)] = tuple(axes)
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        k = self.fold(name)
        if init == "normal":
            fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
            std = scale / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "uniform":
            return (jax.random.uniform(k, shape, jnp.float32, -scale, scale)
                    ).astype(dtype)
        raise ValueError(f"unknown init {init!r}")

    def param_from(self, name: str, shape: Sequence[int],
                   axes: Sequence[Optional[str]], builder,
                   dtype=None) -> jax.Array:
        """Parameter with custom construction (padded/duplicated layouts).

        ``builder(key) -> f32 array of `shape```; skipped in abstract mode.
        """
        shape = tuple(int(s) for s in shape)
        dtype = dtype or self.param_dtype
        self.axes[self._path(name)] = tuple(axes)
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        out = builder(self.fold(name))
        assert tuple(out.shape) == shape, (self._path(name), out.shape, shape)
        return out.astype(dtype)

    def dense(self, name: str, d_in: int, d_out: int,
              axes: Tuple[Optional[str], Optional[str]],
              bias: bool = False, scale: float = 1.0,
              bias_axis: Optional[str] = None) -> Params:
        with self.scope(name):
            p: Params = {"w": self.param("w", (d_in, d_out), axes, scale=scale)}
            if bias:
                p["b"] = self.param(
                    "b", (d_out,), (bias_axis if bias_axis else axes[1],),
                    init="zeros")
        return p


def stacked_init(ctx: InitCtx, name: str, n: int, init_one):
    """Stack `n` layers' params on a leading 'layers' axis.

    ``init_one(ctx) -> Params`` is evaluated once to learn the structure,
    then materialized per-layer and stacked (real mode) or given a stacked
    leading dim (abstract mode).  Axes gain a leading 'layers'.
    """
    with ctx.scope(name):
        if ctx.abstract:
            inner = InitCtx(ctx._key, abstract=True, param_dtype=ctx.param_dtype)
            inner._stack = list(ctx._stack)
            one = init_one(inner)
            for path, ax in inner.axes.items():
                ctx.axes[path] = ("layers",) + ax
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), one)
        # real mode: vmap the initializer over per-layer keys
        leaves_list = []
        axes_snapshot = None
        for i in range(n):
            inner = InitCtx(jax.random.fold_in(ctx.fold(name), i),
                            abstract=False, param_dtype=ctx.param_dtype)
            inner._stack = list(ctx._stack)
            one = init_one(inner)
            leaves_list.append(one)
            axes_snapshot = inner.axes
        for path, ax in axes_snapshot.items():
            ctx.axes[path] = ("layers",) + ax
        return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *leaves_list)


# --------------------------------------------------------------------------
# Normalization / activations / rope
# --------------------------------------------------------------------------

def init_norm(ctx: InitCtx, name: str, dim: int, kind: str) -> Params:
    # 'vec': stored model-sharded; elementwise use is rank-local in the
    # scattered-activation (ESL) convention
    with ctx.scope(name):
        p = {"scale": ctx.param("scale", (dim,), ("vec",), init="ones")}
        if kind == "layernorm":
            p["bias"] = ctx.param("bias", (dim,), ("vec",), init="zeros")
    return p


def apply_norm(p: Params, x: jax.Array, kind: str, eps: float = 1e-5,
               stats_axis_name: Optional[str] = None) -> jax.Array:
    """RMSNorm / LayerNorm in f32.

    ``stats_axis_name``: when the hidden dim is *scattered* across a mesh
    axis (ESL scattered-activation mode), moments are combined with a scalar
    ``psum`` -- the distributed-norm trick that keeps activations scattered.
    """
    dt = x.dtype
    x = x.astype(jnp.float32)
    if kind == "layernorm":
        mean = jnp.mean(x, -1, keepdims=True)
        if stats_axis_name:
            mean = jax.lax.pmean(mean, stats_axis_name)
        x = x - mean
    var = jnp.mean(jnp.square(x), -1, keepdims=True)
    if stats_axis_name:
        var = jax.lax.pmean(var, stats_axis_name)
    y = x * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh), positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Mesh helpers
# --------------------------------------------------------------------------

def run_sharded(fn, mesh, in_specs, out_specs, *args,
                check_vma: bool = False):
    """shard_map when a mesh is given, plain call otherwise (smoke tests)."""
    if mesh is None:
        return fn(*args)
    from repro.core.compat import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=check_vma)(*args)


def axis_index_or_zero(name: Optional[str]) -> jax.Array:
    if name is None:
        return jnp.int32(0)
    return jax.lax.axis_index(name)


def psum_if(x, axis_name: Optional[str]):
    return jax.lax.psum(x, axis_name) if axis_name else x


def big_neg(dtype) -> jax.Array:
    return jnp.asarray(jnp.finfo(jnp.float32).min / 2, jnp.float32)
