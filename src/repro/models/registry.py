"""Model registry: build init / forward / cache constructors per family.

``build_model(cfg, plan)`` returns a :class:`Model` whose members are pure
functions — the step builders in :mod:`repro.core.steps` wrap them in
``shard_map`` + ``jit`` with the mapper's PartitionSpecs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compiler.mapper import partition_specs
from repro.core.dist import AxisEnv
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models import transformer as tf
from repro.models import whisper as wh
from repro.models.common import InitCtx

Params = Dict[str, Any]


@dataclass
class Model:
    cfg: Any
    plan: Any

    # ---- parameters -------------------------------------------------------

    def init(self, key: jax.Array, abstract: bool = False
             ) -> Tuple[Params, Dict[str, tuple]]:
        ctx = InitCtx(key, abstract=abstract,
                      param_dtype=jnp.dtype(self.plan.param_dtype))
        if self.cfg.family == "encdec":
            params = wh.init_encdec(ctx, self.cfg, self.plan)
        else:
            params = tf.init_lm(ctx, self.cfg, self.plan)
        return params, ctx.axes

    def abstract_params(self) -> Tuple[Params, Dict[str, tuple]]:
        return self.init(jax.random.PRNGKey(0), abstract=True)

    def param_specs(self):
        params, axes = self.abstract_params()
        return partition_specs(self.plan, axes, params), params

    # ---- forward ----------------------------------------------------------

    def forward(self, params: Params, tokens: jax.Array, *, env: AxisEnv,
                mode: str, positions=None, cache=None, frames=None,
                patch_embeds=None, block_tables=None, paged_kernel="auto",
                block_s=0, kv_valid_len=None, gather_fn=None):
        if self.cfg.family == "encdec":
            if block_s:
                raise ValueError(
                    "block_s override is not supported for encdec "
                    "decode (no paged/flash-chunk seam to tune)")
            if mode in ("chunk_prefill", "verify"):
                raise ValueError(f"mode={mode!r} needs the paged pool; "
                                 "encdec has no paged cache")
            return wh.forward_encdec(
                params, tokens, cfg=self.cfg, plan=self.plan, env=env,
                mode=mode, frames=frames, positions=positions, cache=cache,
                gather_fn=gather_fn)
        return tf.forward(
            params, tokens, cfg=self.cfg, plan=self.plan, env=env, mode=mode,
            positions=positions, cache=cache, patch_embeds=patch_embeds,
            block_tables=block_tables, paged_kernel=paged_kernel,
            block_s=block_s, kv_valid_len=kv_valid_len, gather_fn=gather_fn)

    # ---- decode cache -----------------------------------------------------

    def supports_paged_kv(self) -> bool:
        """Paged KV needs every layer to be attention (pure transformer):
        recurrent states (mamba/rwkv) are per-slot, not per-token."""
        cfg = self.cfg
        if cfg.family in ("rwkv", "encdec"):
            return False
        sb = tf.super_block_size(cfg)
        return all(cfg.is_attention_layer(j) for j in range(sb))

    def init_cache(self, batch: int, max_seq: int, *,
                   abstract: bool = False, dtype=None, paged: bool = False,
                   num_blocks: int = 0, block_size: int = 0,
                   scale_dtype=None):
        cfg, plan = self.cfg, self.plan
        dtype = dtype or jnp.dtype(plan.cache_dtype)
        if cfg.family == "encdec":
            return wh.init_encdec_cache(cfg, plan, batch, max_seq,
                                        dtype=dtype, abstract=abstract)
        if paged:
            assert self.supports_paged_kv(), \
                f"{cfg.name}: paged KV needs an attention-only stack"
        n_sb = tf.n_super_blocks(cfg)
        sb = tf.super_block_size(cfg)

        def stack(tree):
            return jax.tree.map(
                lambda s: (jax.ShapeDtypeStruct((n_sb,) + s.shape, s.dtype)
                           if abstract else
                           jnp.zeros((n_sb,) + s.shape, s.dtype)),
                tree,
                is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct,
                                                 jax.Array)))

        kv_w = plan_kv_seq_width(plan)
        out = {}
        for j in range(sb):
            if cfg.family == "rwkv":
                c = rwkv_mod.init_rwkv_state(cfg, plan, batch,
                                             abstract=True, dtype=dtype)
            elif cfg.is_attention_layer(j):
                c = attn_mod.init_cache(plan, batch, max_seq, dtype=dtype,
                                        abstract=True, kv_seq_width=kv_w,
                                        paged=paged, num_blocks=num_blocks,
                                        block_size=block_size,
                                        scale_dtype=scale_dtype)
            else:
                c = mamba_mod.init_mamba_state(cfg, plan, batch,
                                               abstract=True, dtype=dtype)
            out[f"l{j}"] = stack(c)
        return out

    def cache_specs(self, env: AxisEnv, paged: bool = False,
                    kv_quant: bool = False):
        """PartitionSpec tree matching init_cache (decoder-only families).

        ``paged=True`` describes the shared block pool: stacked per-layer
        leaves are (n_sb, num_blocks, block_size, Gp, dh) with the stored
        kv heads (Gp) sharded over the model ring — each rank holds its
        head shard of EVERY block, so one host-side block table drives
        all ranks and pool bytes split 1/tp per rank.  ``kv_quant=True``
        adds the quantized pool's scale side-array specs (same layout
        minus the d_head axis, heads likewise ring-sharded).
        """
        cfg, plan = self.cfg, self.plan
        dp = tuple(env.dp) if env.dp else None
        m = plan.tp_axis
        scat = m if plan.esl_overlap else None
        kv_w = plan_kv_seq_width(plan)

        if cfg.family == "encdec":
            kv = P(None, dp, None, m, None)
            return {"k": kv, "v": kv, "ck": kv, "cv": kv}

        sb = tf.super_block_size(cfg)
        out = {}
        for j in range(sb):
            if cfg.family == "rwkv":
                out[f"l{j}"] = {"shift_t": P(None, dp, None, scat),
                                "shift_c": P(None, dp, None, scat),
                                "wkv": P(None, dp, m, None, None)}
            elif cfg.is_attention_layer(j):
                if paged:
                    out[f"l{j}"] = {"k": P(None, None, None, m, None),
                                    "v": P(None, None, None, m, None)}
                    if kv_quant:
                        out[f"l{j}"]["k_scale"] = P(None, None, None, m)
                        out[f"l{j}"]["v_scale"] = P(None, None, None, m)
                elif kv_w > 1:
                    out[f"l{j}"] = {"k": P(None, dp, env.kv_seq_axis, None,
                                           m, None),
                                    "v": P(None, dp, env.kv_seq_axis, None,
                                           m, None)}
                else:
                    out[f"l{j}"] = {"k": P(None, dp, None, m, None),
                                    "v": P(None, dp, None, m, None)}
            else:
                out[f"l{j}"] = {"conv": P(None, dp, None, m),
                                "ssm": P(None, dp, m, None)}
        return out


def plan_kv_seq_width(plan) -> int:
    if getattr(plan, "kv_seq_axis", None):
        return dict(zip(plan.mesh_axes, plan.mesh_shape))[plan.kv_seq_axis]
    return 1


def build_model(cfg, plan) -> Model:
    return Model(cfg=cfg, plan=plan)
