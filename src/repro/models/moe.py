"""Mixture-of-Experts with hierarchical expert parallelism.

Expert placement (mapper-decided, rank-major storage):

* expert weights are stored rank-major on dim 0 over ``expert_axes``
  (width W): rank with linear EP index ``l`` owns expert *group*
  ``g = l // split`` (``Ecell = E_pad / n_groups`` experts) and FFN
  column-half ``h = l % split``.
* ``expert_axes == ('model',)``: tokens are replicated across the model
  ring (they arrive via the ESL all-gather anyway), so dispatch is a
  purely local top-C selection; expert partial outputs (and FFN halves)
  combine in ONE ``psum`` over the ring — which doubles as the layer's
  row-parallel sync.  No all-to-all needed.
* ``expert_axes == ('data','model')`` (giant-MoE serving, llama4-400B):
  tokens are data-sharded, so each model column all-to-alls its token
  buckets across the data axis to the experts' owner rows; each
  (data,model) rank computes its (group, half) cell; a reverse
  all-to-all returns partials which combine via the same ring psum.

Capacity-based (top-C per bucket) dispatch with static shapes; overflow
drops follow the standard Switch discipline and are counted in ``stats``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import esl
from repro.core.dist import AxisEnv
from repro.models.common import InitCtx, activate

Params = Dict[str, Any]


def _ceil_to(x, m):
    return (x + m - 1) // m * m


def moe_layout(plan):
    """(W, split, n_groups, Ecell, E_pad, ffh) for the plan's MoE."""
    m = plan.moe
    sizes = dict(zip(plan.mesh_axes or (), plan.mesh_shape))
    w = 1
    for a in m.expert_axes:
        w *= sizes.get(a, 1)
    w = max(w, 1)
    split = m.ffn_split
    n_groups = max(w // max(split, 1), 1)
    e_pad = _ceil_to(m.n_experts, n_groups)
    ecell = e_pad // n_groups
    ffh = m.d_ff_expert_shard
    return w, split, n_groups, ecell, e_pad, ffh


def init_moe(ctx: InitCtx, cfg, plan, name: str = "moe") -> Params:
    D = cfg.d_model
    m = plan.moe
    w, split, n_groups, ecell, e_pad, ffh = moe_layout(plan)
    dffe = ffh * max(split, 1)
    s1 = 1.0 / math.sqrt(D)
    s2 = 1.0 / math.sqrt(max(dffe, 1))

    def expert_builder(n_in, n_out, transpose_half, scale):
        # logical (E, n_in, n_out) -> rank-major (W, Ecell, n_in, n_out_half)
        def build(key):
            wlog = jax.random.normal(key, (e_pad, n_in, n_out),
                                     jnp.float32) * scale
            # zero padded experts
            if e_pad > m.n_experts:
                mask = (jnp.arange(e_pad) < m.n_experts)[:, None, None]
                wlog = wlog * mask
            parts = []
            for l in range(w):
                g, h = divmod(l, split)
                blk = wlog[g * ecell:(g + 1) * ecell]
                if transpose_half:   # FC2: rows (ffn) are split
                    blk = blk[:, h * (n_in // split):(h + 1) * (n_in // split), :]
                else:                # FC1: columns (ffn) are split
                    blk = blk[:, :, h * (n_out // split):(h + 1) * (n_out // split)]
                parts.append(blk)
            return jnp.stack(parts, 0)
        return build

    with ctx.scope(name):
        p: Params = {
            "router": ctx.param("router", (D, e_pad), ("embed", None),
                                scale=1.0),
            "wg": ctx.param_from(
                "wg", (w, ecell, D, ffh), ("experts", None, "embed", None),
                expert_builder(D, dffe, False, s1)),
            "wu": ctx.param_from(
                "wu", (w, ecell, D, ffh), ("experts", None, "embed", None),
                expert_builder(D, dffe, False, s1)),
            "wd": ctx.param_from(
                "wd", (w, ecell, ffh, D), ("experts", None, None, "embed"),
                expert_builder(dffe, D, True, s2)),
        }
        if cfg.moe.n_shared_experts:
            dsh = cfg.moe.n_shared_experts * plan.d_ff_shard * plan.tp
            with ctx.scope("shared"):
                p["shared"] = {
                    "wg": ctx.param("wg", (D, dsh), ("embed", "ffn"),
                                    scale=1.0),
                    "wu": ctx.param("wu", (D, dsh), ("embed", "ffn"),
                                    scale=1.0),
                    "wd": ctx.param("wd", (dsh, D), ("ffn", "embed"),
                                    scale=1.0),
                }
    return p


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def _route(p, xf, cfg, plan):
    """xf: (T, D) full tokens.  Returns top-k (ids, gates, probs)."""
    m = cfg.moe
    _, _, _, _, e_pad, _ = moe_layout(plan)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if e_pad > m.n_experts:
        logits = jnp.where(jnp.arange(e_pad) < m.n_experts, logits,
                           jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return ids, gates, probs


def _lb_loss(probs, ids, n_experts):
    """Switch-style load-balancing auxiliary loss."""
    e = probs.shape[-1]
    hot = jax.nn.one_hot(ids, e, dtype=jnp.float32)        # (T,k,E)
    frac_tokens = jnp.mean(jnp.sum(hot, 1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)


def _expert_ffn(wg, wu, wd, xt, activation):
    """xt: (..., C, D); expert mats (D, ffh)/(ffh, D)."""
    g = jnp.einsum("...cd,df->...cf", xt, wg)
    u = jnp.einsum("...cd,df->...cf", xt, wu)
    h = activate(g, activation) * u
    return jnp.einsum("...cf,fd->...cd", h, wd)


def _select_topc(score, cap):
    """Indices of up to `cap` rows with score>0 (stable-ish)."""
    vals, idx = lax.top_k(score, cap)
    return idx, (vals > 0)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def moe_fwd(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D/tp) scattered or (B,S,D) full.  Returns (y, aux_loss).

    y matches x's sharding convention (scattered when ESL overlap is on).
    """
    overlap = plan.esl_overlap
    B, S = x.shape[0], x.shape[1]
    xf = (esl.gather_scattered(x, axis=env.model, tp=env.tp)
          if overlap else x)
    T, D = B * S, xf.shape[-1]
    xt = xf.reshape(T, D)

    ids, gates, probs = _route(p, xt, cfg, plan)
    aux = _lb_loss(probs, ids, cfg.moe.n_experts)

    w, split, n_groups, ecell, e_pad, ffh = moe_layout(plan)
    use_a2a = len(plan.moe.expert_axes) > 1 and env.model is not None

    if env.model is None:
        out = _moe_local_all(p, xt, ids, gates, cfg, plan)
    elif not use_a2a:
        out = _moe_model_parallel(p, xt, ids, gates, cfg, plan, env)
    else:
        out = _moe_data_model(p, xt, ids, gates, cfg, plan, env)

    # combine expert partials (and FFN halves) over the ring; this psum is
    # the layer's row-parallel sync — in ESL mode it reduce-scatters
    # directly into the scattered activation domain.
    if env.model is not None:
        if overlap:
            out = lax.psum_scatter(out, env.model,
                                   scatter_dimension=out.ndim - 1, tiled=True)
        else:
            out = lax.psum(out, env.model)

    if "shared" in p:
        sh = p["shared"]
        xin = x
        g = esl.ag_matmul(xin, jnp.concatenate([sh["wg"], sh["wu"]], -1),
                          axis=env.model, tp=env.tp, overlap=overlap)
        gg, uu = jnp.split(g, 2, -1)
        hh = activate(gg, cfg.activation) * uu
        out_sh = esl.rs_matmul(hh, sh["wd"], axis=env.model, tp=env.tp,
                               overlap=overlap, scatter_out=overlap)
        out = out + out_sh.reshape(out.shape[0], -1) \
            if out.ndim == 2 else out + out_sh
    return out.reshape(x.shape), aux


def _capacity(T, k, buckets, cf):
    c = int(math.ceil(T * k * cf / max(buckets, 1)))
    return max(8, _ceil_to(c, 8))


def _moe_local_all(p, xt, ids, gates, cfg, plan):
    """Single-device smoke path: loop over all experts."""
    _, _, _, ecell, e_pad, _ = moe_layout(plan)
    T, D = xt.shape
    k = ids.shape[-1]
    cap = _capacity(T, k, e_pad, plan.moe.capacity_factor)
    out = jnp.zeros((T, D), xt.dtype)
    wg, wu, wd = p["wg"][0], p["wu"][0], p["wd"][0]   # (Ecell=E_pad,...)
    for e in range(e_pad):
        match = (ids == e)                             # (T,k)
        score = jnp.max(match.astype(jnp.float32), -1)
        gate = jnp.sum(jnp.where(match, gates, 0.0), -1)
        idx, valid = _select_topc(score, min(cap, T))
        tok = jnp.take(xt, idx, axis=0)
        y = _expert_ffn(wg[e], wu[e], wd[e], tok, cfg.activation)
        y = y * (gate[idx] * valid)[:, None].astype(y.dtype)
        out = out.at[idx].add(y)
    return out


def _moe_model_parallel(p, xt, ids, gates, cfg, plan, env):
    """EP over the model ring: local select, compute, (caller) psum."""
    _, split, n_groups, ecell, e_pad, _ = moe_layout(plan)
    T, D = xt.shape
    k = ids.shape[-1]
    cap = _capacity(T, k, e_pad, plan.moe.capacity_factor)
    cap = min(cap, T)
    l = lax.axis_index(env.model)                      # linear EP index
    g = l // split
    wg, wu, wd = p["wg"][0], p["wu"][0], p["wd"][0]   # local (Ecell,...)
    out = jnp.zeros((T, D), xt.dtype)
    for c in range(ecell):
        e = g * ecell + c                              # traced expert id
        match = ids == e[..., None] if hasattr(e, "ndim") else ids == e
        score = jnp.max(match.astype(jnp.float32), -1)
        gate = jnp.sum(jnp.where(match, gates, 0.0), -1)
        idx, valid = _select_topc(score, cap)
        tok = jnp.take(xt, idx, axis=0)
        y = _expert_ffn(wg[c], wu[c], wd[c], tok, cfg.activation)
        y = y * (gate[idx] * valid)[:, None].astype(y.dtype)
        out = out.at[idx].add(y)
    return out


def _moe_data_model(p, xt, ids, gates, cfg, plan, env):
    """EP spanning (data, model): bucketed all-to-all over `data`.

    Column `m` forwards an assignment (t, e) iff the (group(e), half)
    cell whose model-column is `m` exists, i.e.
    ``h* = (m - group(e)*split) mod tp`` with ``h* < split``; the
    destination data row is ``(group(e)*split + h*) // tp``.
    """
    m_ax, d_ax = env.model, "data"
    tp = env.tp
    _, split, n_groups, ecell, e_pad, _ = moe_layout(plan)
    T, D = xt.shape
    k = ids.shape[-1]
    dwidth = dict(zip(plan.mesh_axes, plan.mesh_shape))["data"]
    cap = _capacity(T, k, dwidth * max(1, n_groups // dwidth),
                    plan.moe.capacity_factor)
    cap = min(cap, T * k)
    m_idx = lax.axis_index(m_ax)

    ids_f = ids.reshape(-1)                            # (T*k,)
    gates_f = gates.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(T), k)
    grp = ids_f // ecell
    h_star = (m_idx - grp * split) % tp
    sendable = h_star < split
    dest_data = (grp * split + h_star) // tp           # (T*k,)

    buckets_x, buckets_meta = [], []
    for dd in range(dwidth):
        score = (sendable & (dest_data == dd)).astype(jnp.float32)
        # prefer high-gate assignments under capacity pressure
        idx, valid = _select_topc(score * (1.0 + gates_f), cap)
        ok = valid & (score[idx] > 0)
        bx = jnp.take(xt, jnp.take(tok_of, idx), axis=0)
        bx = bx * ok[:, None].astype(bx.dtype)
        meta = jnp.stack([jnp.take(tok_of, idx).astype(jnp.float32),
                          jnp.take(ids_f, idx).astype(jnp.float32),
                          jnp.take(gates_f, idx) * ok], -1)
        buckets_x.append(bx)
        buckets_meta.append(meta)
    bx = jnp.stack(buckets_x, 0)                       # (dwidth, cap, D)
    bm = jnp.stack(buckets_meta, 0)                    # (dwidth, cap, 3)
    rx = lax.all_to_all(bx, d_ax, split_axis=0, concat_axis=0, tiled=False)
    rm = lax.all_to_all(bm, d_ax, split_axis=0, concat_axis=0, tiled=False)

    # this rank's cell: group g_mine, half h_mine
    d_idx = lax.axis_index(d_ax)
    l = d_idx * tp + m_idx
    g_mine = l // split
    r_ids = rm[..., 1].astype(jnp.int32)               # (dwidth, cap)
    r_gate = rm[..., 2]
    y = jnp.zeros_like(rx)
    for c in range(ecell):
        e = g_mine * ecell + c
        mask = (r_ids == e) & (r_gate > 0)
        xin = rx * mask[..., None].astype(rx.dtype)
        yc = _expert_ffn(p["wg"][0, c], p["wu"][0, c], p["wd"][0, c],
                         xin, cfg.activation)
        y = y + yc * mask[..., None].astype(yc.dtype)
    y = y * r_gate[..., None].astype(y.dtype)

    back = lax.all_to_all(y, d_ax, split_axis=0, concat_axis=0, tiled=False)
    out = jnp.zeros((T, D), xt.dtype)
    for dd in range(dwidth):
        t_idx = bm[dd, :, 0].astype(jnp.int32)
        out = out.at[t_idx].add(back[dd])
    return out
