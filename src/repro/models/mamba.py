"""Mamba (S6) block for the jamba hybrid stack.

Sharding: d_inner is column-tiled over the model ring (the mapper's
column-wise rule — conv and SSM are per-channel, so they stay rank-local);
in_proj is a streamed ``ag_matmul``, out_proj streams partial products
back (``rs_matmul``).  The small dt/B/C projection is row-parallel with a
(cheap) psum.  The selective scan is a chunked associative scan in the ref
path; ``kernels/mamba_scan`` is the Pallas twin.

Decode carries (conv_state, ssm_state) — constant memory per token, the
regime where the LPU's "stream parameters, tiny activations" argument is
strongest.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import esl
from repro.core.dist import AxisEnv
from repro.models.common import InitCtx

Params = Dict[str, Any]


def mamba_dims(cfg, plan) -> Tuple[int, int]:
    """(d_inner_padded, d_inner_shard)."""
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    pad = ((d_in + plan.tp - 1) // plan.tp) * plan.tp
    return pad, pad // plan.tp


def init_mamba(ctx: InitCtx, cfg, plan, name: str = "mamba") -> Params:
    m = cfg.mamba
    D = cfg.d_model
    d_in, _ = mamba_dims(cfg, plan)
    s = 1.0 / math.sqrt(D)
    with ctx.scope(name):
        p: Params = {
            # separate x/z projections: a fused (D, 2*d_in) tile would split
            # the concatenated halves across ranks instead of per-half
            "in_x": ctx.param("in_x", (D, d_in),
                              ("embed", "mamba_inner"), scale=1.0),
            "in_z": ctx.param("in_z", (D, d_in),
                              ("embed", "mamba_inner"), scale=1.0),
            "conv_w": ctx.param("conv_w", (m.d_conv, d_in),
                                ("conv", "mamba_inner"), scale=1.0),
            "conv_b": ctx.param("conv_b", (d_in,), ("mamba_inner",),
                                init="zeros"),
            "x_proj": ctx.param("x_proj", (d_in, m.dt_rank + 2 * m.d_state),
                                ("mamba_inner", None), scale=1.0),
            "dt_proj": ctx.param("dt_proj", (m.dt_rank, d_in),
                                 ("dt", "mamba_inner"), scale=1.0),
            "dt_bias": ctx.param("dt_bias", (d_in,), ("mamba_inner",),
                                 init="zeros"),
            "a_log": ctx.param_from(
                "a_log", (d_in, m.d_state), ("mamba_inner", "state"),
                lambda k: jnp.log(jnp.broadcast_to(
                    jnp.arange(1, m.d_state + 1, dtype=jnp.float32),
                    (d_in, m.d_state)))),
            "d_skip": ctx.param("d_skip", (d_in,), ("mamba_inner",),
                                init="ones"),
            "out_proj": ctx.param("out_proj", (d_in, D),
                                  ("mamba_inner", "embed"),
                                  scale=1.0 / math.sqrt(d_in) * math.sqrt(d_in) ** 0),
        }
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over seq.  x: (B,S,C); w: (K,C).

    Returns (y, new_state) with state = last K-1 inputs (for decode).
    """
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, xp.shape[1] - (K - 1):, :]
    return y + b, new_state


def _ssm_scan(a: jax.Array, bx: jax.Array, c: jax.Array,
              h0: jax.Array, chunk: int = 128
              ) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + bx_t;  y_t = sum_n c_tn * h_tn.

    a, bx: (B,S,C,N); c: (B,S,N).  Chunked associative scan.
    Returns (y (B,S,C), h_final (B,C,N)).
    """
    B, S, C, N = a.shape
    chunk = min(chunk, S)
    n_chunks = (S + chunk - 1) // chunk
    pad = n_chunks * chunk - S
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    ac = a.reshape(B, n_chunks, chunk, C, N).transpose(1, 0, 2, 3, 4)
    bc = bx.reshape(B, n_chunks, chunk, C, N).transpose(1, 0, 2, 3, 4)
    cc = c.reshape(B, n_chunks, chunk, N).transpose(1, 0, 2, 3)

    def chunk_body(h, inp):
        ak, bk, ck = inp

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        aa, bb = lax.associative_scan(combine, (ak, bk), axis=1)
        h_all = aa * h[:, None] + bb                   # (B,chunk,C,N)
        y = jnp.einsum("bscn,bsn->bsc", h_all, ck)
        return h_all[:, -1], y

    h_fin, ys = lax.scan(chunk_body, h0, (ac, bc, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, C)
    return y[:, :S], h_fin


def mamba_fwd(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
              state: Optional[Dict[str, jax.Array]] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,S,D/tp) scattered or (B,S,D).  state: decode carry or None.

    Returns (y in x's convention, new_state).
    """
    m = cfg.mamba
    overlap = plan.esl_overlap
    B, S = x.shape[0], x.shape[1]

    w_in = jnp.concatenate([p["in_x"], p["in_z"]], axis=-1)  # local halves
    xz = esl.ag_matmul(x, w_in, axis=env.model, tp=env.tp,
                       overlap=overlap)
    xs, z = jnp.split(xz, 2, axis=-1)                  # (B,S,din_loc)

    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(
        xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)

    # dt/B/C: row-parallel small projection (psum over the ring)
    dbc = jnp.einsum("bsc,cr->bsr", xs, p["x_proj"])
    if env.model is not None:
        dbc = lax.psum(dbc, env.model)
    dt, bmat, cmat = jnp.split(
        dbc, [m.dt_rank, m.dt_rank + m.d_state], axis=-1)
    dt = jnp.einsum("bsr,rc->bsc", dt, p["dt_proj"]) + p["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))       # (B,S,din_loc)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))       # (din_loc,N)
    da = jnp.exp(dt[..., None] * a)                    # (B,S,C,N)
    bx = (dt * xs.astype(jnp.float32))[..., None] * \
        bmat.astype(jnp.float32)[:, :, None, :]        # (B,S,C,N)

    if S == 1 and state is not None:
        # generation stage: one recurrence step, constant memory
        h0 = state["ssm"]
        h = da[:, 0] * h0 + bx[:, 0]
        y = jnp.einsum("bcn,bn->bc", h, cmat[:, 0].astype(jnp.float32))[:, None]
        new_state = {"conv": new_conv, "ssm": h}
    else:
        h0 = (state["ssm"] if state is not None
              else jnp.zeros((B, xs.shape[-1], m.d_state), jnp.float32))
        y, h_fin = _ssm_scan(da, bx, cmat.astype(jnp.float32), h0)
        new_state = {"conv": new_conv, "ssm": h_fin}

    y = y.astype(xs.dtype) + xs * p["d_skip"]
    y = y * jax.nn.silu(z)
    out = esl.rs_matmul(y, p["out_proj"], axis=env.model, tp=env.tp,
                        overlap=overlap, scatter_out=overlap)
    return out, new_state


def init_mamba_state(cfg, plan, batch: int, abstract: bool = False,
                     dtype=jnp.bfloat16):
    m = cfg.mamba
    d_in, _ = mamba_dims(cfg, plan)
    conv = (batch, m.d_conv - 1, d_in)
    ssm = (batch, d_in, m.d_state)
    if abstract:
        return {"conv": jax.ShapeDtypeStruct(conv, dtype),
                "ssm": jax.ShapeDtypeStruct(ssm, jnp.float32)}
    return {"conv": jnp.zeros(conv, dtype),
            "ssm": jnp.zeros(ssm, jnp.float32)}
