"""RWKV6 ("Finch") — attention-free, data-dependent per-channel decay.

The purest case for the LPU thesis: decode has *no* KV cache at all —
per-layer state is one (head_dim x head_dim) matrix per head plus two
shift vectors, so token latency is entirely weight-streaming bound.

Sharding: heads (= channel blocks of head_dim) are column tiles over the
model ring; token-shift, decay and the WKV recurrence are per-channel and
stay rank-local.  r/k/v/g projections stream through ``ag_matmul``; the
output projection streams partials back (``rs_matmul``).

Ref recurrence (validated against the Pallas ``rwkv_scan`` kernel):
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(w0 + lora_w(x_t))) in (0,1), per channel.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import esl
from repro.core.dist import AxisEnv, model_rank
from repro.models.common import InitCtx

Params = Dict[str, Any]

_MIX = ("r", "k", "v", "g", "w")


def rwkv_dims(cfg, plan) -> Tuple[int, int, int]:
    """(heads_padded_total, heads_per_rank, head_dim)."""
    a = plan.attn
    return a.hp, a.q_per_rank, cfg.rwkv.head_dim


def init_time_mix(ctx: InitCtx, cfg, plan, name: str = "tmix") -> Params:
    r = cfg.rwkv
    D = cfg.d_model
    hp, hpr, dh = rwkv_dims(cfg, plan)
    dproj = hp * dh                                    # padded head width
    with ctx.scope(name):
        p: Params = {}
        p["mu_x"] = ctx.param("mu_x", (D,), ("vec",), init="uniform",
                              scale=0.5)
        for nm in _MIX:
            p[f"mu_{nm}"] = ctx.param(f"mu_{nm}", (D,), ("vec",),
                                      init="uniform", scale=0.5)
        p["mix_w1"] = ctx.param("mix_w1", (D, 5 * r.mix_lora),
                                ("embed", "lora"), scale=1.0)
        p["mix_w2"] = ctx.param("mix_w2", (5, r.mix_lora, D),
                                (None, "lora", "embed_scatter"), scale=0.1)
        for nm in ("r", "k", "v", "g"):
            p[f"w_{nm}"] = ctx.param(f"w_{nm}", (D, dproj),
                                     ("embed", "rwkv_heads"), scale=1.0)
        p["w_o"] = ctx.param("w_o", (dproj, D), ("rwkv_heads", "embed"),
                             scale=1.0)
        p["decay_w0"] = ctx.param("decay_w0", (dproj,), ("rwkv_heads",),
                                  init="uniform", scale=1.0)
        p["decay_w1"] = ctx.param("decay_w1", (D, r.decay_lora),
                                  ("embed", "lora"), scale=1.0)
        p["decay_w2"] = ctx.param("decay_w2", (r.decay_lora, dproj),
                                  ("lora", "rwkv_heads"), scale=0.1)
        p["bonus_u"] = ctx.param("bonus_u", (dproj,), ("rwkv_heads",),
                                 init="uniform", scale=0.5)
        p["ln_x"] = ctx.param("ln_x", (dproj,), ("rwkv_heads",), init="ones")
    return p


def init_channel_mix(ctx: InitCtx, cfg, plan, name: str = "cmix") -> Params:
    D = cfg.d_model
    ff = plan.d_ff_padded
    with ctx.scope(name):
        return {
            "mu_k": ctx.param("mu_k", (D,), ("vec",), init="uniform",
                              scale=0.5),
            "mu_r": ctx.param("mu_r", (D,), ("vec",), init="uniform",
                              scale=0.5),
            "w_k": ctx.param("w_k", (D, ff), ("embed", "ffn"), scale=1.0),
            "w_v": ctx.param("w_v", (ff, D), ("ffn", "embed"), scale=1.0),
            # receptance: column tiles so r matches the scattered output
            "w_r": ctx.param("w_r", (D, D), ("embed", "ffn"), scale=1.0),
        }


# ---------------------------------------------------------------------------
# token shift (works identically on scattered channels)
# ---------------------------------------------------------------------------

def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1}; `prev` is the carried last token for decode/continuation."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


def _vslice(v: jax.Array, env: AxisEnv, plan) -> jax.Array:
    """'vec' params arrive model-sharded; gather only in the blocking
    baseline where activations are full."""
    return esl.full_vec(v, axis=env.model, tp=env.tp,
                        scattered_activations=plan.esl_overlap)


def _head_local(v: jax.Array, env: AxisEnv, plan) -> jax.Array:
    """'rwkv_heads' params arrive as the local head slice already."""
    return v


# ---------------------------------------------------------------------------
# WKV recurrence (ref path; kernels/rwkv_scan is the Pallas twin)
# ---------------------------------------------------------------------------

def wkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, s0: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,S,H,dh) f32; u: (H,dh); s0: (B,H,dh,dh) f32.

    Returns (y (B,S,H,dh), s_final).  Per-step reference recurrence.
    """
    def step(s, inp):
        rt, kt, vt, wt = inp                           # (B,H,dh)
        kv = kt[..., :, None] * vt[..., None, :]       # (B,H,dh,dh)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    rs, ks, vs, ws = (t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    s_fin, ys = lax.scan(step, s0, (rs, ks, vs, ws))
    return ys.transpose(1, 0, 2, 3), s_fin


def wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, s0: jax.Array, chunk: int = 32
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV — §Perf iteration (rwkv x train_4k).

    The per-step scan writes the (dh x dh) state to HBM every token:
    2 x 4.2 MB x S x L per device.  Chunking the recurrence (the same
    dataflow the Pallas kernel uses with VMEM-resident state) cuts state
    traffic by the chunk length and turns the inner math into dense
    einsums.  Numerically stable: every decay exponent is <= 0
    (L is non-increasing, so L_{t-1}-L_s <= 0 for s < t, and
    L_last - L_s <= 0).

    Matches ``wkv_scan`` to ~1e-4 (tests/test_rwkv_chunked.py).
    """
    B, S, H, dh = r.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        zeros = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    n = (S + pad) // chunk

    def to_chunks(t):
        return t.reshape(B, n, chunk, H, dh).transpose(1, 0, 3, 2, 4)

    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)  # (n,B,H,C,dh)
    lw = jnp.log(jnp.maximum(to_chunks(w), 1e-38))

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)

    def body(s, inp):
        rb, kb, vb, lwb = inp                         # (B,H,C,dh)
        L = jnp.cumsum(lwb, axis=2)                   # inclusive
        L_in = L - lwb                                # exclusive (L_{t-1})
        Lc = L[:, :, -1:, :]                          # (B,H,1,dh)
        # carry contribution: (r_t * exp(L_{t-1})) . S
        y_carry = jnp.einsum("bhtd,bhdv->bhtv", rb * jnp.exp(L_in), s)
        # intra-chunk: M[t,s] = sum_d r_t exp(L_{t-1}-L_s) k_s, s<t
        decay = jnp.exp(jnp.clip(L_in[:, :, :, None, :]
                                 - L[:, :, None, :, :], -60.0, 0.0))
        m = jnp.einsum("bhtd,bhtsd,bhsd->bhts", rb, decay, kb)
        m = m * tri
        y_intra = jnp.einsum("bhts,bhsv->bhtv", m, vb)
        # diagonal bonus
        y_diag = jnp.sum(rb * u[None, :, None, :] * kb, -1,
                         keepdims=True) * vb
        # state update: S' = exp(Lc) . S + sum_s (k_s exp(Lc - L_s)) v_s
        k_dec = kb * jnp.exp(jnp.clip(Lc - L, -60.0, 0.0))
        s_new = jnp.exp(Lc[:, :, 0, :, None]) * s + \
            jnp.einsum("bhsd,bhsv->bhdv", k_dec, vb)
        return s_new, y_carry + y_intra + y_diag

    s_fin, ys = lax.scan(body, s0, (rc, kc, vc, lw))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, n * chunk, H, dh)
    return y[:, :S], s_fin


def time_mix_fwd(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
                 state: Optional[Dict[str, jax.Array]] = None
                 ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,S,D/tp) scattered or (B,S,D) full.

    state: {'shift': (B,1,D[/tp]), 'wkv': (B,hpr,dh,dh)} decode carry.
    """
    overlap = plan.esl_overlap
    hp, hpr, dh = rwkv_dims(cfg, plan)
    B, S = x.shape[0], x.shape[1]
    prev = state["shift"] if state is not None else None
    xx = _shift(x, prev)
    dx = xx - x

    # data-dependent token-shift lerps (low-rank adjusted)
    xm = x + dx * _vslice(p["mu_x"], env, plan)
    lora = jnp.tanh(esl.ag_matmul(xm, p["mix_w1"], axis=env.model,
                                  tp=env.tp, overlap=overlap))
    lora = lora.reshape(B, S, 5, -1)
    mixed = {}
    for i, nm in enumerate(_MIX):
        adj = jnp.einsum("bsl,ld->bsd", lora[:, :, i],
                         _mix_w2_local(p["mix_w2"], i, env, plan))
        mu = _vslice(p[f"mu_{nm}"], env, plan)
        mixed[nm] = x + dx * (mu + adj)

    r = esl.ag_matmul(mixed["r"], p["w_r"], axis=env.model, tp=env.tp,
                      overlap=overlap)
    kk = esl.ag_matmul(mixed["k"], p["w_k"], axis=env.model, tp=env.tp,
                       overlap=overlap)
    vv = esl.ag_matmul(mixed["v"], p["w_v"], axis=env.model, tp=env.tp,
                       overlap=overlap)
    g = jax.nn.silu(esl.ag_matmul(mixed["g"], p["w_g"], axis=env.model,
                                  tp=env.tp, overlap=overlap))
    dlo = jnp.tanh(esl.ag_matmul(mixed["w"], p["decay_w1"], axis=env.model,
                                 tp=env.tp, overlap=overlap))
    dw = jnp.einsum("bsl,lc->bsc", dlo, _head_local(p["decay_w2"], env, plan))
    w0 = _head_local(p["decay_w0"], env, plan)
    w = jnp.exp(-jnp.exp((w0 + dw).astype(jnp.float32)))   # (B,S,C), (0,1)

    u = _head_local(p["bonus_u"], env, plan)
    shp = (B, S, hpr, dh)
    rr, kk4, vv4, ww = (t.astype(jnp.float32).reshape(shp)
                        for t in (r, kk, vv, w))
    s0 = (state["wkv"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, hpr, dh, dh), jnp.float32))
    if S > 1:
        # chunked formulation: state stays resident across a chunk
        # (§Perf: 6.7e6 ms -> see EXPERIMENTS.md; per-step scan spilled
        # the state matrix to HBM every token)
        y, s_fin = wkv_chunked(rr, kk4, vv4, ww, u.reshape(hpr, dh), s0)
    else:
        y, s_fin = wkv_scan(rr, kk4, vv4, ww, u.reshape(hpr, dh), s0)

    # per-head group norm
    mean = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = (y - mean) * lax.rsqrt(var + 1e-5)
    y = y.reshape(B, S, hpr * dh) * _head_local(p["ln_x"], env, plan)
    y = y.astype(x.dtype) * g

    out = esl.rs_matmul(y, p["w_o"], axis=env.model, tp=env.tp,
                        overlap=overlap, scatter_out=overlap)
    new_state = None
    if state is not None:
        new_state = {"shift": x[:, -1:, :], "wkv": s_fin}
    return out, new_state


def _mix_w2_local(w2: jax.Array, i: int, env: AxisEnv, plan) -> jax.Array:
    """mix_w2[i]: arrives (lora, D/tp) local ('embed_scatter'); in the
    blocking baseline the lerp target x is full, so gather."""
    w = w2[i]
    if plan.esl_overlap or env.model is None:
        return w
    return lax.all_gather(w, env.model, axis=w.ndim - 1, tiled=True)


def channel_mix_fwd(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
                    state: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """state: (B,1,D[/tp]) previous-token carry (decode)."""
    overlap = plan.esl_overlap
    xx = _shift(x, state)
    dx = xx - x
    xk = x + dx * _vslice(p["mu_k"], env, plan)
    xr = x + dx * _vslice(p["mu_r"], env, plan)
    kk = esl.ag_matmul(xk, p["w_k"], axis=env.model, tp=env.tp,
                       overlap=overlap)
    kk = jnp.square(jax.nn.relu(kk))
    y = esl.rs_matmul(kk, p["w_v"], axis=env.model, tp=env.tp,
                      overlap=overlap, scatter_out=overlap)
    rr = esl.ag_matmul(xr, p["w_r"], axis=env.model, tp=env.tp,
                       overlap=overlap)
    if not overlap and env.model is not None:
        rr = esl.gather_scattered(rr, axis=env.model, tp=env.tp)
    y = jax.nn.sigmoid(rr.astype(jnp.float32)).astype(y.dtype) * y
    new_state = x[:, -1:, :] if state is not None else None
    return y, new_state


def init_rwkv_state(cfg, plan, batch: int, abstract: bool = False,
                    dtype=jnp.bfloat16):
    """Decode carry for one rwkv layer (global shapes)."""
    hp, hpr, dh = rwkv_dims(cfg, plan)
    D = cfg.d_model
    scattered = plan.esl_overlap and plan.mesh_axes is not None
    d_shift = D  # stored full; sliced on entry when scattered
    shift = (batch, 1, d_shift)
    wkv = (batch, hp, dh, dh)
    if abstract:
        return {"shift_t": jax.ShapeDtypeStruct(shift, dtype),
                "shift_c": jax.ShapeDtypeStruct(shift, dtype),
                "wkv": jax.ShapeDtypeStruct(wkv, jnp.float32)}
    return {"shift_t": jnp.zeros(shift, dtype),
            "shift_c": jnp.zeros(shift, dtype),
            "wkv": jnp.zeros(wkv, jnp.float32)}
