"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, enc_seq, d_model).  The
encoder runs once per request (a prefill-like summarization pass); the
decoder is a standard cached LM with cross-attention, i.e. exactly the
LPU's generation-stage regime plus one extra streamed matmul block.

Decoder cache per layer: self-attention K/V ring + cross-attention K/V
(computed once from encoder states at prefill).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import esl
from repro.core.dist import AxisEnv
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.common import InitCtx, init_norm, stacked_init
from repro.models.transformer import (_norm, embed_tokens, add_positional,
                                      lm_logits)

Params = Dict[str, Any]


def init_encoder_layer(ctx: InitCtx, cfg, plan) -> Params:
    return {
        "ln1": init_norm(ctx, "ln1", cfg.d_model, cfg.norm),
        "attn": attn_mod.init_attention(ctx, cfg, plan),
        "ln2": init_norm(ctx, "ln2", cfg.d_model, cfg.norm),
        "mlp": mlp_mod.init_mlp(ctx, cfg, plan, bias=True),
    }


def init_decoder_layer(ctx: InitCtx, cfg, plan) -> Params:
    return {
        "ln1": init_norm(ctx, "ln1", cfg.d_model, cfg.norm),
        "attn": attn_mod.init_attention(ctx, cfg, plan),
        "lnx": init_norm(ctx, "lnx", cfg.d_model, cfg.norm),
        "xattn": attn_mod.init_attention(ctx, cfg, plan, name="xattn"),
        "ln2": init_norm(ctx, "ln2", cfg.d_model, cfg.norm),
        "mlp": mlp_mod.init_mlp(ctx, cfg, plan, bias=True),
    }


def init_encdec(ctx: InitCtx, cfg, plan) -> Params:
    D = cfg.d_model
    p: Params = {}
    p["embed"] = ctx.param("embed", (plan.vocab_padded, D),
                           ("vocab", "embed"), scale=1.0)
    p["pos_embed"] = ctx.param("pos_embed", (cfg.max_seq, D),
                               ("pos", "embed_scatter"), scale=1.0)
    p["enc_blocks"] = stacked_init(
        ctx, "enc_blocks", cfg.encdec.n_enc_layers,
        lambda c: init_encoder_layer(c, cfg, plan))
    p["ln_enc"] = init_norm(ctx, "ln_enc", D, cfg.norm)
    p["dec_blocks"] = stacked_init(
        ctx, "dec_blocks", cfg.n_layers,
        lambda c: init_decoder_layer(c, cfg, plan))
    p["ln_f"] = init_norm(ctx, "ln_f", D, cfg.norm)
    return p


# ---------------------------------------------------------------------------


def run_encoder(params: Params, frames: jax.Array, *, cfg, plan,
                env: AxisEnv, gather_fn) -> jax.Array:
    """frames: (B, enc_seq, D) stub embeddings -> encoder states."""
    x = frames.astype(jnp.dtype(plan.compute_dtype))
    if plan.esl_overlap and env.model is not None:
        x = esl.scatter_full(x, axis=env.model, tp=env.tp)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(xc, bp):
        bp = gather_fn("enc_block", bp)
        h = attn_mod.self_attention(
            bp["attn"], _norm(bp["ln1"], xc, cfg, plan, env),
            cfg=cfg, plan=plan, env=env, positions=positions, causal=False)
        xc = xc + h
        h = mlp_mod.mlp_fwd(bp["mlp"], _norm(bp["ln2"], xc, cfg, plan, env),
                            cfg=cfg, plan=plan, env=env)
        return xc + h, None

    if plan.remat != "none":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_blocks"],
                    unroll=cfg.encdec.n_enc_layers if plan.scan_unroll else 1)
    return _norm(params["ln_enc"], x, cfg, plan, env)


def forward_encdec(params: Params, tokens: jax.Array, *, cfg, plan,
                   env: AxisEnv, mode: str,
                   frames: Optional[jax.Array] = None,
                   positions: Optional[jax.Array] = None,
                   cache: Optional[Params] = None,
                   gather_fn=None):
    """Returns (logits_sharded, new_cache, aux=0).

    train/prefill: ``frames`` required (stub encoder input).
    decode: cross K/V come from the cache; encoder is not re-run.
    """
    gather_fn = gather_fn or (lambda path, t: t)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    emb_p = gather_fn("embed", {k: params[k]
                                for k in ("embed", "pos_embed")})
    x = embed_tokens(emb_p, tokens, cfg, plan, env)
    x = add_positional(emb_p, x,
                       positions if mode != "decode" else positions[:, None],
                       cfg, plan, env)
    x = x.astype(jnp.dtype(plan.compute_dtype))

    enc_x = None
    if mode != "decode":
        assert frames is not None
        enc_x = run_encoder(params, frames, cfg=cfg, plan=plan, env=env,
                            gather_fn=gather_fn)

    if mode == "decode":
        # cache rides the carry: per token only the new KV entries are
        # written; cross-attention K/V are read-only (§Perf 1b)
        def dec_body(carry, xs):
            xc, cache_st = carry
            bp, idx = xs
            bp = gather_fn("dec_block", bp)
            sl = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, idx, 0,
                                                   keepdims=False),
                cache_st)
            h_in = _norm(bp["ln1"], xc, cfg, plan, env)
            h, upd = attn_mod.decode_attention(
                bp["attn"], h_in, cfg=cfg, plan=plan, env=env,
                cache={"k": sl["k"], "v": sl["v"]}, positions=positions)
            xc = xc + h
            h_in = _norm(bp["lnx"], xc, cfg, plan, env)
            h = attn_mod.cross_attention(
                bp["xattn"], h_in, cfg=cfg, plan=plan, env=env,
                enc_k=sl["ck"].astype(xc.dtype),
                enc_v=sl["cv"].astype(xc.dtype))
            xc = xc + h
            h = mlp_mod.mlp_fwd(bp["mlp"],
                                _norm(bp["ln2"], xc, cfg, plan, env),
                                cfg=cfg, plan=plan, env=env)
            xc = xc + h
            b_idx = jnp.arange(upd["k_new"].shape[0])
            cache_st = dict(cache_st)
            cache_st["k"] = cache_st["k"].at[
                idx, b_idx, upd["pos"]].set(upd["k_new"][:, 0])
            cache_st["v"] = cache_st["v"].at[
                idx, b_idx, upd["pos"]].set(upd["v_new"][:, 0])
            return (xc, cache_st), None

        (x, new_cache), _ = lax.scan(
            dec_body, (x, cache),
            (params["dec_blocks"], jnp.arange(cfg.n_layers)),
            unroll=cfg.n_layers if plan.scan_unroll else 1)
        x = _norm(params["ln_f"], x, cfg, plan, env)
        logits = lm_logits(emb_p, x, cfg, plan, env)
        return logits, new_cache, jnp.float32(0)

    def body(carry, xs):
        xc = carry
        bp, bc = xs
        bp = gather_fn("dec_block", bp)
        nc: Dict[str, Any] = {}
        h_in = _norm(bp["ln1"], xc, cfg, plan, env)
        if mode == "prefill":
            h, kv = attn_mod.prefill_attention(
                bp["attn"], h_in, cfg=cfg, plan=plan, env=env,
                positions=positions, cache={"k": bc["k"], "v": bc["v"]})
            nc.update(kv)
        else:
            h = attn_mod.self_attention(bp["attn"], h_in, cfg=cfg, plan=plan,
                                        env=env, positions=positions)
        xc = xc + h

        h_in = _norm(bp["lnx"], xc, cfg, plan, env)
        ck, cv = attn_mod.encode_cross_kv(bp["xattn"], enc_x, cfg=cfg,
                                          plan=plan, env=env)
        if bc is not None:
            nc["ck"], nc["cv"] = (ck.astype(bc["ck"].dtype),
                                  cv.astype(bc["cv"].dtype))
        h = attn_mod.cross_attention(bp["xattn"], h_in, cfg=cfg, plan=plan,
                                     env=env, enc_k=ck.astype(xc.dtype),
                                     enc_v=cv.astype(xc.dtype))
        xc = xc + h

        h = mlp_mod.mlp_fwd(bp["mlp"], _norm(bp["ln2"], xc, cfg, plan, env),
                            cfg=cfg, plan=plan, env=env)
        return xc + h, (nc if bc is not None else None)

    if plan.remat != "none" and mode == "train":
        body = jax.checkpoint(body)
    x, new_cache = lax.scan(body, x, (params["dec_blocks"], cache),
                            unroll=cfg.n_layers if plan.scan_unroll else 1)
    x = _norm(params["ln_f"], x, cfg, plan, env)
    logits = lm_logits(emb_p, x, cfg, plan, env)
    return logits, (new_cache if cache is not None else None), jnp.float32(0)


def init_encdec_cache(cfg, plan, batch: int, max_seq: int,
                      dtype=jnp.bfloat16, abstract: bool = False):
    """Stacked decoder cache: self K/V ring + cross K/V."""
    a = plan.attn
    L = cfg.n_layers
    es = cfg.encdec.enc_seq
    kv = (L, batch, max_seq, a.gp, a.d_head)
    ckv = (L, batch, es, a.gp, a.d_head)
    if abstract:
        return {"k": jax.ShapeDtypeStruct(kv, dtype),
                "v": jax.ShapeDtypeStruct(kv, dtype),
                "ck": jax.ShapeDtypeStruct(ckv, dtype),
                "cv": jax.ShapeDtypeStruct(ckv, dtype)}
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "ck": jnp.zeros(ckv, dtype), "cv": jnp.zeros(ckv, dtype)}
