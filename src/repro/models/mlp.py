"""Feed-forward layers: column-parallel FC1 / row-parallel FC2 via ESL.

The mapper gives the FFN *column-wise tiles* (paper: "divides the
feed-forward network weights with column-wise tiles").  Gated (SwiGLU)
variants fuse gate+up into one streamed ``ag_matmul``; FC2 streams its
partial products around the ring (``rs_matmul``) — the paper's "tail of
FC1's sync hides under FC2" case.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import esl
from repro.core.dist import AxisEnv
from repro.models.common import InitCtx, activate

Params = Dict[str, Any]


def init_mlp(ctx: InitCtx, cfg, plan, name: str = "mlp",
             d_ff_shard: int = None, bias: bool = False) -> Params:
    D = cfg.d_model
    ff = (plan.d_ff_shard if d_ff_shard is None else d_ff_shard) * plan.tp
    s1 = 1.0 / math.sqrt(D)
    s2 = 1.0 / math.sqrt(ff)
    with ctx.scope(name):
        p: Params = {}
        if cfg.mlp_gated:
            p["wg"] = ctx.param("wg", (D, ff), ("embed", "ffn"), scale=1.0)
            p["wu"] = ctx.param("wu", (D, ff), ("embed", "ffn"), scale=1.0)
        else:
            p["wi"] = ctx.param("wi", (D, ff), ("embed", "ffn"), scale=1.0)
            if bias:
                p["bi"] = ctx.param("bi", (ff,), ("ffn",), init="zeros")
        p["wd"] = ctx.param("wd", (ff, D), ("ffn", "embed"), scale=1.0)
        if bias:
            p["bd"] = ctx.param("bd", (D,), ("vec",), init="zeros")
    return p


def mlp_fwd(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv) -> jax.Array:
    """x: (B,S,D/tp) scattered (ESL) or (B,S,D) full (baseline)."""
    overlap = plan.esl_overlap
    if "wg" in p:
        w1 = jnp.concatenate([p["wg"], p["wu"]], axis=-1)
        h = esl.ag_matmul(x, w1, axis=env.model, tp=env.tp, overlap=overlap)
        g, u = jnp.split(h, 2, axis=-1)
        h = activate(g, cfg.activation) * u
    else:
        h = esl.ag_matmul(x, p["wi"], axis=env.model, tp=env.tp,
                          overlap=overlap, b=p.get("bi"))
        h = activate(h, cfg.activation)
    y = esl.rs_matmul(h, p["wd"], axis=env.model, tp=env.tp,
                      overlap=overlap, scatter_out=overlap)
    if "bd" in p:
        y = y + esl.full_vec(p["bd"], axis=env.model, tp=env.tp,
                             scattered_activations=overlap)
    return y
