"""Decoder-only LM assembly (dense / moe / hybrid / rwkv / vlm).

One code path for every family: layers are grouped into *super-blocks*
(the lcm of the family's interleave patterns — jamba: 8 = 7 mamba + 1
attention with MoE on odd layers; llama4: 2 = dense+MoE; others: 1) and
scanned with stacked parameters, so the lowered HLO stays compact for
62-layer models and remat applies per super-block.

Activations flow *scattered* over the model ring between layers when ESL
overlap is on (plan.esl_overlap) and *replicated* in the blocking
baseline; every sub-module follows the same convention.

Decode rides the scan CARRY so XLA aliases cache buffers in place; the
same path serves the dense per-slot cache, the kv-seq-sharded cache and
the serving engine's paged pool (``block_tables``), single-device or
inside the engine's ``shard_map`` ring — the cache pytree's sharding is
declared by ``registry.Model.cache_specs``, never inspected here.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import esl
from repro.core.dist import AxisEnv, model_rank
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.common import InitCtx, apply_norm, init_norm

Params = Dict[str, Any]


def super_block_size(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.mamba.attn_every
    if cfg.moe is not None:
        return cfg.moe.moe_every
    return 1


def n_super_blocks(cfg) -> int:
    sb = super_block_size(cfg)
    assert cfg.n_layers % sb == 0, (cfg.name, cfg.n_layers, sb)
    return cfg.n_layers // sb


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(ctx: InitCtx, cfg, plan, layer_idx: int) -> Params:
    """One decoder layer (position ``layer_idx % super_block`` pattern)."""
    p: Params = {}
    if cfg.family == "rwkv":
        p["ln1"] = init_norm(ctx, "ln1", cfg.d_model, cfg.norm)
        p["tmix"] = rwkv_mod.init_time_mix(ctx, cfg, plan)
        p["ln2"] = init_norm(ctx, "ln2", cfg.d_model, cfg.norm)
        p["cmix"] = rwkv_mod.init_channel_mix(ctx, cfg, plan)
        return p
    p["ln1"] = init_norm(ctx, "ln1", cfg.d_model, cfg.norm)
    if cfg.is_attention_layer(layer_idx):
        p["attn"] = attn_mod.init_attention(ctx, cfg, plan)
    else:
        p["mamba"] = mamba_mod.init_mamba(ctx, cfg, plan)
    p["ln2"] = init_norm(ctx, "ln2", cfg.d_model, cfg.norm)
    if cfg.is_moe_layer(layer_idx):
        p["moe"] = moe_mod.init_moe(ctx, cfg, plan)
    else:
        p["mlp"] = mlp_mod.init_mlp(ctx, cfg, plan,
                                    bias=(cfg.norm == "layernorm"
                                          and not cfg.mlp_gated))
    return p


def init_super_block(ctx: InitCtx, cfg, plan) -> Params:
    sb = super_block_size(cfg)
    out: Params = {}
    for j in range(sb):
        with ctx.scope(f"l{j}"):
            out[f"l{j}"] = init_layer(ctx, cfg, plan, j)
    return out


def init_lm(ctx: InitCtx, cfg, plan) -> Params:
    from repro.models.common import stacked_init
    D = cfg.d_model
    p: Params = {}
    if cfg.tie_embeddings:
        p["embed"] = ctx.param("embed", (plan.vocab_padded, D),
                               ("vocab", "embed"), scale=1.0)
    else:
        p["embed_in"] = ctx.param("embed_in", (cfg.vocab_size, D),
                                  ("vocab_rep", "embed_scatter"), scale=1.0)
        p["head"] = ctx.param("head", (D, plan.vocab_padded),
                              ("embed", "vocab"), scale=1.0)
    if cfg.positional == "learned":
        p["pos_embed"] = ctx.param("pos_embed", (cfg.max_seq, D),
                                   ("pos", "embed_scatter"), scale=1.0)
    if cfg.vlm is not None:
        p["projector"] = ctx.param(
            "projector", (cfg.vlm.patch_embed_dim, D),
            ("patches", "embed_scatter"), scale=1.0)
    p["blocks"] = stacked_init(ctx, "blocks", n_super_blocks(cfg),
                               lambda c: init_super_block(c, cfg, plan))
    p["ln_f"] = init_norm(ctx, "ln_f", D, cfg.norm)
    return p


# ---------------------------------------------------------------------------
# embeddings / head / loss (vocab column-parallel; logits never unsharded)
# ---------------------------------------------------------------------------

def embed_tokens(p: Params, tokens: jax.Array, cfg, plan,
                 env: AxisEnv) -> jax.Array:
    """tokens (B,S) -> activations in the plan's convention."""
    scattered = plan.esl_overlap and env.model is not None
    if "embed_in" in p:
        # D-sharded table: local slice lookup, no communication at all —
        # output is natively scattered (feeds the first ag_matmul).
        x = jnp.take(p["embed_in"], tokens, axis=0)
        if not scattered and env.model is not None:
            x = esl.gather_scattered(x, axis=env.model, tp=env.tp)
        return x
    # tied, vocab-sharded: masked local rows + ring combine
    w = p["embed"]
    if env.model is None:
        return jnp.take(w, tokens, axis=0)
    v_loc = w.shape[0]
    r = model_rank(env)
    local = tokens - r * v_loc
    ok = (local >= 0) & (local < v_loc)
    x = jnp.take(w, jnp.clip(local, 0, v_loc - 1), axis=0)
    x = jnp.where(ok[..., None], x, 0)
    if scattered:
        return lax.psum_scatter(x, env.model, scatter_dimension=x.ndim - 1,
                                tiled=True)
    return lax.psum(x, env.model)


def add_positional(p: Params, x: jax.Array, positions: jax.Array, cfg, plan,
                   env: AxisEnv) -> jax.Array:
    if cfg.positional != "learned":
        return x
    scattered = plan.esl_overlap and env.model is not None
    pe = p["pos_embed"]
    if env.model is not None:
        # stored D-sharded: local column slice is this rank's shard
        pass
    emb = jnp.take(pe, positions, axis=0)
    if not scattered and env.model is not None:
        emb = esl.gather_scattered(emb, axis=env.model, tp=env.tp)
    return x + emb.astype(x.dtype)


def lm_logits(p: Params, x: jax.Array, cfg, plan, env: AxisEnv) -> jax.Array:
    """-> (B,S,V_pad/tp) vocab-sharded logits (never materialized full)."""
    w = p["head"] if "head" in p else jnp.swapaxes(p["embed"], 0, 1)
    y = esl.ag_matmul(x, w, axis=env.model, tp=env.tp,
                      overlap=plan.esl_overlap,
                      scattered_in=plan.esl_overlap)
    # mask padded vocab columns
    if env.model is None:
        v_ids = jnp.arange(y.shape[-1])
    else:
        v_loc = y.shape[-1]
        v_ids = model_rank(env) * v_loc + jnp.arange(v_loc)
    y = jnp.where(v_ids < cfg.vocab_size, y,
                  jnp.finfo(jnp.float32).min / 2)
    return y


def sharded_xent(logits: jax.Array, labels: jax.Array, env: AxisEnv,
                 ignore: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over vocab-sharded logits.  Returns (sum_loss, count).

    logits: (B,S,Vloc); labels: (B,S) global token ids (or `ignore`).
    """
    lg = logits.astype(jnp.float32)
    v_loc = lg.shape[-1]
    # the stabilizer is gradient-neutral (lse(x) = log sum exp(x-m) + m
    # holds for any constant m); pmax has no diff rule, so detach *before*.
    m = jnp.max(lax.stop_gradient(lg), -1)
    if env.model is not None:
        m = lax.pmax(m, env.model)
    se = jnp.sum(jnp.exp(lg - m[..., None]), -1)
    if env.model is not None:
        se = lax.psum(se, env.model)
    lse = jnp.log(se) + m                               # (B,S)
    r = model_rank(env)
    local = labels - r * v_loc
    ok = (local >= 0) & (local < v_loc)
    picked = jnp.take_along_axis(
        lg, jnp.clip(local, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    picked = jnp.where(ok, picked, 0.0)
    if env.model is not None:
        picked = lax.psum(picked, env.model)
    valid = labels != ignore
    loss = jnp.where(valid, lse - picked, 0.0)
    return jnp.sum(loss), jnp.sum(valid)


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _norm(pn, x, cfg, plan, env):
    scattered = plan.esl_overlap and env.model is not None
    stats_axis = env.model if scattered else None
    scale = esl.full_vec(pn["scale"], axis=env.model, tp=env.tp,
                         scattered_activations=plan.esl_overlap)
    pl = {"scale": scale}
    if "bias" in pn:
        pl["bias"] = esl.full_vec(pn["bias"], axis=env.model, tp=env.tp,
                                  scattered_activations=plan.esl_overlap)
    return apply_norm(pl, x, cfg.norm, stats_axis_name=stats_axis)


def apply_layer(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
                layer_idx: int, positions: jax.Array, mode: str,
                cache: Optional[Params] = None,
                block_tables: Optional[jax.Array] = None,
                paged_kernel: str = "auto", block_s: int = 0,
                kv_valid_len: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (x, new_cache, aux_loss).

    ``mode="chunk_prefill"`` (attention-only stacks) prefills one chunk
    of a partially-resident prompt straight against the paged pool:
    ``cache`` is the pool, ``block_tables`` the request's table and
    ``kv_valid_len`` the resident token count after this chunk.
    """
    aux = jnp.float32(0.0)
    new_cache: Optional[Params] = dict(cache) if cache is not None else None

    if cfg.family == "rwkv":
        st = None
        if cache is not None:
            st = {"shift": cache["shift_t"], "wkv": cache["wkv"]}
        h, st2 = rwkv_mod.time_mix_fwd(
            p["tmix"], _norm(p["ln1"], x, cfg, plan, env),
            cfg=cfg, plan=plan, env=env, state=st)
        x = x + h
        st_c = cache["shift_c"] if cache is not None else None
        h, st_c2 = rwkv_mod.channel_mix_fwd(
            p["cmix"], _norm(p["ln2"], x, cfg, plan, env),
            cfg=cfg, plan=plan, env=env, state=st_c)
        x = x + h
        if cache is not None:
            new_cache = {"shift_t": st2["shift"], "wkv": st2["wkv"],
                         "shift_c": st_c2}
        return x, new_cache, aux

    h_in = _norm(p["ln1"], x, cfg, plan, env)
    if "attn" in p:
        if mode == "decode":
            h, kv = attn_mod.decode_attention(
                p["attn"], h_in, cfg=cfg, plan=plan, env=env,
                cache=cache, positions=positions,
                block_table=block_tables, paged_kernel=paged_kernel,
                block_s=block_s)
            new_cache = kv
        elif mode == "prefill":
            h, kv = attn_mod.prefill_attention(
                p["attn"], h_in, cfg=cfg, plan=plan, env=env,
                positions=positions, cache=cache)
            new_cache = kv
        elif mode == "chunk_prefill":
            h, kv = attn_mod.chunk_prefill_attention(
                p["attn"], h_in, cfg=cfg, plan=plan, env=env,
                positions=positions, cache=cache,
                block_table=block_tables, kv_valid_len=kv_valid_len,
                paged_kernel=paged_kernel)
            new_cache = kv
        elif mode == "verify":
            # speculative verify: flattened per-query tables and
            # per-query valid lengths (see verify_attention)
            h, kv = attn_mod.verify_attention(
                p["attn"], h_in, cfg=cfg, plan=plan, env=env,
                positions=positions, cache=cache,
                block_tables=block_tables, kv_valid_len=kv_valid_len,
                paged_kernel=paged_kernel)
            new_cache = kv
        else:
            h = attn_mod.self_attention(
                p["attn"], h_in, cfg=cfg, plan=plan, env=env,
                positions=positions)
    else:
        st = cache if cache is not None else None
        h, st2 = mamba_mod.mamba_fwd(p["mamba"], h_in, cfg=cfg, plan=plan,
                                     env=env, state=st)
        if cache is not None:
            new_cache = st2
    x = x + h

    h_in = _norm(p["ln2"], x, cfg, plan, env)
    if "moe" in p:
        h, aux = moe_mod.moe_fwd(p["moe"], h_in, cfg=cfg, plan=plan, env=env)
    else:
        h = mlp_mod.mlp_fwd(p["mlp"], h_in, cfg=cfg, plan=plan, env=env)
    x = x + h
    return x, new_cache, aux


def apply_super_block(p: Params, x: jax.Array, *, cfg, plan, env: AxisEnv,
                      positions: jax.Array, mode: str,
                      cache: Optional[Params] = None,
                      block_tables: Optional[jax.Array] = None,
                      paged_kernel: str = "auto", block_s: int = 0,
                      kv_valid_len: Optional[jax.Array] = None):
    sb = super_block_size(cfg)
    aux_total = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}
    for j in range(sb):
        cj = cache.get(f"l{j}") if cache is not None else None
        x, cj2, aux = apply_layer(p[f"l{j}"], x, cfg=cfg, plan=plan, env=env,
                                  layer_idx=j, positions=positions,
                                  mode=mode, cache=cj,
                                  block_tables=block_tables,
                                  paged_kernel=paged_kernel,
                                  block_s=block_s,
                                  kv_valid_len=kv_valid_len)
        if cache is not None:
            new_cache[f"l{j}"] = cj2
        aux_total = aux_total + aux
    return x, (new_cache if cache is not None else None), aux_total


def _scatter_cache_updates(cache_st, upd, idx, seq_sharded: bool,
                           block_tables=None):
    """Scatter per-layer decode updates into the stacked cache carry."""
    out = {}
    for lj, u in upd.items():
        c = cache_st[lj]
        if u is None:
            out[lj] = c
            continue
        if "k_new" in u:
            knew, vnew = u["k_new"], u["v_new"]
            pos, mask = u["pos"], u["mask"]
            b_idx = jnp.arange(knew.shape[0])
            if block_tables is not None:
                # paged pool (n_sb, N, bs, gp, dh): the token at logical
                # position ``pos`` lands in physical block
                # table[b, pos // bs] at offset pos % bs.  Inactive slots
                # point at the null block 0 (don't-care writes).
                bs_blk = c["k"].shape[2]
                blk = jnp.take_along_axis(
                    block_tables, (pos // bs_blk)[:, None], axis=1)[:, 0]
                off = pos % bs_blk
                out[lj] = {
                    "k": c["k"].at[idx, blk, off].set(
                        knew[:, 0].astype(c["k"].dtype)),
                    "v": c["v"].at[idx, blk, off].set(
                        vnew[:, 0].astype(c["v"].dtype)),
                }
                if "k_scale_new" in u:
                    # quantized pool: the row's absmax scales land beside
                    # the int8/fp8 values at the same (block, offset)
                    out[lj]["k_scale"] = c["k_scale"].at[idx, blk, off].set(
                        u["k_scale_new"][:, 0].astype(c["k_scale"].dtype))
                    out[lj]["v_scale"] = c["v_scale"].at[idx, blk, off].set(
                        u["v_scale_new"][:, 0].astype(c["v_scale"].dtype))
            elif seq_sharded and c["k"].ndim == 6:
                old_k = c["k"][idx, b_idx, 0, pos]
                old_v = c["v"][idx, b_idx, 0, pos]
                val_k = jnp.where(mask[:, None, None], knew[:, 0], old_k)
                val_v = jnp.where(mask[:, None, None], vnew[:, 0], old_v)
                out[lj] = {
                    "k": c["k"].at[idx, b_idx, 0, pos].set(val_k),
                    "v": c["v"].at[idx, b_idx, 0, pos].set(val_v),
                }
            else:
                out[lj] = {
                    "k": c["k"].at[idx, b_idx, pos].set(knew[:, 0]),
                    "v": c["v"].at[idx, b_idx, pos].set(vnew[:, 0]),
                }
        else:
            # small recurrent states (mamba/rwkv): whole-slice update
            out[lj] = jax.tree.map(
                lambda cs, un: cs.at[idx].set(un.astype(cs.dtype)),
                c, u)
    return out


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------

def forward(params: Params, tokens: jax.Array, *, cfg, plan, env: AxisEnv,
            mode: str = "train",
            positions: Optional[jax.Array] = None,
            cache: Optional[Params] = None,
            patch_embeds: Optional[jax.Array] = None,
            block_tables: Optional[jax.Array] = None,
            paged_kernel: str = "auto",
            block_s: int = 0,
            kv_valid_len: Optional[jax.Array] = None,
            gather_fn=None):
    """Shared forward.  ``gather_fn(subtree_path, subtree)`` applies FSDP
    gathering (injected by the step builder; identity in smoke mode).

    ``mode="chunk_prefill"`` rides the same non-decode scan (the pool
    cache slices through the scan xs and restacks through its ys):
    ``positions`` carry the chunk's absolute offsets, ``block_tables``
    the request's table and ``kv_valid_len`` the post-chunk resident
    length — see :func:`repro.models.attention.chunk_prefill_attention`.

    Returns (logits_sharded, new_cache, aux).
    """
    gather_fn = gather_fn or (lambda path, t: t)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    emb_p = gather_fn("embed", {k: v for k, v in params.items()
                                if k in ("embed", "embed_in", "head",
                                         "pos_embed", "projector")})
    x = embed_tokens(emb_p, tokens, cfg, plan, env)
    x = x.astype(jnp.dtype(plan.compute_dtype))

    if patch_embeds is not None:
        # vision-stub frontend: precomputed patch embeddings -> projector
        pe = esl.ag_matmul(patch_embeds.astype(x.dtype),
                           emb_p["projector"].astype(x.dtype),
                           axis=env.model, tp=env.tp,
                           overlap=plan.esl_overlap, scattered_in=False)
        if not plan.esl_overlap and env.model is not None:
            pe = esl.gather_scattered(pe, axis=env.model, tp=env.tp)
        x = jnp.concatenate([pe, x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S)) \
            if mode != "decode" else positions
    x = add_positional(emb_p, x, positions if mode != "decode"
                       else positions[:, None], cfg, plan, env)
    x = x.astype(jnp.dtype(plan.compute_dtype))

    n_sb = n_super_blocks(cfg)
    aux_total = jnp.float32(0.0)

    def block_fn(carry, xs):
        xc, auxc = carry
        bp, bc = xs
        bp = gather_fn("block", bp)
        xc, nc, aux = apply_super_block(bp, xc, cfg=cfg, plan=plan, env=env,
                                        positions=positions, mode=mode,
                                        cache=bc,
                                        block_tables=block_tables,
                                        paged_kernel=paged_kernel,
                                        block_s=block_s,
                                        kv_valid_len=kv_valid_len)
        return (xc, auxc + aux), nc

    if plan.remat != "none":
        block_fn = jax.checkpoint(block_fn)

    unroll = n_sb if plan.scan_unroll else 1
    if cache is None:
        (x, aux_total), _ = lax.scan(block_fn, (x, aux_total),
                                     (params["blocks"], None), unroll=unroll)
        new_cache = None
    elif mode == "decode":
        # decode: the cache rides the scan CARRY so XLA's while-loop
        # buffer aliasing keeps updates in place — per token we write
        # only the new KV entries, never the 2*L*S*d cache (§Perf 1b)
        seq_sharded = env.kv_seq_axis is not None

        def dec_body(carry, xs):
            xc, auxc, cache_st = carry
            bp, idx = xs
            bp = gather_fn("block", bp)
            sl = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, idx, 0,
                                                   keepdims=False),
                cache_st)
            xc, upd, aux = apply_super_block(
                bp, xc, cfg=cfg, plan=plan, env=env, positions=positions,
                mode=mode, cache=sl, block_tables=block_tables,
                paged_kernel=paged_kernel, block_s=block_s)
            cache_st = _scatter_cache_updates(cache_st, upd, idx,
                                              seq_sharded, block_tables)
            return (xc, auxc + aux, cache_st), None

        (x, aux_total, new_cache), _ = lax.scan(
            dec_body, (x, aux_total, cache),
            (params["blocks"], jnp.arange(n_sb)), unroll=unroll)
    elif mode in ("chunk_prefill", "verify"):
        # chunk prefill + speculative verify: like decode, the pool
        # rides the scan CARRY so XLA's while-loop buffer aliasing can
        # keep the per-layer slice -> scatter -> write-back chain in
        # place, instead of the xs->ys stacking (whose separate
        # input/output buffers force a full pool copy per layer)
        def chunk_body(carry, xs):
            xc, auxc, cache_st = carry
            bp, idx = xs
            bp = gather_fn("block", bp)
            sl = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, idx, 0,
                                                   keepdims=False),
                cache_st)
            xc, upd, aux = apply_super_block(
                bp, xc, cfg=cfg, plan=plan, env=env, positions=positions,
                mode=mode, cache=sl, block_tables=block_tables,
                paged_kernel=paged_kernel, block_s=block_s,
                kv_valid_len=kv_valid_len)
            cache_st = jax.tree.map(
                lambda st, u: lax.dynamic_update_index_in_dim(
                    st, u.astype(st.dtype), idx, 0),
                cache_st, upd)
            return (xc, auxc + aux, cache_st), None

        (x, aux_total, new_cache), _ = lax.scan(
            chunk_body, (x, aux_total, cache),
            (params["blocks"], jnp.arange(n_sb)), unroll=unroll)
    else:
        (x, aux_total), new_cache = lax.scan(block_fn, (x, aux_total),
                                             (params["blocks"], cache),
                                             unroll=unroll)

    x = _norm(params["ln_f"], x, cfg, plan, env)
    logits = lm_logits(emb_p, x, cfg, plan, env)
    return logits, new_cache, aux_total
