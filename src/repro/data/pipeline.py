"""Deterministic, elastic-friendly synthetic LM data pipeline.

Every token is a pure function of its *global example index* — not of
the worker count — so a run restarted on a different data-parallel width
(elastic scaling) consumes exactly the same stream with no gaps or
repeats.  Per-host sharded loading: each host materializes only its
slice of the global batch.

The generator produces a Zipf-ish unigram mixture with Markov
second-order structure, so tiny models show a real, monotonically
decreasing loss (needed by the train-loss-decreases integration test and
the ~100M-model example run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def _hash64(x: np.ndarray) -> np.ndarray:
    """splitmix64 — stateless, vectorized."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
        & np.uint64(0xFFFFFFFFFFFFFFFF)
    return x ^ (x >> np.uint64(31))


@dataclass
class SyntheticLM:
    """Markov-structured synthetic corpus."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    order_mix: float = 0.8      # P(next token from the Markov rule)

    def example(self, global_idx: int) -> np.ndarray:
        """Tokens of example `global_idx` — count-invariant."""
        n = self.seq_len + 1
        idx = np.uint64(global_idx)
        base = _hash64(np.arange(n, dtype=np.uint64)
                       + idx * np.uint64(1_000_003)
                       + np.uint64(self.seed) * np.uint64(7_777_777))
        # Zipf-ish unigram draw
        u = (base >> np.uint64(11)).astype(np.float64) / 2.0 ** 53
        zipf = np.minimum((1.0 / np.maximum(u, 1e-12)) ** 0.5,
                          self.vocab_size - 1).astype(np.int64)
        toks = zipf % self.vocab_size
        # second-order structure: with prob order_mix, token t is a fixed
        # function of tokens t-1, t-2 => learnable bigram/trigram signal
        gate = ((base & np.uint64(0xFF)).astype(np.float64) / 255.0
                < self.order_mix)
        out = toks.copy()
        for t in range(2, n):
            if gate[t]:
                out[t] = int((out[t - 1] * 31 + out[t - 2] * 7 + 11)
                             % self.vocab_size)
        return out

    def batch(self, step: int, global_batch: int,
              shard: Tuple[int, int] = (0, 1)
              ) -> Dict[str, np.ndarray]:
        """Host-sharded batch for `step`: shard=(host_idx, n_hosts)."""
        host, n_hosts = shard
        assert global_batch % n_hosts == 0
        per = global_batch // n_hosts
        start = step * global_batch + host * per
        rows = np.stack([self.example(start + i) for i in range(per)])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def stream(self, global_batch: int, shard=(0, 1),
               start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, global_batch, shard)
            step += 1
