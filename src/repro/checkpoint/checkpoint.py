"""Checkpointing: atomic sharded save/restore with elastic resharding.

Fault-tolerance contract (launch/train.py):
* saves are atomic (write to ``step_N.tmp`` then rename) — a crash
  mid-save never corrupts the latest checkpoint;
* ``latest_step`` + ``restore`` implement crash-restart;
* ``restore`` works under a *different* mesh than ``save`` used: arrays
  are stored as full logical ndarrays (np.load lazily memory-maps), and
  the trainer re-device_puts them under the new sharding — elastic
  scale-up/down is a restart, not a migration;
* an optional ``keep`` window garbage-collects old steps.

For 1000+-node deployments the same layout maps onto a parallel
filesystem: one shard file per (host, tree-leaf chunk); here (single
host) the tree is flattened into one npz per step plus a JSON manifest
with the treedef and step metadata.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None

    # -- core ---------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        arrs, treedef = _flatten(tree)
        final = self.dir / f"step_{step:08d}"
        tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_save_"))
        np.savez(tmp / "arrays.npz", **arrs)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(arrs),
            "time": time.time(),
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, tree,
                   extra: Optional[Dict[str, Any]] = None):
        """Non-blocking save (host copy happens before returning)."""
        arrs, treedef = _flatten(tree)              # device->host sync here
        self.wait()

        def work():
            final = self.dir / f"step_{step:08d}"
            tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_save_"))
            np.savez(tmp / "arrays.npz", **arrs)
            (tmp / "manifest.json").write_text(json.dumps(
                {"step": step, "treedef": str(treedef),
                 "n_leaves": len(arrs), "time": time.time(),
                 "extra": extra or {}}))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            m = re.match(r"step_(\d+)$", p.name)
            if m and (p / "manifest.json").exists():
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Load into the structure of `like_tree`; re-shard if given.

        Elastic: `shardings` may target a different mesh than the one
        that saved — arrays are full logical values, so device_put with
        the new sharding is all resharding takes.
        """
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "arrays.npz")
        leaves, treedef = jax.tree_util.tree_flatten(like_tree)
        assert len(leaves) == len(data.files), \
            f"leaf count mismatch: ckpt {len(data.files)} vs {len(leaves)}"
        new_leaves = []
        for i, like in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            assert arr.shape == tuple(like.shape), (i, arr.shape, like.shape)
            new_leaves.append(arr.astype(like.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree

    def manifest(self, step: int) -> Dict[str, Any]:
        return json.loads(
            (self.dir / f"step_{step:08d}" / "manifest.json").read_text())

    def _gc(self):
        steps = sorted(
            int(re.match(r"step_(\d+)$", p.name).group(1))
            for p in self.dir.glob("step_*")
            if re.match(r"step_(\d+)$", p.name))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
