"""AdamW with global-norm clipping, built from scratch (no optax).

The optimizer state is sharded *identically to the parameters* (ZeRO:
the mapper's PartitionSpecs apply verbatim to m/v), so the update is a
purely elementwise jit region — no communication except the grad-norm
all-reduce, which XLA emits from the global-norm reduction.

``grad_compress='int8'`` enables error-feedback int8 quantization of the
cross-pod gradient sync (the distributed-optimization trick for slow DCI
links); it is applied by the train driver on the pod axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


class OptState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


@dataclass
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Params) -> OptState:
        zeros = lambda t: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), t)
        return OptState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                        v=zeros(params))

    def init_abstract(self, params: Params) -> OptState:
        zeros = lambda t: jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), t)
        return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                        m=zeros(params), v=zeros(params))

    def apply(self, params: Params, grads: Params, state: OptState
              ) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
        step = state.step + 1
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        lr = self.lr(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            step_p = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:                      # decay matrices only
                step_p = step_p + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_p).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step, new_m, new_v), {
            "grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# gradient compression (cross-pod sync over slow DCI links)
# ---------------------------------------------------------------------------

def int8_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    q = jnp.clip(jnp.round(g / amax * 127.0), -127, 127).astype(jnp.int8)
    return q, amax


def int8_decompress(q: jax.Array, amax: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * (amax / 127.0)


def compressed_psum(g: jax.Array, axis_name: str,
                    err: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce over `axis_name`.

    Returns (summed_grad_f32, new_error_residual).  The residual carries
    quantization error into the next step (Karimireddy et al., EF-SGD).
    """
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    q, amax = int8_compress(gf)
    deq = int8_decompress(q, amax)
    new_err = gf - deq
    summed = jax.lax.psum(deq, axis_name)
    return summed, new_err
