"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM).

Pure functions of the step counter — jit-safe.
"""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, min_ratio: float = 0.01):
    """Warmup -> Stable (constant) -> Decay (last `decay_frac` of steps).

    MiniCPM's schedule (arXiv:2404.06395): exponential-ish decay tail
    approximated by the published 'sqrt-linear' ramp.
    """
    decay_start = int(total * (1 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - decay_start) /
                     jnp.maximum(total - decay_start, 1), 0, 1)
        decay = base_lr * (min_ratio ** t)
        stable = jnp.full_like(step, base_lr)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < decay_start, stable, decay))
        return out
    return lr


def get_schedule(name: str, base_lr: float, warmup: int, total: int):
    if name == "wsd":
        return wsd_schedule(base_lr, warmup, total)
    return cosine_schedule(base_lr, warmup, total)
