from repro.optim.adamw import AdamW, OptState
from repro.optim.schedule import cosine_schedule, wsd_schedule, get_schedule

__all__ = ["AdamW", "OptState", "cosine_schedule", "wsd_schedule",
           "get_schedule"]
