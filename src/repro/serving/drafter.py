"""Draft-token proposers for speculative decoding.

The speculative path in :mod:`repro.serving.engine` is
draft-and-verify: a *drafter* proposes up to ``k`` continuation tokens
per decode slot on the host, the engine scores all ``k+1`` positions in
ONE paged-kernel pass (the PR 5 chunk-as-batch seam), and on-device
rejection sampling accepts the matched prefix.  Rejection sampling is
proposal-agnostic — a bad draft costs acceptance, never correctness —
so drafters are free to be cheap heuristics:

* :class:`NGramDrafter` — self-speculation by suffix match: find the
  longest recent n-gram suffix of the sequence that occurred earlier
  and propose the tokens that followed it.  Zero extra FLOPs, and on
  repetitive text (greedy decode loops, templated output, code) it
  predicts the target model almost perfectly.  This is the default.

* :class:`ModelDrafter` — a small registry model (e.g. the
  ``smollm_135m`` config ``reduced()``) decoded greedily on the host
  path.  Stateless between calls: each proposal re-scores the full
  context through pow2-bucketed dense forwards, so rollback after a
  rejected window is free (nothing to roll back).  Meant for tiny draft
  models where k extra dense forwards are still far cheaper than k
  target-model steps.

Drafters are deterministic by contract: the verify path's rejection
sampler assumes a one-hot proposal distribution (accept draft ``d``
with probability ``p(d)``), and greedy bit-parity with non-speculative
decoding relies on the draft sequence being a pure function of the
visible tokens.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import numpy as np


class NGramDrafter:
    """Propose the continuation of the longest matched suffix n-gram.

    For context ``t_0 .. t_{n-1}``, try suffix lengths ``max_n .. 1``:
    if the length-m suffix re-occurs earlier in the context, propose the
    ``k`` tokens that followed its MOST RECENT earlier occurrence.
    Returns ``[]`` on a cold miss (the engine then runs a normal
    non-speculative round for that window).
    """

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, got "
                             f"({min_n}, {max_n})")
        self.max_n = max_n
        self.min_n = min_n

    def _next(self, toks: List[int]) -> Optional[int]:
        """Predict ONE token: longest suffix n-gram, most recent match."""
        n = len(toks)
        for m in range(min(self.max_n, n - 1), self.min_n - 1, -1):
            pat = toks[n - m:]
            # j = exclusive end of a candidate earlier occurrence; the
            # window may overlap the suffix (periodic text, period < m)
            for j in range(n - 1, m - 1, -1):
                if toks[j - m:j] == pat:
                    return toks[j]
        return None

    def propose(self, tokens: Sequence[int], k: int) -> List[int]:
        ext = list(tokens)
        out: List[int] = []
        for _ in range(k):
            t = self._next(ext)
            if t is None:
                break
            out.append(t)
            ext.append(t)
        return out


class ModelDrafter:
    """Greedy draft proposals from a small registry model.

    Runs the draft model's dense forward over the full visible context
    (pow2-bucketed so trace count stays O(log2 max_seq)) and extends it
    greedily token by token — ``k`` forwards per proposal.  The draft
    model keeps NO cross-call state, so rejected speculation windows
    need no draft-side rollback and preemption/recompute are free.
    """

    def __init__(self, model, params, max_seq: int = 2048):
        from repro.core.dist import make_axis_env
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.env = make_axis_env(model.plan, batch=1)
        self._jits = {}

    def _row_fn(self, bucket: int):
        """jit per pow2 bucket: full dense forward, last valid row out.

        Right padding is invisible to the causal rows <= n_valid-1, so
        the padded forward scores the true context exactly.
        """
        fn = self._jits.get(bucket)
        if fn is None:
            def run(params, toks, n_valid):
                logits, _, _ = self.model.forward(params, toks,
                                                  env=self.env,
                                                  mode="train")
                return jax.lax.dynamic_index_in_dim(
                    logits[0], n_valid - 1, 0, keepdims=False)
            fn = jax.jit(run)
            self._jits[bucket] = fn
        return fn

    def propose(self, tokens: Sequence[int], k: int) -> List[int]:
        toks = list(tokens)
        out: List[int] = []
        for _ in range(k):
            n = len(toks)
            if n >= self.max_seq:
                break
            bucket = 1
            while bucket < n:
                bucket *= 2
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :n] = toks
            row = self._row_fn(bucket)(self.params,
                                       jax.numpy.asarray(padded),
                                       jax.numpy.int32(n))
            nxt = int(np.argmax(np.asarray(row)))
            out.append(nxt)
            toks.append(nxt)
        return out


def make_drafter(kind: str, *, draft_model=None, draft_params=None,
                 max_seq: int = 2048) -> Optional[object]:
    """Build the drafter for ``LPUEngine(speculate=...)``.

    ``"ngram"`` needs nothing; ``"model"`` needs a built registry model
    + params (e.g. ``get_config("smollm-135m").reduced()``) passed as
    ``draft_model`` / ``draft_params``.
    """
    if kind == "off":
        return None
    if kind == "ngram":
        return NGramDrafter()
    if kind == "model":
        if draft_model is None or draft_params is None:
            raise ValueError(
                "speculate='model' needs draft_model/draft_params "
                "(a small registry model, e.g. smollm-135m reduced)")
        return ModelDrafter(draft_model, draft_params, max_seq=max_seq)
    raise ValueError(f"unknown speculate mode {kind!r}")
