"""HyperDex-style runtime layer: continuous-batching serving engine.

``LPUEngine`` mirrors the paper's runtime API surface on top and a paged
KV-cache serving stack below; with a mesh it becomes the paper's
*multi-LPU* configuration — the whole prefill/decode path runs inside
one ``shard_map`` over the ``model`` ring so the ESL collectives (C2)
and the paged pool compose:

* **API** — the HF-like blocking ``generate(prompts, ...)`` plus a
  non-blocking ``submit(request) / step() / drain()`` interface for
  continuous serving (the paper's "batch mode" datacenter direction).
* **Scheduler** — a fixed decode batch of B slots; queued requests are
  admitted at step boundaries by :class:`repro.serving.scheduler.
  Scheduler`, finished sequences release their slot (and blocks)
  mid-flight.
* **KV cache** — paged by default for attention-only stacks: a shared
  pool of fixed-size blocks with per-request block tables
  (:mod:`repro.serving.kv_cache`), so the *persistent* cache scales
  with resident tokens instead of slots x max_seq.  Decode **streams**
  KV tiles straight from the pool through the scalar-prefetched paged
  Pallas kernel (``paged_kernel="stream"``, the default wherever the
  stored GQA layout allows): no contiguous per-request view is ever
  materialized.  ``paged_kernel="gather"`` keeps the old
  copy-then-attend path as the reference oracle (bit-trustworthy, 3x
  the KV bytes moved — see :meth:`LPUEngine.kv_bytes_moved_per_step`).
  The dense per-slot cache remains the contiguous fast path
  (``paged=False``, and the automatic fallback for recurrent-state
  families).
* **Prefill** — per-request at batch 1, padded to power-of-two length
  buckets so the prefill jit traces O(log2 max_seq) times instead of
  once per distinct prompt length; the resulting KV is scattered into
  the pool (or the slot's dense region).  With ``prefill_chunk=C`` the
  monolith is replaced by CHUNKED prefill: the prompt becomes resident
  C tokens per ``step()``, each chunk's KV written incrementally into
  the pool while the same step still dispatches a decode window — so a
  long prompt no longer freezes every in-flight stream for its whole
  bucketed prefill (``EngineStats.decode_stalls`` measures exactly
  that, and is structurally zero in chunked mode).  Token streams are
  bit-identical to monolithic prefill for greedy decoding.
* **Preemption** — when the pool is exhausted, the newest sequence is
  evicted and re-prefiled later (recompute), protecting old requests.
* **Fused sampling (C1)** — by default the sampler runs INSIDE the
  jitted decode program (the paper's VXE "sampling with sort"):
  per-slot SamplingParams ride as device arrays, the rng chain is
  device state, and only int32 token ids cross to the host — O(slots)
  bytes per token instead of the O(slots x vocab) logits row
  (``EngineStats.host_syncs`` / ``bytes_to_host`` measure it).
  ``steps_per_sync=S`` further runs S decode steps as one ``lax.scan``
  window with on-device stop masking (host reconciles overrun tokens
  after readback), and ``pipeline=True`` double-buffers window k+1's
  dispatch before blocking on window k.  ``sampling="host"`` keeps the
  pre-fusion loop as the parity oracle; token streams are identical in
  both modes (greedy bit-for-bit; stochastic for a fixed rng).

**Ring parallelism (C2)** — ``LPUEngine(model, params, mesh=...)`` with
a plan built for the mesh shards weights AND the KV pool over the
``model`` axis (stored kv heads split 1/tp per rank: same block ids on
every rank, 1/tp of the bytes).  Decode and prefill are jitted
``shard_map`` programs whose matmuls stream partial products around the
ICI ring (:mod:`repro.core.esl` ``ag_matmul``/``rs_matmul``); the
engine's host loop — admission, block tables, sampling — is unchanged,
because tables and sampled tokens are replicated ring-wide.  The token
stream matches the single-device engine (tests/test_serving.py).

**Sub-rings (C3)** — :class:`MultiRingEngine` carves the model axis
into ``RingConfig`` sub-rings (:mod:`repro.core.rings`) and runs one
independent ``LPUEngine`` per sub-mesh: disjoint device groups, so no
collective of one tenant can touch another's ring.  Requests are
admitted per-ring by :class:`repro.serving.scheduler.RingRouter`
(least outstanding tokens).

Monitoring hooks expose tokens/s, slot occupancy, prefill trace count,
preemptions and KV bytes (total and per rank) — the datacenter-level
statistics HyperDex exposes from its driver.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import (Callable, Dict, List, Optional, Sequence, Set, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compiler.plan import resolve_kv_precision
from repro.core.compat import shard_map
from repro.core.dist import make_axis_env
from repro.core.rings import reconfigure, submeshes
from repro.kernels.decode_attention.ops import (plan_block_s,
                                                resolve_paged_kernel)
from repro.serving.kv_cache import (LANE, BlockPool, PrefixCache,
                                    assert_pool_balanced,
                                    cache_bytes, copy_pool_block,
                                    per_rank_block_bytes,
                                    pool_blocks_for_budget,
                                    scatter_prefill_dense,
                                    scatter_prefill_pages)
from repro.serving.config import EngineConfig, resolve_engine_config
from repro.serving.drafter import make_drafter
from repro.serving.ft import (Event, FailureInjector, HeartbeatTracker,
                              ManualClock, RingFailure, StragglerMonitor,
                              parse_chaos)
from repro.serving.sampler import (SamplingParams, sample_batched,
                                   sample_local, sample_sharded_batched,
                                   spec_verify_rows,
                                   speculative_verify_sharded,
                                   split_spec_rng_chain)
from repro.serving.scheduler import RingRouter, Scheduler, SeqSlot

StreamCB = Callable[[int, int], None]   # (request_id, token)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    params: SamplingParams = SamplingParams()
    out: List[int] = field(default_factory=list)
    done: bool = False
    stream_cb: Optional[StreamCB] = None
    failed: bool = False          # structured failure (rejection, or
                                  # migration retries exhausted) — the
                                  # request is done but its stream is
                                  # incomplete; ``error`` says why
    error: Optional[str] = None
    cancelled: bool = False       # caller-initiated abort (frontend
                                  # cancellation): done, not failed —
                                  # the partial stream is intentional

    def resume_tokens(self) -> List[int]:
        """Tokens whose KV must be resident before decoding continues.

        Fresh request: the prompt.  After preemption the generated tokens
        ride along — all but the last (which has been sampled, not yet
        fed through the model) are re-prefiled.
        """
        if not self.out:
            return list(self.prompt)
        return list(self.prompt) + list(self.out[:-1])


@dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    busy_slot_steps: int = 0
    slot_steps: int = 0
    wall: float = 0.0
    preemptions: int = 0
    prefill_traces: int = 0       # distinct prefill buckets traced
    prefills: int = 0             # total prefill launches (incl. resume)
    peak_pool_blocks: int = 0     # high-water block-pool occupancy
    host_syncs: int = 0           # blocking device->host readbacks
    prefill_syncs: int = 0        # ...of which sample a prefill row
    bytes_to_host: int = 0        # payload bytes of those readbacks
    overrun_tokens: int = 0       # sampled in a window, discarded by host
    prefill_chunks: int = 0       # chunk launches (chunked prefill mode)
    decode_stalls: int = 0        # monolithic prefills run while decode
                                  # streams were in flight: each one
                                  # froze every stream for a full
                                  # bucketed prefill (chunked mode: 0 —
                                  # a decode window dispatches in the
                                  # same step as each chunk)
    prefix_lookups: int = 0       # admissions that consulted the prefix
                                  # index (prefix_cache=True only)
    prefix_hits: int = 0          # ...that admitted with shared blocks
    prefix_hit_blocks: int = 0    # pool blocks mapped from the index
                                  # instead of freshly prefilled
    prefill_tokens_saved: int = 0 # prompt tokens NOT re-prefilled thanks
                                  # to prefix hits (the TTFT win)
    evicted_blocks: int = 0       # cached refcount-0 blocks recycled by
                                  # the pool's LRU under pressure
    cow_blocks: int = 0           # copy-on-write splits: a shared block
                                  # copied before a divergent KV write
    spec_rounds: int = 0          # speculative verify rounds dispatched
    draft_tokens: int = 0         # drafter-proposed tokens verified
    accepted_tokens: int = 0      # ...accepted by rejection sampling
    ring_failures: int = 0        # drain/rebuild cycles this engine went
                                  # through (detected or injected faults)
    migrated_requests: int = 0    # in-flight requests this engine took
                                  # over from a failed ring (recompute
                                  # resume via Request.resume_tokens)
    retries: int = 0              # recovery resubmissions admitted here
                                  # (every migration, incl. back onto
                                  # the rebuilt ring when it is alone)
    rejected_requests: int = 0    # admissions rejected with a structured
                                  # per-request failure instead of a
                                  # scheduler RuntimeError (livelock fix)
    cancelled_requests: int = 0   # caller-aborted via cancel(): slot and
                                  # blocks released mid-stream

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall if self.wall else 0.0

    @property
    def occupancy(self) -> float:
        return self.busy_slot_steps / max(self.slot_steps, 1)

    @property
    def bytes_to_host_per_token(self) -> float:
        """Device->host payload per decode token: O(slots * vocab) for
        the host-sampled path, O(slots) once sampling is fused in-jit."""
        return self.bytes_to_host / max(self.tokens, 1)

    @property
    def syncs_per_token(self) -> float:
        return self.host_syncs / max(self.tokens, 1)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prefix-index consultations that mapped at least
        one shared block into the admitted table."""
        return self.prefix_hits / max(self.prefix_lookups, 1)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafter-proposed tokens the verify pass accepted
        (counts only REAL proposals, never the padding of slots the
        drafter had nothing for)."""
        return self.accepted_tokens / max(self.draft_tokens, 1)

    @property
    def accepted_per_window(self) -> float:
        """Mean accepted draft tokens per speculative round — the
        latency win: each accepted token is one decode step the engine
        did not have to run."""
        return self.accepted_tokens / max(self.spec_rounds, 1)


class LPUEngine:
    """Slot-based continuous-batching decode engine (single host).

    ``mesh=None`` is the single-device smoke configuration.  With a
    1-axis ``model`` mesh (and a plan built for it) the engine runs its
    jitted steps inside ``shard_map`` over the ring — weights, the KV
    pool and the prefill caches are placed with the mapper's
    PartitionSpecs; block tables, positions and sampled tokens stay
    replicated host state, identical to the single-device loop.

    Construction: ``LPUEngine(model, params, config=EngineConfig(...))``
    with runtime objects (``mesh``, ``rng``, ``drafter``,
    ``draft_model``/``draft_params``) as direct keyword arguments.
    Loose scalar kwargs (``slots=8, paged=True, ...``) still work via
    the deprecation shim in :mod:`repro.serving.config` — they fold
    into an identical ``EngineConfig`` and warn once per process.
    """

    def __init__(self, model, params,
                 config: Optional[EngineConfig] = None, *,
                 mesh=None, rng: Optional[jax.Array] = None,
                 drafter=None, draft_model=None, draft_params=None,
                 **legacy_kwargs):
        c = resolve_engine_config(config, legacy_kwargs)
        self.config = c
        slots, max_seq, eos_id = c.slots, c.max_seq, c.eos_id
        paged, block_size, num_blocks = c.paged, c.block_size, c.num_blocks
        min_bucket, kv_budget_bytes = c.min_bucket, c.kv_budget_bytes
        paged_kernel, sampling = c.paged_kernel, c.sampling
        steps_per_sync, pipeline = c.steps_per_sync, c.pipeline
        block_s, prefill_chunk = c.block_s, c.prefill_chunk
        prefix_cache, speculate = c.prefix_cache, c.speculate
        draft_k = c.draft_k
        self.model = model
        self.cfg = model.cfg
        self.plan = model.plan
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.mesh = mesh
        if mesh is not None:
            assert self.plan.mesh_axes is not None and \
                tuple(mesh.axis_names) == tuple(self.plan.mesh_axes) and \
                tuple(mesh.devices.shape) == tuple(self.plan.mesh_shape), \
                (f"plan built for {self.plan.mesh_axes}"
                 f"{self.plan.mesh_shape} but engine mesh is "
                 f"{mesh.axis_names}{mesh.devices.shape}")
        self.tp = self.plan.tp if mesh is not None else 1
        self.env = make_axis_env(self.plan, batch=slots)
        self.env1 = make_axis_env(self.plan, batch=1)
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)

        if paged is None:
            paged = model.supports_paged_kv()
        self.paged = paged
        # KV storage precision: "auto" stores at the plan's cache dtype
        # (bit-identical to the historical engine); an explicit fp dtype
        # restores the pool at that width; int8/fp8 adds per-(row, kv
        # head) absmax scale side-arrays and in-kernel dequantization.
        self.kv_prec = resolve_kv_precision(c.kv_dtype,
                                            self.plan.cache_dtype)
        if self.kv_prec.quantized:
            if not paged:
                raise ValueError(
                    f"kv_dtype={c.kv_dtype!r} needs the paged KV pool: "
                    "quantization is a pool-storage contract (scales "
                    "live beside pool blocks); dense caches store fp")
            if self.kv_prec.store_dtype == "float8_e4m3fn" and \
                    not hasattr(jnp, "float8_e4m3fn"):
                raise ValueError(
                    "kv_dtype='fp8' needs jnp.float8_e4m3fn, which this "
                    "jax build does not provide; use kv_dtype='int8'")
        self.kv_dtype = self.kv_prec.store_dtype
        # w_dtype is the streamed-weight precision of the gemv decode
        # chain (core/streamline.decode_layer + kernels/gemv); the
        # engine's full-model decode keeps fp weights.  It is carried
        # here so the config round-trips and serving telemetry (bench
        # rows, the serve banner) reports the precision pair the
        # deployment requested.
        self.w_dtype = c.w_dtype
        if paged_kernel not in ("auto", "stream", "gather"):
            raise ValueError(f"paged_kernel={paged_kernel!r} not in "
                             "('auto', 'stream', 'gather')")
        # sampling="fused" (default) runs the sampler INSIDE the jitted
        # decode program — the paper's VXE "sampling with sort" (C1):
        # only the sampled token ids ever cross to the host.  "host" is
        # the pre-fusion baseline (full logits row to host, per-slot
        # python sampling), kept as the parity oracle and the synced
        # side of serving_bench's synced-vs-fused rows.
        if sampling not in ("fused", "host"):
            raise ValueError(f"sampling={sampling!r} not in "
                             "('fused', 'host')")
        if steps_per_sync < 1:
            raise ValueError(f"steps_per_sync={steps_per_sync} must be >= 1")
        if steps_per_sync > 1 and sampling != "fused":
            raise ValueError("steps_per_sync > 1 needs fused sampling: "
                             "the host path must read logits every step")
        self.sampling = sampling
        self.steps_per_sync = int(steps_per_sync)
        self.pipeline = bool(pipeline)
        self.block_s = int(block_s)
        # pow2 prefill buckets pad the prompt with token 0; attention
        # masks padded KV by valid length, but recurrent state (mamba /
        # rwkv) folds every position in — those families prefill at the
        # exact prompt length (one trace per distinct length, as before)
        self.bucketed = model.supports_paged_kv()
        if paged:
            self.block_size = block_size or min(LANE, max_seq)
            assert max_seq % self.block_size == 0, \
                (max_seq, self.block_size)
            self.table_len = max_seq // self.block_size
            if not num_blocks and kv_budget_bytes:
                # size the pool from the per-rank HBM budget: heads are
                # sharded over the ring, so a tp-ring stretches the same
                # budget to tp x the resident tokens — and a quantized
                # pool (block bytes ~halved, plus the scale side-array)
                # admits correspondingly more blocks under the SAME
                # budget: the memory half of the tentpole's claim
                a = self.plan.attn
                num_blocks = pool_blocks_for_budget(
                    kv_budget_bytes,
                    per_rank_block_bytes(
                        self.cfg.n_layers, a.kv_per_rank, a.d_head,
                        self.block_size, self.kv_prec.itemsize,
                        self.kv_prec.scale_itemsize))
            # default pool: dense-equivalent capacity + the null block
            self.num_blocks = num_blocks or (slots * self.table_len + 1)
        else:
            self.block_size = max_seq
            self.table_len = 1
            self.num_blocks = slots
        pool = self._init_kv_state()
        # paged decode dataflow: "stream" runs the Pallas paged kernel
        # straight off the pool (scalar-prefetched block table, no
        # contiguous per-request copy); "gather" keeps the materialized
        # (B, T*bs) view as the reference oracle; "auto" streams
        # whenever the stored GQA layout (and, compiled on TPU, the
        # tile alignment) allows it.  Resolved AFTER block_size so the
        # choice — and the kv_bytes_moved accounting keyed off it — is
        # what the decode program will actually execute.
        self.paged_kernel = (resolve_paged_kernel(
            self.plan, self.block_size, paged_kernel) if self.paged
            else None)
        if self.block_s and self.paged_kernel == "stream" and \
                self.block_s != self.block_size:
            raise ValueError(
                "the streamed paged kernel's KV tile IS the pool "
                f"block_size ({self.block_size}); block_s="
                f"{self.block_s} conflicts (use block_size, or the "
                "gather/dense paths where block_s sets the flash chunk)")
        # chunked prefill (--prefill-chunk): prompts become resident C
        # tokens per step, interleaved with decode windows, instead of
        # one monolithic bucketed prefill that stalls every in-flight
        # stream.  Needs the paged pool: chunk KV scatters incrementally
        # through the block table (recurrent-state families fold every
        # position into per-slot state and must prefill whole).
        if prefill_chunk < 0:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be >= 0")
        if prefill_chunk and not self.paged:
            raise ValueError(
                "prefill_chunk needs the paged KV pool (attention-only "
                "stacks); dense / recurrent-state caches prefill "
                "monolithically")
        self.prefill_chunk = int(prefill_chunk)
        # prefix caching (--prefix-cache): a block-aligned hash index
        # over prompt prefixes lets a new request map already-resident
        # blocks (refcounted) into its table and prefill only the tail;
        # shared blocks split copy-on-write at the first divergent KV
        # write, and refcount-0 cached blocks are recycled LRU-first.
        # Needs the paged pool: sharing is per-block by construction.
        if prefix_cache and not self.paged:
            raise ValueError(
                "prefix_cache needs the paged KV pool (attention-only "
                "stacks); the dense per-slot cache cannot share blocks")
        self.prefix_cache = bool(prefix_cache)
        self.prefix = PrefixCache(pool) if (self.paged and prefix_cache) \
            else None
        # speculative decoding (--speculate): a cheap drafter proposes up
        # to draft_k tokens per slot; ONE chunk-as-batch verify pass
        # scores all k+1 positions against the pool and on-device
        # rejection sampling accepts a prefix — stochastic streams draw
        # from exactly the target distribution and greedy streams are
        # bit-identical to the plain engine (repro.serving.sampler.
        # _verify_rows).  ``drafter=`` injects a custom proposer (tests
        # use adversarial / oracle drafters); otherwise ``speculate``
        # picks the built-in n-gram or small-model drafter.
        if speculate not in ("off", "ngram", "model"):
            raise ValueError(f"speculate={speculate!r} not in "
                             "('off', 'ngram', 'model')")
        if draft_k < 1:
            raise ValueError(f"draft_k={draft_k} must be >= 1")
        self.drafter = drafter if drafter is not None else make_drafter(
            speculate, draft_model=draft_model, draft_params=draft_params,
            max_seq=max_seq)
        if self.drafter is not None and not self.paged:
            raise ValueError(
                "speculate needs the paged KV pool: the verify pass "
                "scatters draft KV per-query through block tables")
        self.speculate = ("off" if self.drafter is None
                          else (speculate if speculate != "off"
                                else "custom"))
        self.draft_k = int(draft_k)
        self._verify_jits: Dict[tuple, Callable] = {}
        # fault tolerance: deterministic chaos + the detection seams.
        # ``ring_id`` is stamped by MultiRingEngine; a standalone engine
        # is ring 0.  The injector's fired-set lives OUTSIDE the state
        # reset() rebuilds, so a chaos event fires exactly once per
        # process even across drain/rebuild cycles.
        self.ring_id = 0
        self.events: List[Event] = []
        self._step_no = 0
        self._stalled = False
        self._poison_next = False
        if c.chaos:
            chaos_events = parse_chaos(c.chaos)
            if self.drafter is not None:
                raise ValueError(
                    "chaos injection does not compose with speculative "
                    "decoding yet: the verify path has no finite-logits "
                    "guard, so a NaN fault could commit tokens")
            if not self.paged and \
                    any(e.kind == "corrupt" for e in chaos_events):
                raise ValueError(
                    "chaos kind 'corrupt' poisons a KV pool block and "
                    "needs the paged pool (dense caches have no blocks)")
            self.injector: Optional[FailureInjector] = \
                FailureInjector(chaos=chaos_events)
        else:
            self.injector = None
        self.sched = Scheduler(slots, max_seq, pool, min_bucket,
                               prefix=self.prefix)
        self.stats = EngineStats()
        # cumulative bases for the stats fields ASSIGNED (not
        # incremented) from subsystem counters — scheduler preemptions,
        # pool evictions, prefix index counters.  reset() rebuilds those
        # subsystems from zero; folding the pre-reset totals in here
        # keeps EngineStats monotone across drain/rebuild cycles, so
        # per-window telemetry deltas (serving/tracker.py) never go
        # negative after a migration.
        self._ctr_base = dict(preemptions=0, evicted_blocks=0,
                              prefix_lookups=0, prefix_hits=0,
                              prefix_hit_blocks=0, prefill_tokens_saved=0)
        self._results: Dict[int, List[int]] = {}
        self._rid = 0
        self._chunk_rr = -1           # admit_seq of the last chunk served
        self._buckets_traced: Set[int] = set()
        self._window_jits: Dict[int, Callable] = {}
        self._sample_one = jax.jit(self._sample_one_fn)
        if mesh is None:
            self._decode = jax.jit(self._decode_fn)
            self._prefill = jax.jit(self._prefill_fn)
            self._prefill_chunk_fn = jax.jit(self._chunk_fn)
            self._write_pages = jax.jit(scatter_prefill_pages)
            self._write_dense = jax.jit(scatter_prefill_dense)
            self._copy_block = jax.jit(copy_pool_block)
        else:
            self._build_mesh_fns()

    def _init_kv_state(self) -> Optional[BlockPool]:
        """(Re)build the device KV state and its host mirrors from the
        engine's fixed geometry: a fresh zeroed cache (pool or dense),
        fresh block tables, and — paged — a fresh :class:`BlockPool`.
        Called at construction and by :meth:`reset` (ring rebuild)."""
        store = (None if self.kv_prec.requested == "auto"
                 else jnp.dtype(self.kv_prec.store_dtype))
        if self.paged:
            pool = BlockPool(self.num_blocks, self.block_size)
            scale_dt = (jnp.dtype(self.kv_prec.scale_dtype)
                        if self.kv_prec.quantized else None)
            self.cache = self.model.init_cache(
                self.slots, self.max_seq, paged=True,
                num_blocks=self.num_blocks, block_size=self.block_size,
                dtype=store, scale_dtype=scale_dt)
            self.block_tables = np.zeros((self.slots, self.table_len),
                                         np.int32)
        else:
            pool = None
            self.cache = self.model.init_cache(self.slots, self.max_seq,
                                               dtype=store)
            self.block_tables = None
        return pool

    def reset(self) -> List[Request]:
        """Drain this ring: drop every KV block, table and scheduling
        structure and rebuild them empty — the rebuild half of a ring
        drain/rebuild cycle.  Returns the orphaned in-flight requests
        (active sequences first, in admission order, then the queue) for
        the supervisor to migrate via the recompute-resume path
        (:meth:`Request.resume_tokens`).

        Finished results, stats, traced jits and the chaos injector's
        fired-set all survive: a rebuilt ring re-enters rotation without
        retracing a single program and without replaying chaos events.
        """
        orphans = [s.req for s in
                   sorted((s for s in self.sched.active if s is not None),
                          key=lambda s: s.admit_seq)]
        orphans += list(self.sched.queue)
        # the rebuilt scheduler/pool/prefix restart their counters at
        # zero: bank the cumulative totals so the ASSIGNED stats fields
        # stay monotone (telemetry deltas must never regress — the
        # tracker seam diffs consecutive snapshots)
        self._ctr_base["preemptions"] = self.stats.preemptions
        self._ctr_base["evicted_blocks"] = self.stats.evicted_blocks
        self._ctr_base["prefix_lookups"] = self.stats.prefix_lookups
        self._ctr_base["prefix_hits"] = self.stats.prefix_hits
        self._ctr_base["prefix_hit_blocks"] = self.stats.prefix_hit_blocks
        self._ctr_base["prefill_tokens_saved"] = \
            self.stats.prefill_tokens_saved
        pool = self._init_kv_state()
        if self.mesh is not None:
            self.cache = jax.device_put(self.cache, self._cache_named)
        self.prefix = (PrefixCache(pool)
                       if (self.paged and self.prefix is not None)
                       else None)
        self.sched = Scheduler(self.slots, self.max_seq, pool,
                               self.config.min_bucket, prefix=self.prefix)
        self._chunk_rr = -1
        self._stalled = False
        self._poison_next = False
        return orphans

    def check_pool_balanced(self) -> None:
        """Raise unless every pool block's refcount balances to zero
        (post-drain invariant; see
        :func:`repro.serving.kv_cache.assert_pool_balanced`)."""
        if self.sched.pool is not None:
            assert_pool_balanced(self.sched.pool, self.prefix)

    # -- chaos injection + detection (serving fault tolerance) ---------

    def _chaos_tick(self) -> None:
        """Fire this step's configured chaos events (exactly once each).

        ``ring`` raises :class:`RingFailure` outright; ``stall`` wedges
        the engine (no progress until the supervisor's heartbeat timeout
        drains it); ``nan`` poisons the next decode program's logits on
        device; ``corrupt`` overwrites a resident pool block with NaN —
        both of the latter are then *detected* by the finite-logits
        guard, never trusted to be benign.
        """
        if self.injector is None:
            return
        for ev in self.injector.fire(self._step_no, self.ring_id):
            self.events.append(Event("chaos", self._step_no,
                                     {"kind": ev.kind,
                                      "ring": self.ring_id}))
            if ev.kind == "ring":
                raise RingFailure("injected_ring_failure", self._step_no,
                                  self.ring_id)
            if ev.kind == "stall":
                self._stalled = True
            elif ev.kind == "nan":
                self._poison_next = True
            elif ev.kind == "corrupt":
                self._corrupt_pool_block()

    def _corrupt_pool_block(self) -> None:
        """Overwrite the first decode-ready sequence's first resident
        block with NaN across every floating cache leaf (a quantized
        pool is poisoned through its scale side-arrays).  The fault then
        surfaces exactly the way a real memory fault would: the next
        decode program's logits go non-finite and the guard fires."""
        blk = None
        for seq in self.sched.active:
            if seq is not None and not seq.prefilling and seq.blocks:
                blk = seq.blocks[0]
                break
        if blk is None:
            return                   # nothing resident: fault lands on air
        bad = jnp.int32(blk)

        def poison(pg):
            if jnp.issubdtype(pg.dtype, jnp.floating):
                return pg.at[:, bad].set(jnp.nan)
            return pg
        self.cache = jax.tree.map(poison, self.cache)
        if self.mesh is not None:
            self.cache = jax.device_put(self.cache, self._cache_named)

    # -- jitted steps --------------------------------------------------

    def _decode_fn(self, params, cache, tokens, positions, tables):
        logits, new_cache, _ = self.model.forward(
            params, tokens, env=self.env, mode="decode",
            positions=positions, cache=cache, block_tables=tables,
            paged_kernel=self.paged_kernel or "gather",
            block_s=self.block_s)
        return logits[:, -1], new_cache

    def _window_fn(self, S, params, cache, tables, last, pos, n_out,
                   alive, rng, temps, top_ks, top_ps, max_new, poison):
        """``S`` fused decode steps in ONE jitted program (lax.scan).

        Each scan step runs the forward, samples every slot in-jit
        (:func:`sample_batched`; the vocab-sharded
        :func:`sample_sharded_batched` under ring tp, so the full
        logits row never leaves the ranks), and applies the engine's
        finish rules ON DEVICE: a slot that hits eos / its token budget
        / max_seq drops out of ``alive`` and is FROZEN — its
        (last, pos) stop advancing, so subsequent steps rewrite the
        same KV entry with the same value (idempotent don't-care work,
        like the null-block writes of idle slots).  The host reads back
        only the (S, slots) int32 token matrix plus an (S, slots) bool
        **finite-logits flag** per sampled row (the fault-tolerance NaN
        guard: O(slots) extra bytes, never the vocab row) and discards
        the frozen slots' overrun tokens during reconciliation.

        ``poison`` is the chaos seam: a traced bool that overwrites the
        sampled-from logits rows with NaN, so the guard is exercised by
        a fault that genuinely happens on device.
        """
        eos = jnp.int32(-1 if self.eos_id is None else self.eos_id)
        axis, tp = self.env.model, self.tp

        def one(carry, _):
            cache, last, pos, n_out, alive, rng = carry
            logits, cache, _ = self.model.forward(
                params, last[:, None], env=self.env, mode="decode",
                positions=pos, cache=cache, block_tables=tables,
                paged_kernel=self.paged_kernel or "gather",
                block_s=self.block_s)
            row = logits[:, -1]
            row = jnp.where(poison, jnp.full_like(row, jnp.nan), row)
            # NaN guard: each rank checks its vocab shard; under tp the
            # verdict must agree ring-wide, so AND via psum
            ok = jnp.isfinite(row).all(axis=-1)
            if tp > 1:
                ok = lax.psum(ok.astype(jnp.int32), axis) == tp
            toks, rng = sample_sharded_batched(
                row, rng, temps, top_ks, top_ps, alive, axis,
                tp)
            live = alive.astype(jnp.int32)
            n_out = n_out + live
            pos = pos + live
            fin = (n_out >= max_new) | (toks == eos) | \
                (pos >= self.max_seq - 1)
            last = jnp.where(alive, toks, last)
            alive = alive & ~fin
            return (cache, last, pos, n_out, alive, rng), (toks, ok)

        (cache, last, pos, n_out, alive, rng), (tok_mat, ok_mat) = \
            lax.scan(one, (cache, last, pos, n_out, alive, rng), None,
                     length=S)
        return tok_mat, ok_mat, cache, last, pos, n_out, alive, rng

    def _window(self, S: int) -> Callable:
        """The jitted ``S``-step fused window (one trace per S)."""
        fn = self._window_jits.get(S)
        if fn is None:
            fn = (jax.jit(partial(self._window_fn, S)) if self.mesh is None
                  else self._build_mesh_window(S))
            self._window_jits[S] = fn
        return fn

    def _sample_one_fn(self, row, rng, temp, top_k, top_p):
        """Fused sampling of ONE prefill logits row; rng stays on device."""
        toks, rng = sample_batched(row[None], rng, temp[None],
                                   top_k[None], top_p[None])
        return toks[0], rng

    def _prefill_fn(self, params, tokens, true_len):
        """Batch-1 prefill of a bucket-padded prompt.

        Traced once per bucket size (``tokens.shape[1]``); ``true_len``
        is dynamic so distinct prompt lengths inside one bucket share
        the trace.  Returns (last-valid-token logits row, filled cache).
        """
        B, S = tokens.shape
        cache = self.model.init_cache(1, S)
        positions = jnp.broadcast_to(jnp.arange(S), (1, S))
        logits, new_cache, _ = self.model.forward(
            params, tokens, env=self.env1, mode="prefill", cache=cache,
            positions=positions)
        row = lax.dynamic_index_in_dim(logits[0], true_len - 1, 0,
                                       keepdims=False)
        return row, new_cache

    def _chunk_fn(self, params, cache, tokens, table, start, n_valid):
        """ONE prefill chunk of a partially-resident prompt.

        Unlike :meth:`_prefill_fn` this runs straight against the
        shared pool: the chunk's KV scatters incrementally through the
        request's block ``table`` and its queries attend to the full
        resident history (earlier chunks / recomputed tokens) via the
        same paged dataflow as decode — see
        :func:`repro.models.attention.chunk_prefill_attention`.

        tokens: (1, C) with C static — ONE trace total for any prompt
        mix (vs O(log2 max_seq) pow2 buckets); ``start`` (chunk offset)
        and ``n_valid`` (valid rows; the tail chunk is padded) are
        dynamic.  Returns (logits row of the chunk's last valid token —
        meaningful only for the final chunk — and the updated pool).
        """
        C = tokens.shape[1]
        positions = start + jnp.arange(C, dtype=jnp.int32)[None]
        logits, new_cache, _ = self.model.forward(
            params, tokens, env=self.env1, mode="chunk_prefill",
            positions=positions, cache=cache, block_tables=table[None],
            paged_kernel=self.paged_kernel or "gather",
            kv_valid_len=start + n_valid)
        row = lax.dynamic_index_in_dim(logits[0], n_valid - 1, 0,
                                       keepdims=False)
        return row, new_cache

    def _verify_fwd(self, params, cache, tokens, positions, tables, lens):
        """Forward of ONE speculative verify window.

        Every decode slot's (committed token + K drafts) ride flattened
        as a (1, B*(K+1)) batch of single-token queries with PER-QUERY
        block tables and valid lengths (``mode="verify"`` — see
        :func:`repro.models.attention.verify_attention`): query i of a
        slot attends its resident history plus the drafts before it, so
        one program scores all K+1 positions of every slot.  Returns
        the (B*(K+1), V) logits and the pool with the draft KV
        scattered in; rows past the accepted prefix are STALE but
        harmless — ``seq.pos`` never advances over them, so they are
        masked by valid length and overwritten by the next round's
        writes (logical rollback, zero device work)."""
        logits, new_cache, _ = self.model.forward(
            params, tokens, env=self.env1, mode="verify",
            positions=positions, cache=cache, block_tables=tables,
            paged_kernel=self.paged_kernel or "gather",
            kv_valid_len=lens)
        return logits[0], new_cache

    def _verify_fused_fn(self, K, params, cache, tokens, positions,
                         tables, lens, draft, rng, temps, top_ks,
                         top_ps, alive):
        """Verify forward + in-jit rejection sampling (C1 composed with
        speculation): only (out, n_acc) int32 vectors cross to the host,
        never the (B, K+1, V) verify logits."""
        rows, cache = self._verify_fwd(params, cache, tokens, positions,
                                       tables, lens)
        rows = rows.reshape(self.slots, K + 1, -1)
        out, n_acc, rng = speculative_verify_sharded(
            rows, draft, rng, temps, top_ks, top_ps, alive,
            self.env.model, self.tp)
        return out, n_acc, cache, rng

    def _verify(self, K: int) -> Callable:
        """The jitted verify program for draft length ``K`` (one trace
        per distinct K; rounds cap K near the end of a sequence, so
        only a handful of values ever trace)."""
        key = (K, self.sampling == "fused")
        fn = self._verify_jits.get(key)
        if fn is None:
            if self.mesh is not None:
                fn = self._build_mesh_verify(K)
            elif self.sampling == "fused":
                fn = jax.jit(partial(self._verify_fused_fn, K))
            else:
                fn = jax.jit(self._verify_fwd)
            self._verify_jits[key] = fn
        return fn

    # -- ring-parallel (shard_map) step construction -------------------

    def _named(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            spec_tree, is_leaf=lambda x: isinstance(x, P))

    def _build_mesh_fns(self) -> None:
        """shard_map-wrapped decode/prefill over the model ring.

        Everything the host loop touches stays replicated (tokens,
        positions, block tables in; the sampled-from logits row out, so
        sampling happens once, ring-consistent).  Weights, the KV pool
        and prefill caches live sharded per the mapper's specs; inside
        the program every matmul is an ESL collective matmul.
        """
        mesh, m = self.mesh, self.plan.tp_axis
        specs, _ = self.model.param_specs()
        self.params = jax.device_put(self.params, self._named(specs))
        cspecs = self.model.cache_specs(self.env, paged=self.paged,
                                        kv_quant=self.kv_prec.quantized)
        self._mesh_specs = (specs, cspecs)
        cspecs_named = self._named(cspecs)
        self._cache_named = cspecs_named     # reset() re-places with this
        self.cache = jax.device_put(self.cache, cspecs_named)
        pf_cspecs = self.model.cache_specs(self.env1)
        self._pf_named = self._named(pf_cspecs)
        self._pf_zero: Dict[int, object] = {}   # bucket -> zeroed cache

        if self.paged:
            def dec(params, cache, tokens, positions, tables):
                return self._decode_fn(params, cache, tokens, positions,
                                       tables)
            dec_sm = jax.jit(shard_map(
                dec, mesh=mesh,
                in_specs=(specs, cspecs, P(None, None), P(None),
                          P(None, None)),
                out_specs=(P(None, m), cspecs), check_vma=False))
            self._decode = dec_sm
        else:
            def dec_d(params, cache, tokens, positions):
                return self._decode_fn(params, cache, tokens, positions,
                                       None)
            dec_sm = jax.jit(shard_map(
                dec_d, mesh=mesh,
                in_specs=(specs, cspecs, P(None, None), P(None)),
                out_specs=(P(None, m), cspecs), check_vma=False))
            self._decode = lambda p, c, t, pos, tables: dec_sm(p, c, t, pos)

        def pre(params, cache0, tokens, true_len):
            B, S = tokens.shape
            positions = jnp.broadcast_to(jnp.arange(S), (1, S))
            logits, new_cache, _ = self.model.forward(
                params, tokens, env=self.env1, mode="prefill",
                cache=cache0, positions=positions)
            row = lax.dynamic_index_in_dim(logits[0], true_len - 1, 0,
                                           keepdims=False)
            return row, new_cache

        pre_sm = jax.jit(shard_map(
            pre, mesh=mesh,
            in_specs=(specs, pf_cspecs, P(None, None), P()),
            out_specs=(P(m), pf_cspecs), check_vma=False))

        def prefill(params, tokens, true_len):
            # the bucket cache is an INPUT here (the single-device path
            # allocates it inside the jit): shard_map needs it placed
            # with the mapper's specs, and prefill overwrites the whole
            # [0:S) prefix, so one zeroed buffer per bucket is reusable
            S = int(tokens.shape[1])
            if S not in self._pf_zero:
                self._pf_zero[S] = jax.device_put(
                    self.model.init_cache(1, S), self._pf_named)
            return pre_sm(params, self._pf_zero[S], tokens, true_len)

        self._prefill = prefill
        if self.paged:
            # chunked prefill against the ring-sharded pool: the pool
            # rides in/out with the mapper's specs (head dim 1/tp per
            # rank, same block ids everywhere), tokens/table/offsets are
            # replicated host state and the logits row comes out
            # vocab-sharded exactly like the monolithic prefill's.
            def chunk(params, cache, tokens, table, start, n_valid):
                return self._chunk_fn(params, cache, tokens, table,
                                      start, n_valid)
            self._prefill_chunk_fn = jax.jit(shard_map(
                chunk, mesh=mesh,
                in_specs=(specs, cspecs, P(None, None), P(None), P(),
                          P()),
                out_specs=(P(m), cspecs), check_vma=False))
        self._write_pages = jax.jit(scatter_prefill_pages,
                                    out_shardings=cspecs_named)
        self._write_dense = jax.jit(scatter_prefill_dense,
                                    out_shardings=cspecs_named)
        self._copy_block = jax.jit(copy_pool_block,
                                   out_shardings=cspecs_named)

    def _build_mesh_window(self, S: int) -> Callable:
        """shard_map-wrapped fused window over the model ring.

        Token ids come out REPLICATED: every rank runs the identical
        rng chain and samples from the same all-gathered (tp x k)
        candidate set (:func:`sample_sharded_batched`), so no broadcast
        is needed and the full vocab row never leaves the ranks — the
        multi-LPU form of the paper's on-chip sampling.
        """
        mesh = self.mesh
        specs, cspecs = self._mesh_specs
        rep = P(None)
        # tok_mat + the NaN-guard ok_mat come out replicated (the psum
        # inside _window_fn makes the per-rank verdicts agree)
        out_specs = (P(None, None), P(None, None), cspecs) + (rep,) * 5

        if self.paged:
            def win(params, cache, tables, last, pos, n_out, alive, rng,
                    temps, top_ks, top_ps, max_new, poison):
                return self._window_fn(S, params, cache, tables, last,
                                       pos, n_out, alive, rng, temps,
                                       top_ks, top_ps, max_new, poison)
            return jax.jit(shard_map(
                win, mesh=mesh,
                in_specs=(specs, cspecs, P(None, None)) + (rep,) * 9
                + (P(),),
                out_specs=out_specs, check_vma=False))

        def win_d(params, cache, last, pos, n_out, alive, rng,
                  temps, top_ks, top_ps, max_new, poison):
            return self._window_fn(S, params, cache, None, last, pos,
                                   n_out, alive, rng, temps, top_ks,
                                   top_ps, max_new, poison)
        sm = jax.jit(shard_map(
            win_d, mesh=mesh,
            in_specs=(specs, cspecs) + (rep,) * 9 + (P(),),
            out_specs=out_specs, check_vma=False))

        def drop_tables(params, cache, tables, *rest):
            return sm(params, cache, *rest)
        # keep .lower working (lower_decode_text / the bench's gate)
        drop_tables.lower = \
            lambda params, cache, tables, *rest: sm.lower(params, cache,
                                                          *rest)
        return drop_tables

    def _build_mesh_verify(self, K: int) -> Callable:
        """shard_map form of the verify program over the model ring.

        Tokens / positions / tables / drafts are replicated host state
        in, verified token ids come out replicated: every rank runs the
        identical rng chain over the all-gathered (tp x 64) candidate
        set (:func:`speculative_verify_sharded`), so the full verify
        logits never leave the ranks — same contract as the fused
        window.  The host-sampling variant returns the vocab-sharded
        logits rows instead (the parity oracle reads them back)."""
        mesh, m = self.mesh, self.plan.tp_axis
        specs, cspecs = self._mesh_specs
        rep1, rep2 = P(None), P(None, None)
        if self.sampling == "fused":
            def ver(params, cache, tokens, positions, tables, lens,
                    draft, rng, temps, top_ks, top_ps, alive):
                return self._verify_fused_fn(
                    K, params, cache, tokens, positions, tables, lens,
                    draft, rng, temps, top_ks, top_ps, alive)
            return jax.jit(shard_map(
                ver, mesh=mesh,
                in_specs=(specs, cspecs, rep2, rep2, rep2, rep1, rep2,
                          rep1, rep1, rep1, rep1, rep1),
                out_specs=(rep2, rep1, cspecs, rep1), check_vma=False))

        def ver_h(params, cache, tokens, positions, tables, lens):
            return self._verify_fwd(params, cache, tokens, positions,
                                    tables, lens)
        return jax.jit(shard_map(
            ver_h, mesh=mesh,
            in_specs=(specs, cspecs, rep2, rep2, rep2, rep1),
            out_specs=(P(None, m), cspecs), check_vma=False))

    # -- sampling ------------------------------------------------------

    def _sample(self, logits_np: np.ndarray, logits_dev,
                params: SamplingParams) -> int:
        """Host-path sampling (``sampling="host"``): per-slot python loop
        over a full logits row already copied to host — the pre-fusion
        baseline whose rng-split order the fused sampler reproduces."""
        if params.temperature <= 0.0:
            return int(np.argmax(logits_np))
        self.rng, sub = jax.random.split(self.rng)
        self.stats.host_syncs += 1
        self.stats.bytes_to_host += 4
        return int(sample_local(logits_dev[None], sub, params)[0])

    def _sample_first(self, row, params: SamplingParams) -> int:
        """Sample the prefill row per the engine's sampling mode.

        Fused: the row stays on device, only the token id (4 bytes)
        crosses; the rng chain advances on device exactly as the host
        loop would (greedy consumes nothing).
        """
        if self.sampling == "fused":
            tok, self.rng = self._sample_one(
                row, self.rng, np.float32(params.temperature),
                np.int32(params.top_k), np.float32(params.top_p))
            self.stats.host_syncs += 1
            self.stats.prefill_syncs += 1
            self.stats.bytes_to_host += 4
            return int(tok)
        row_np = np.asarray(row)
        self.stats.host_syncs += 1
        self.stats.bytes_to_host += row_np.nbytes
        before = self.stats.host_syncs
        tok = self._sample(row_np, row, params)
        # the row readback + any nested stochastic draw are both
        # prefill-attributed syncs (decode syncs = host_syncs - these)
        self.stats.prefill_syncs += 1 + self.stats.host_syncs - before
        return tok

    # -- prefill + admission -------------------------------------------

    def _refresh_tables(self) -> None:
        """Mirror decode-ready sequences' block lists into the replicated
        (slots, T) table the decode programs consume.  Slots that are
        empty OR still prefilling stay all-zero: their don't-care window
        writes land in the null block — a prefilling slot's REAL blocks
        are known only to the host and the per-chunk program, so decode
        can never clobber a partially-resident prompt.

        A FRESH array is allocated every refresh, never an in-place
        rewrite: ``jnp.asarray`` on CPU can alias an aligned numpy
        buffer zero-copy, so mutating the old array would race with a
        still-executing window that was dispatched against it (the
        pipelined h2 dispatch refreshes tables while h1 is in flight) —
        the transiently zeroed rows read as null-block garbage and
        corrupt the stream."""
        if not self.paged:
            return
        tables = np.zeros((self.slots, self.table_len), np.int32)
        for slot, seq in enumerate(self.sched.active):
            if seq is not None and seq.blocks and not seq.prefilling:
                tables[slot, :len(seq.blocks)] = seq.blocks
        self.block_tables = tables

    def _should_finish(self, seq: SeqSlot, tok: int) -> bool:
        req = seq.req
        return (len(req.out) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id)
                or seq.pos >= self.max_seq - 1)

    def _finish(self, seq: SeqSlot) -> Request:
        req = seq.req
        req.done = True
        self._results[req.rid] = req.out
        self.sched.release(seq)
        return req

    def _do_prefill(self, seq: SeqSlot) -> Optional[Request]:
        """Run MONOLITHIC bucketed prefill for a just-admitted sequence.

        The whole prompt (pow2-padded) runs as one batch-1 program and
        its cache is block-copied into the pool (or the slot's dense
        region) afterwards.  While it runs, every in-flight decode
        stream is frozen — ``stats.decode_stalls`` counts exactly those
        launches (the tail-latency cliff ``prefill_chunk`` removes).
        Returns the request if it finished immediately (eos /
        max_new_tokens == 1).
        """
        req = seq.req
        tokens = req.resume_tokens()
        if self.sched.num_decoding() > 0:
            self.stats.decode_stalls += 1
        if seq.cached:
            return self._prefill_tail(seq, tokens)
        bucket = (self.sched.bucket(len(tokens)) if self.bucketed
                  else len(tokens))
        buf = np.zeros((1, bucket), np.int32)
        buf[0, :len(tokens)] = tokens
        row, pc = self._prefill(self.params, jnp.asarray(buf),
                                jnp.int32(len(tokens)))
        self._buckets_traced.add(bucket)
        self.stats.prefills += 1
        slot = self.sched.slot_of(seq)
        if self.paged:
            table = np.zeros((bucket // self.block_size,), np.int32)
            table[:len(seq.blocks)] = seq.blocks
            self.cache = self._write_pages(self.cache, pc,
                                           jnp.asarray(table))
        else:
            self.cache = self._write_dense(self.cache, pc, jnp.int32(slot))
        return self._finish_prefill(seq, row)

    def _prefill_tail(self, seq: SeqSlot, tokens: List[int]
                      ) -> Optional[Request]:
        """Prefill ONLY the un-cached tail of a prefix-cache hit.

        The first ``seq.cached`` tokens' KV is already resident in the
        shared blocks mapped at admission; the tail runs through the
        chunk-prefill program (its queries attend the resident history
        through the paged dataflow, its KV scatters through the table),
        pow2-bucketed so tail lengths share O(log2 max_seq) traces with
        the chunked-prefill path.  Shared blocks the tail writes into
        (a hit capped mid-block) are split copy-on-write first.
        """
        n = len(tokens)
        start = seq.cached
        C = self.sched.bucket(n - start)
        self._ensure_writable(seq, start, n)
        buf = np.zeros((1, C), np.int32)
        buf[0, :n - start] = tokens[start:n]
        table = np.zeros((self.table_len,), np.int32)
        table[:len(seq.blocks)] = seq.blocks
        row, self.cache = self._prefill_chunk_fn(
            self.params, self.cache, jnp.asarray(buf), jnp.asarray(table),
            jnp.int32(start), jnp.int32(n - start))
        self._buckets_traced.add(("chunk", C))
        self.stats.prefills += 1
        return self._finish_prefill(seq, row)

    def _ensure_writable(self, seq: SeqSlot, lo: int, hi: int,
                         allow_preempt: bool = True) -> bool:
        """Copy-on-write guard: before KV for positions ``[lo, hi)`` of
        ``seq`` is scattered, any block in that span referenced by MORE
        than one table is copied device-side into a fresh block, the
        fresh block swapped into ``seq``'s table, and the shared
        original released — so the write can never reach another
        request's (or the index's still-shared) resident KV.

        Returns False (nothing copied beyond what already succeeded)
        when a fresh block cannot be had without preemption and
        ``allow_preempt`` is False — the retry-capable chunk path waits
        for the next step.  Sole-owner blocks are written in place even
        when index-registered: the write carries the SAME token's KV
        (hits are capped at ``n - 1``, so the only in-span registered
        positions are re-computations of the hashed tokens), which
        keeps every index entry's content claim intact.
        """
        if self.sched.pool is None or hi <= lo:
            return True
        bs = self.block_size
        for li in range(lo // bs, (hi - 1) // bs + 1):
            if li >= len(seq.blocks):
                break
            old = seq.blocks[li]
            if self.sched.pool.ref[old] <= 1:
                continue
            new, _ = self.sched.cow_alloc(seq, allow_preempt)
            if new is None:
                return False
            self.cache = self._copy_block(self.cache, jnp.int32(old),
                                          jnp.int32(new))
            seq.blocks[li] = new
            self.sched.pool.free([old])
            self.stats.cow_blocks += 1
        return True

    def _cow_pending(self, seq: SeqSlot, lo: int, hi: int) -> bool:
        """True while any block in the span is still multiply-referenced
        (i.e. :meth:`_ensure_writable` has not run / could not finish)."""
        pool = self.sched.pool
        if pool is None or hi <= lo:
            return False
        bs = self.block_size
        top = min((hi - 1) // bs + 1, len(seq.blocks))
        return any(pool.ref[seq.blocks[li]] > 1
                   for li in range(lo // bs, top))

    def _finish_prefill(self, seq: SeqSlot, row) -> Optional[Request]:
        """Shared tail of both prefill paths, once the prompt is fully
        resident: restore the last sampled token (preemption resume) or
        sample the first one from the final logits row, then apply the
        finish rules.  Returns the request if it finished immediately."""
        req = seq.req
        if self.prefix is not None:
            self.prefix.register(req.resume_tokens(), seq.blocks)
        if seq.resumed:
            seq.last_token = req.out[-1]
            return None
        tok = self._sample_first(row, req.params)
        req.out.append(tok)
        seq.last_token = tok
        if req.stream_cb:
            req.stream_cb(req.rid, tok)
        if self._should_finish(seq, tok):
            return self._finish(seq)
        return None

    def _run_prefill_chunk(self, seq: SeqSlot) -> Optional[Request]:
        """Make the next ``prefill_chunk`` prompt tokens of ``seq``
        resident (KV scattered incrementally into the pool through the
        request's table); on the final chunk, hand off to
        :meth:`_finish_prefill`.  The caller has already reserved the
        chunk's blocks (:meth:`Scheduler.chunk_reserve`).  Returns the
        request if it finished immediately."""
        req = seq.req
        tokens = req.resume_tokens()
        C = self.prefill_chunk
        start = seq.prefilled
        n_valid = min(C, len(tokens) - start)
        buf = np.zeros((1, C), np.int32)
        # table is built AFTER the CoW guard: a split swaps block ids
        assert not self._cow_pending(seq, start, start + n_valid)
        buf[0, :n_valid] = tokens[start:start + n_valid]
        table = np.zeros((self.table_len,), np.int32)
        table[:len(seq.blocks)] = seq.blocks
        row, self.cache = self._prefill_chunk_fn(
            self.params, self.cache, jnp.asarray(buf), jnp.asarray(table),
            jnp.int32(start), jnp.int32(n_valid))
        seq.prefilled = start + n_valid
        seq.pos = seq.prefilled
        self._buckets_traced.add(("chunk", C))
        self.stats.prefills += 1
        self.stats.prefill_chunks += 1
        if seq.prefilling:
            return None              # more chunks next step
        return self._finish_prefill(seq, row)

    def _admit_and_chunk(self) -> List[Request]:
        """Chunked-mode admission: admit while slots + first-chunk
        blocks allow, then run ONE prefill chunk — the per-step prefill
        budget — for one prefilling sequence.  The decode window
        dispatched later in the same :meth:`_step` is what makes the
        interleave: active streams keep producing a token per step
        while a long prompt trickles in, instead of standing still for
        its whole bucketed prefill.

        The chunk goes to prefilling sequences ROUND-ROBIN (by
        admission order, resuming after the last one served), not
        FIFO-to-completion: a 40-token prompt ahead of a 3-token prompt
        must not hold the short one's first token hostage for ten
        steps — exactly the head-of-line blocking chunking exists to
        remove."""
        finished: List[Request] = []
        while self.sched.admit_next(chunk=self.prefill_chunk) is not None:
            pass
        cands = self.sched.prefilling()
        # rotate so the scan starts just after the last sequence served
        # (each candidate probed at most once per step)
        i = next((j for j, s in enumerate(cands)
                  if s.admit_seq > self._chunk_rr), 0)
        for seq in cands[i:] + cands[:i]:
            allow_preempt = self.sched.num_decoding() == 0
            got = self.sched.chunk_reserve(
                seq, self.prefill_chunk,
                allow_preempt=allow_preempt)
            if got is None:
                continue             # pool pressure: try the next seq
            nxt = min(seq.prefilled + self.prefill_chunk,
                      seq.prefill_target)
            if not self._ensure_writable(seq, seq.prefilled, nxt,
                                         allow_preempt=allow_preempt):
                continue             # no CoW block free: try the next seq
            self._chunk_rr = seq.admit_seq
            done = self._run_prefill_chunk(seq)
            if done is not None:
                finished.append(done)
            break                    # ONE chunk per step
        return finished

    # -- public API ----------------------------------------------------

    def submit(self, prompt: Union[Request, Sequence[int]],
               max_new_tokens: int = 32,
               params: Optional[SamplingParams] = None,
               stream_cb: Optional[StreamCB] = None) -> int:
        """Enqueue a request (non-blocking).  Returns its request id."""
        if isinstance(prompt, Request):
            req = prompt
        else:
            req = Request(self._rid, list(prompt), max_new_tokens,
                          params or SamplingParams(0.0, 0, 1.0),
                          stream_cb=stream_cb)
        if not req.prompt:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"prompt length {len(req.prompt)} >= max_seq "
                f"{self.max_seq}: no room to decode")
        self._rid = max(self._rid, req.rid) + 1
        self.sched.submit(req)
        return req.rid

    def has_work(self) -> bool:
        """True while the queue or any slot holds an unfinished request
        (same signal :meth:`drain` loops on; the async frontend's pump
        uses it to idle without busy-stepping an empty engine)."""
        return self.sched.has_work()

    def cancel(self, rid: int) -> Optional[Request]:
        """Abort one request between steps: pop it from the queue, or
        release its slot and free its pool blocks if already admitted
        (shared prefix blocks just drop a refcount — cached KV survives
        for future hits).  The partial stream is kept in the results
        buffer.  Returns the request, or None if ``rid`` is not in
        flight here (already finished, or routed to another ring).

        Host-side only and safe by construction: ``step()`` reconciles
        every dispatched window before returning, so no in-flight device
        program can still scatter into the freed blocks.
        """
        req = None
        for r in self.sched.queue:
            if r.rid == rid:
                self.sched.queue.remove(r)
                req = r
                break
        else:
            for seq in self.sched.active:
                if seq is not None and seq.req.rid == rid:
                    self.sched.release(seq)
                    req = seq.req
                    break
        if req is None:
            return None
        req.done = True
        req.cancelled = True
        self._results[rid] = req.out
        self.stats.cancelled_requests += 1
        self.events.append(Event("request_cancelled", self._step_no,
                                 {"rid": rid, "tokens": len(req.out)}))
        return req

    def set_step_knobs(self, prefill_chunk: Optional[int] = None,
                       steps_per_sync: Optional[int] = None) -> None:
        """Retune the per-step latency knobs between steps — the seam
        the SLO budget scheduler (serving/budget.py) drives.

        Cheap by design: decode windows are jitted per window size
        (``_window_jits[S]``) so a new ``steps_per_sync`` at worst adds
        one trace, and the chunk program retraces once per distinct
        chunk width (the budget scheduler quantizes to powers of two to
        bound that).  Validation mirrors construction; additionally a
        chunked engine can never drop back to ``prefill_chunk=0`` —
        mid-prefill sequences would starve (only ``_admit_and_chunk``
        feeds them), and monolithic prefill cannot resume a
        half-resident prompt.
        """
        if steps_per_sync is not None:
            s = int(steps_per_sync)
            if s < 1:
                raise ValueError(f"steps_per_sync={s} must be >= 1")
            if s > 1 and self.sampling != "fused":
                raise ValueError("steps_per_sync > 1 needs fused sampling")
            self.steps_per_sync = s
        if prefill_chunk is not None:
            c = int(prefill_chunk)
            if c < 0:
                raise ValueError(f"prefill_chunk={c} must be >= 0")
            if c and not self.paged:
                raise ValueError("prefill_chunk needs the paged KV pool")
            if c == 0 and self.prefill_chunk:
                raise ValueError(
                    "cannot leave chunked-prefill mode mid-serve: "
                    "admitted prompts may be partially resident")
            self.prefill_chunk = c

    def step(self) -> List[Request]:
        """One scheduler round: admit + prefill (monolithic, or ONE
        chunk in ``prefill_chunk`` mode), then one decode round for the
        whole slot batch — a fused window of up to ``steps_per_sync``
        device steps (pipelined one window ahead) in the default fused
        mode, or a single host-sampled step with ``sampling="host"``.
        In chunked mode the prefill chunk and the decode window share
        the step — that interleave is what keeps active streams
        producing while a long prompt admits.  Returns requests
        finished this round."""
        t0 = time.time()
        try:
            return self._step()
        finally:
            self.stats.wall += time.time() - t0

    def _step(self) -> List[Request]:
        self._step_no += 1
        self._chaos_tick()
        if self._stalled:
            # injected stall: the ring makes no progress this step (and
            # every later one) — the fleet's heartbeat tracker is what
            # notices and drains it.
            return []
        finished: List[Request] = []
        if self.prefill_chunk:
            finished += self._admit_and_chunk()
        else:
            while True:
                seq = self.sched.admit_next()
                if seq is None:
                    break
                done = self._do_prefill(seq)
                if done is not None:
                    finished.append(done)
        finished += self._harvest_rejections()
        self.sched.ensure_decode_capacity()     # may preempt (recompute)
        base = self._ctr_base
        self.stats.preemptions = base["preemptions"] \
            + self.sched.preemptions
        if self.sched.pool is not None:
            self.stats.peak_pool_blocks = max(self.stats.peak_pool_blocks,
                                              self.sched.pool.num_used)
            self.stats.evicted_blocks = base["evicted_blocks"] \
                + self.sched.pool.evicted_blocks
        if self.prefix is not None:
            self.stats.prefix_lookups = base["prefix_lookups"] \
                + self.prefix.lookups
            self.stats.prefix_hits = base["prefix_hits"] + self.prefix.hits
            self.stats.prefix_hit_blocks = base["prefix_hit_blocks"] \
                + self.prefix.hit_blocks
            self.stats.prefill_tokens_saved = base["prefill_tokens_saved"] \
                + self.prefix.tokens_saved
        if self.sched.num_decoding() == 0:
            return finished
        if self.drafter is not None:
            finished += self._spec_decode_round()
        elif self.sampling == "fused":
            finished += self._fused_decode_round()
        else:
            finished += self._host_decode_step()
        self.stats.prefill_traces = len(self._buckets_traced)
        return finished

    def _harvest_rejections(self) -> List[Request]:
        """Surface scheduler admission rejections (request can NEVER
        fit, e.g. needs more blocks than the whole pool) as structured
        per-request failures instead of the historical engine-crashing
        ``RuntimeError`` — see Scheduler.take_rejected()."""
        finished: List[Request] = []
        for req, why in self.sched.take_rejected():
            req.done = True
            req.failed = True
            req.error = why
            self._results[req.rid] = req.out
            self.stats.rejected_requests += 1
            self.events.append(Event("request_rejected", self._step_no,
                                     {"rid": req.rid, "why": why}))
            finished.append(req)
        return finished

    # -- host-sampled decode (the pre-fusion baseline) -----------------

    def _host_decode_step(self) -> List[Request]:
        """One decode step, sampling on host: the full (slots, vocab)
        logits tensor crosses to the host every token — the
        serialization the fused path removes (kept as the parity oracle
        and the "synced" row of serving_bench)."""
        self._refresh_tables()
        toks = np.zeros((self.slots, 1), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        for slot, seq in enumerate(self.sched.active):
            if seq is not None and not seq.prefilling:
                toks[slot, 0] = seq.last_token
                pos[slot] = seq.pos
        tables = (jnp.asarray(self.block_tables) if self.paged else None)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            tables)
        logits_np = np.asarray(logits)
        self.stats.host_syncs += 1
        self.stats.bytes_to_host += logits_np.nbytes
        if self._poison_next:
            # chaos "nan": corrupt the host copy (asarray may alias a
            # read-only device buffer) so the guard below trips.
            self._poison_next = False
            logits_np = np.array(logits_np)
            logits_np[:] = np.nan
        act = [slot for slot, seq in enumerate(self.sched.active)
               if seq is not None and not seq.prefilling]
        if act and not np.isfinite(logits_np[act]).all():
            bad = [slot for slot in act
                   if not np.isfinite(logits_np[slot]).all()]
            raise RingFailure("nan_logits", self._step_no, self.ring_id,
                              {"slots": bad})

        finished: List[Request] = []
        self.stats.steps += 1
        self.stats.slot_steps += self.slots
        for slot, seq in enumerate(self.sched.active):
            if seq is None or seq.prefilling:
                continue
            req = seq.req
            self.stats.busy_slot_steps += 1
            self.stats.tokens += 1
            tok = self._sample(logits_np[slot], logits[slot], req.params)
            req.out.append(tok)
            seq.pos += 1
            seq.last_token = tok
            if req.stream_cb:
                req.stream_cb(req.rid, tok)
            if self._should_finish(seq, tok):
                finished.append(self._finish(seq))
        return finished

    # -- fused decode: multi-step windows + double-buffered dispatch ---

    def _slot_state(self) -> Tuple[tuple, tuple]:
        """Host slot state -> the window program's carry + per-slot
        sampling params (tiny O(slots) uploads).

        A slot is marked ``alive`` only when it holds a DECODE-READY
        sequence.  Empty slots and slots still chunk-prefilling stay
        dead (zeros): the window freezes them — their (last, pos) never
        advance and their KV writes target the null block (the table
        row is zeroed by :meth:`_refresh_tables`), so a window can
        safely run concurrently with a prompt that is only partially
        resident."""
        B = self.slots
        last = np.zeros((B,), np.int32)
        pos = np.zeros((B,), np.int32)
        n_out = np.zeros((B,), np.int32)
        alive = np.zeros((B,), bool)
        temps = np.zeros((B,), np.float32)
        top_ks = np.zeros((B,), np.int32)
        top_ps = np.ones((B,), np.float32)
        max_new = np.zeros((B,), np.int32)
        for slot, seq in enumerate(self.sched.active):
            if seq is None or seq.prefilling:
                continue
            sp = seq.req.params
            last[slot] = seq.last_token
            pos[slot] = seq.pos
            n_out[slot] = len(seq.req.out)
            alive[slot] = True
            temps[slot] = sp.temperature
            top_ks[slot] = sp.top_k
            top_ps[slot] = sp.top_p
            max_new[slot] = seq.req.max_new_tokens
        return (last, pos, n_out, alive), (temps, top_ks, top_ps, max_new)

    def _admission_waiting(self) -> bool:
        """True when the baseline loop could admit next step: a queued
        request AND a free slot (pool pressure pending).  Multi-step
        windows shrink to a single step then, so admission latency
        stays at the single-step baseline's.  Note this is the
        *window-size* rule only — the full-prefill decode stall (every
        stream frozen while a long prompt prefills monolithically) is
        what ``prefill_chunk`` removes; see :meth:`_admit_and_chunk`."""
        return bool(self.sched.queue) and \
            any(s is None for s in self.sched.active)

    def _may_survive(self, steps: int) -> bool:
        """Could any decode-ready slot still be alive after ``steps``
        more tokens?  (Budget/length check only — eos can still end a
        window early; speculation past an eos is bounded waste, never
        wrong.  Prefilling slots sit windows out entirely.)"""
        for seq in self.sched.active:
            if seq is None or seq.prefilling:
                continue
            if (seq.req.max_new_tokens - len(seq.req.out)) > steps and \
                    (self.max_seq - 1 - seq.pos) > steps:
                return True
        return False

    def _dispatch_window(self, win: int, carry: tuple, samp: tuple):
        """Launch one fused window (non-blocking: jax dispatch is async).
        Returns ((win, token matrix, ok matrix, active snapshot),
        device carry).  Consumes a pending chaos ``nan`` event: the
        poison flag rides into the program as a traced bool, so the
        fault happens on device and only the guard can catch it."""
        tables = (jnp.asarray(self.block_tables) if self.paged else None)
        poison = np.bool_(self._poison_next)
        self._poison_next = False
        out = self._window(win)(self.params, self.cache, tables, *carry,
                                self.rng, *samp, poison)
        tok_mat, ok_mat, self.cache, last, pos, n_out, alive, self.rng \
            = out
        snapshot = [s is not None and not s.prefilling
                    for s in self.sched.active]
        return (win, tok_mat, ok_mat, snapshot), (last, pos, n_out, alive)

    def _reconcile(self, handle) -> List[Request]:
        """Block on a window's token matrix (the ONE device->host sync
        per window) and replay the finish rules the device already
        applied: tokens of slots that finished earlier in the window —
        or in a previously reconciled window — are overrun and
        discarded; everything else appends exactly as the single-step
        loop would.

        The NaN guard runs per window step BEFORE that step's tokens
        commit: the first step whose sampled-from row went non-finite
        for any dispatched slot raises :class:`RingFailure` — tokens of
        earlier (finite) steps are already committed, tokens at or
        after the fault never reach a request, so a recovered stream
        can be bit-identical to a fault-free run."""
        win, tok_mat, ok_mat, dispatch_active = handle
        toks = np.asarray(tok_mat)                     # (win, slots)
        oks = np.asarray(ok_mat)                       # (win, slots) bool
        self.stats.host_syncs += 1
        self.stats.bytes_to_host += toks.nbytes + oks.nbytes
        finished: List[Request] = []
        for s in range(win):
            bad = [slot for slot in range(self.slots)
                   if dispatch_active[slot] and not oks[s, slot]]
            if bad:
                raise RingFailure(
                    "nan_logits", self._step_no, self.ring_id,
                    {"window_step": s, "slots": bad})
            if self.sched.num_decoding() == 0:
                self.stats.overrun_tokens += \
                    (win - s) * sum(dispatch_active)
                break
            self.stats.steps += 1
            self.stats.slot_steps += self.slots
            for slot, seq in enumerate(self.sched.active):
                if seq is None or seq.prefilling:
                    if dispatch_active[slot]:
                        self.stats.overrun_tokens += 1
                    continue
                req = seq.req
                self.stats.busy_slot_steps += 1
                self.stats.tokens += 1
                tok = int(toks[s, slot])
                req.out.append(tok)
                seq.pos += 1
                seq.last_token = tok
                if req.stream_cb:
                    req.stream_cb(req.rid, tok)
                if self._should_finish(seq, tok):
                    finished.append(self._finish(seq))
        return finished

    def _fused_decode_round(self) -> List[Request]:
        """One fused decode round: up to two pipelined windows.

        Window size is ``steps_per_sync`` whenever no admission is
        waiting and the scheduler can reserve the whole window's blocks
        WITHOUT preemption (all-or-nothing, so speculative lookahead
        never evicts resident work); otherwise a single fused step.
        With ``pipeline=True`` and an empty queue, window k+1 is
        dispatched off window k's on-device carry BEFORE blocking on
        window k's tokens — the device-side finish masking makes the
        chained carry exact, so the speculation can waste compute
        (overrun tokens) but never produce wrong ones.
        """
        S = self.steps_per_sync
        win = S if (S > 1 and not self._admission_waiting()
                    and self.sched.reserve_lookahead(S)) else 1
        self._refresh_tables()
        carry, samp = self._slot_state()
        h1, dev_carry = self._dispatch_window(win, carry, samp)
        h2 = None
        if self.pipeline and not self.sched.queue \
                and self._may_survive(win) \
                and self.sched.reserve_lookahead(2 * win):
            self._refresh_tables()
            h2, _ = self._dispatch_window(win, dev_carry, samp)
        finished = self._reconcile(h1)
        if h2 is not None:
            finished += self._reconcile(h2)
        return finished

    def _spec_decode_round(self) -> List[Request]:
        """One draft-and-verify speculative round.

        Per decode-ready slot the drafter proposes up to ``draft_k``
        tokens from the request's visible token stream; ONE verify
        program scores all K+1 positions of every slot against the pool
        and rejection sampling accepts a per-slot prefix.  Slots the
        drafter had nothing for ride along with zero-padded drafts:
        rejection sampling is exact for ANY deterministic proposal, so
        correctness never depends on the drafter — only the acceptance
        counters (which track real proposals) do.  Rejected positions
        roll back logically (``seq.pos`` advances only over emitted
        tokens; stale KV is masked and overwritten next round), and the
        copy-on-write guard runs BEFORE the speculative write, so a
        rejected write can never have landed in a block another table
        still references.

        Rounds that cannot speculate — no proposal anywhere, no
        head-room before max_seq, or a lookahead-block shortfall — fall
        back to one plain round, so composition with admission,
        chunked prefill and preemption needs no special cases.
        """
        K = self.draft_k
        for seq in self.sched.active:
            if seq is not None and not seq.prefilling:
                K = min(K, self.max_seq - 1 - seq.pos)
        props: Dict[int, List[int]] = {}
        if K >= 1:
            for slot, seq in enumerate(self.sched.active):
                if seq is None or seq.prefilling:
                    continue
                p = self.drafter.propose(
                    list(seq.req.prompt) + list(seq.req.out), K)[:K]
                if p:
                    props[slot] = p
        if K < 1 or not props \
                or not self.sched.reserve_lookahead(1, draft_k=K):
            return (self._fused_decode_round()
                    if self.sampling == "fused"
                    else self._host_decode_step())
        for seq in self.sched.active:
            if seq is not None and not seq.prefilling:
                self._ensure_writable(seq, seq.pos, seq.pos + K + 1)
        self._refresh_tables()
        (last, pos, _, alive), (temps, top_ks, top_ps, _) = \
            self._slot_state()
        B, K1 = self.slots, K + 1
        toks = np.zeros((B, K1), np.int32)
        draft = np.zeros((B, K), np.int32)
        real = np.zeros((B,), np.int32)
        toks[:, 0] = last
        for slot, p in props.items():
            draft[slot, :len(p)] = p
            real[slot] = len(p)
        toks[:, 1:] = draft
        positions = pos[:, None] + np.arange(K1, dtype=np.int32)[None]
        positions = np.where(alive[:, None], positions, 0) \
            .astype(np.int32)
        lens = np.where(alive[:, None], positions + 1, 1) \
            .reshape(-1).astype(np.int32)
        tables = np.repeat(self.block_tables, K1, axis=0)
        flat_t = jnp.asarray(toks.reshape(1, B * K1))
        flat_p = jnp.asarray(positions.reshape(1, B * K1))
        if self.sampling == "fused":
            out, n_acc, self.cache, self.rng = self._verify(K)(
                self.params, self.cache, flat_t, flat_p,
                jnp.asarray(tables), jnp.asarray(lens),
                jnp.asarray(draft), self.rng, jnp.asarray(temps),
                jnp.asarray(top_ks), jnp.asarray(top_ps),
                jnp.asarray(alive))
            out = np.asarray(out)
            n_acc = np.asarray(n_acc)
            self.stats.host_syncs += 1
            self.stats.bytes_to_host += out.nbytes + n_acc.nbytes
        else:
            rows, self.cache = self._verify(K)(
                self.params, self.cache, flat_t, flat_p,
                jnp.asarray(tables), jnp.asarray(lens))
            rows_np = np.asarray(rows).reshape(B, K1, -1)
            self.stats.host_syncs += 1
            self.stats.bytes_to_host += rows_np.nbytes
            stoch = alive & (temps > 0.0)
            self.rng, keys = split_spec_rng_chain(
                self.rng, jnp.asarray(stoch), K1)
            out = np.zeros((B, K1), np.int32)
            n_acc = np.zeros((B,), np.int32)
            for slot in range(B):
                if not alive[slot]:
                    continue
                o, n = spec_verify_rows(
                    jnp.asarray(rows_np[slot]),
                    jnp.asarray(draft[slot]), keys[slot],
                    jnp.float32(temps[slot]), jnp.int32(top_ks[slot]),
                    jnp.float32(top_ps[slot]))
                out[slot] = np.asarray(o)
                n_acc[slot] = int(n)
        finished: List[Request] = []
        self.stats.steps += 1
        self.stats.spec_rounds += 1
        self.stats.slot_steps += self.slots
        for slot, seq in enumerate(self.sched.active):
            if seq is None or seq.prefilling:
                continue
            req = seq.req
            self.stats.busy_slot_steps += 1
            n = int(n_acc[slot])
            self.stats.draft_tokens += int(real[slot])
            self.stats.accepted_tokens += min(n, int(real[slot]))
            emit = [int(t) for t in out[slot, :n + 1]]
            for j, tok in enumerate(emit):
                self.stats.tokens += 1
                req.out.append(tok)
                seq.pos += 1
                seq.last_token = tok
                if req.stream_cb:
                    req.stream_cb(req.rid, tok)
                if self._should_finish(seq, tok):
                    self.stats.overrun_tokens += len(emit) - j - 1
                    finished.append(self._finish(seq))
                    break
        return finished

    def drain(self) -> Dict[int, List[int]]:
        """Step until the queue and all slots are empty; returns
        {rid: generated tokens} finished since the last drain.

        Results are handed off exactly once (the buffer is cleared), so a
        long-running submit/step/drain server does not accumulate every
        request it ever served.
        """
        while self.sched.has_work():
            self.step()
        self.stats.prefill_traces = len(self._buckets_traced)
        out, self._results = self._results, {}
        return out

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32,
                 params: Optional[SamplingParams] = None,
                 stream_cb: Optional[StreamCB] = None) -> List[List[int]]:
        """HF-like entry point: batch of prompts -> generated ids."""
        rids = [self.submit(list(p), max_new_tokens, params,
                            stream_cb=stream_cb) for p in prompts]
        results = self.drain()
        return [results[r] for r in rids]

    # -- monitoring ----------------------------------------------------

    def kv_cache_bytes(self) -> int:
        """Bytes held by the KV cache (block pool or dense slot cache)."""
        return cache_bytes(self.cache)

    def per_rank_kv_bytes(self) -> int:
        """KV bytes resident on ONE ring rank (heads shard 1/tp)."""
        return self.kv_cache_bytes() // self.tp

    def pending_load(self) -> int:
        """Outstanding tokens (queued + active) — the router's signal."""
        return self.sched.pending_tokens()

    def kv_bytes_moved_per_step(self) -> int:
        """Analytic KV bytes MOVED through HBM per decode step (all ranks).

        * dense / streamed-paged: attention reads each resident KV tile
          exactly once (``V`` = the table-span view bytes); nothing is
          copied.
        * gather-paged: the contiguous per-request view is materialized
          first — read the pool span, write the view, then attention
          reads the view back: ``3 * V``.  This is the O(resident-tokens)
          copy per layer per token the streamed kernel removes.

        A quantized pool streams at the quantized byte width PLUS its
        scale side-array (one scale per (row, kv head)): per (position,
        head) that is ``d_head * store_itemsize + scale_itemsize``
        bytes instead of ``d_head * fp_itemsize`` — the bandwidth half
        of the tentpole's claim (the accuracy half is serving_bench's
        drift gate).
        """
        a = self.plan.attn
        row = self.kv_prec.bytes_per_row_head(a.d_head)
        v = 2 * self.cfg.n_layers * self.slots * self.table_len \
            * self.block_size * a.gp * row
        return 3 * v if self.paged_kernel == "gather" else v

    def dense_equiv_bytes(self) -> int:
        """Bytes a dense (slots, max_seq) cache of this model would take."""
        if not self.paged:
            return self.kv_cache_bytes()
        per_tok = self.kv_cache_bytes() // (self.num_blocks
                                            * self.block_size)
        return per_tok * self.slots * self.max_seq

    def decode_block_s(self) -> int:
        """KV stream tile of the decode program actually dispatched: the
        pool block size when streaming off the pool, else the flash
        chunk (the ``block_s`` override or its 2048 default, clamped to
        the resident span)."""
        if self.paged and self.paged_kernel == "stream":
            return self.block_size
        return min(self.block_s or 2048, self.max_seq)

    def planned_block_s(self) -> int:
        """What :func:`plan_block_s` recommends for this span — the
        reference a real-hardware ``--block-s`` sweep tunes against
        (ROADMAP: tune the streamed kernel's block size on TPU)."""
        a = self.plan.attn
        gs = max(a.hp // max(a.gp, 1), 1) if a is not None else 1
        dh = a.d_head if a is not None else LANE
        return plan_block_s(self.max_seq, dh, gs, self.kv_prec.itemsize)

    def lower_decode_text(self) -> str:
        """MLIR of the decode program this engine will actually run (the
        fused 1-step window, or the host-sampled logits step) — the
        bench's MEASURED no-copy gate greps this text for per-request
        view tensors instead of trusting the analytic byte formula."""
        tables = (jnp.asarray(self.block_tables) if self.paged else None)
        if self.sampling != "fused":
            toks = jnp.zeros((self.slots, 1), jnp.int32)
            pos = jnp.zeros((self.slots,), jnp.int32)
            return self._decode.lower(self.params, self.cache, toks, pos,
                                      tables).as_text()
        carry, samp = self._slot_state()
        return self._window(1).lower(self.params, self.cache, tables,
                                     *carry, self.rng, *samp,
                                     np.bool_(False)).as_text()


class MultiRingEngine:
    """C3 multi-tenant serving: one :class:`LPUEngine` per ESL sub-ring.

    The paper's router splits an 8-LPU ring into 2x4 / 4x2 sub-rings so
    several request streams are served concurrently with no cross-ring
    interference.  Here the ``model`` axis of ``mesh`` is carved by
    :func:`repro.core.rings.submeshes` into ``total // ring_size``
    disjoint sub-meshes; each gets an independent ring-parallel engine
    (its own weight replica, KV pool and scheduler), so no collective of
    one ring can involve another ring's devices — the paper's isolation
    property by construction.

    ``model`` must be built with a plan for ONE sub-ring (mesh axes
    ``("model",)``, shape ``(ring_size,)``); the same plan serves every
    ring because the sub-meshes are congruent.  Admission is per-ring:
    :class:`repro.serving.scheduler.RingRouter` sends each request to
    the ring with the fewest outstanding tokens.

    Concurrency caveat: isolation is the paper's property reproduced
    here; *wall-clock* concurrency is not.  ``step()`` dispatches the
    rings sequentially from one host thread, and each engine's step
    blocks on its host-side sampling sync — a real deployment runs one
    driver per sub-ring.  Throughput accounting must therefore use
    total tokens over fleet wall time, never the sum of per-ring rates
    (see ``benchmarks/serving_bench.py``).

    Fault tolerance (docs/serving.md §Fault tolerance): ``step()``
    supervises the rings.  A :class:`repro.serving.ft.RingFailure`
    raised by any engine (chaos-injected or detected by the NaN guard)
    — or a ring that stops making progress past the heartbeat timeout —
    triggers the recovery cycle: drain the ring
    (:meth:`LPUEngine.reset` returns its orphaned requests and rebuilds
    the KV pool / prefix cache / scheduler from scratch), migrate the
    orphans to surviving rings through the recompute-resume path
    (``Request.resume_tokens``), and return the rebuilt ring to
    rotation (:meth:`HeartbeatTracker.revive`).  Migrations are bounded
    by ``EngineConfig.max_migrations``; a request that exhausts them
    surfaces ``failed=True`` + ``error`` instead of crashing the fleet.

    Host-fleet mode (``mesh=None, rings=N``) builds N single-device
    engines over the same host backend — no ring parallelism, but the
    full supervision/recovery machinery, which is how the chaos tests
    and serving_bench exercise it without a multi-device mesh.
    """

    def __init__(self, model, params, mesh=None, *, ring_size: int = 0,
                 rings: int = 0, config: Optional[EngineConfig] = None,
                 clock: Optional[Callable[[], float]] = None,
                 step_prior_s: float = 0.0, **engine_kw):
        if mesh is not None:
            if ring_size < 1:
                raise ValueError("mesh fleets need ring_size >= 1")
            total = mesh.devices.shape[-1]
            self.ring_cfg = reconfigure(total, ring_size)
            assert self.ring_cfg.validate_disjoint()
            assert model.plan.tp == ring_size, \
                (f"model planned for tp={model.plan.tp}, "
                 f"ring_size={ring_size}")
            self.engines = [LPUEngine(model, params, config, mesh=sub,
                                      **engine_kw)
                            for sub in submeshes(mesh, ring_size)]
        else:
            if rings < 1:
                raise ValueError("host fleets need rings >= 1")
            if model.plan.mesh_axes is not None:
                raise ValueError(
                    "host-fleet mode needs a mesh-free plan "
                    f"(got mesh_axes={model.plan.mesh_axes})")
            self.ring_cfg = None
            self.engines = [LPUEngine(model, params, config, **engine_kw)
                            for _ in range(rings)]
        for i, eng in enumerate(self.engines):
            eng.ring_id = i
        self.router = RingRouter(len(self.engines))
        self.ring_of: Dict[int, int] = {}
        self._rid = 0
        # -- supervision state (see class docstring) -------------------
        c = self.engines[0].config
        self.max_migrations = c.max_migrations
        # "prefix": probe every ring's PrefixCache at submit and prefer
        # the deepest owner of the prompt's block chain (see RingRouter)
        self.affinity = c.affinity
        chaotic = any(e.injector is not None for e in self.engines)
        # chaos runs default to a virtual clock (1 fleet round = 1 s)
        # so heartbeat timeouts are step-deterministic, never wall time
        self._clock = clock or (ManualClock() if chaotic else time.time)
        self.round_dt = 1.0
        self.hb = HeartbeatTracker(len(self.engines),
                                   timeout_s=c.heartbeat_timeout_s,
                                   clock=self._clock)
        self.monitors = [StragglerMonitor(mu0=step_prior_s or None)
                         for _ in self.engines]
        self.ft_straggler_drain = c.ft_straggler_drain
        self.events: List[Event] = []
        self._migrations: Dict[int, int] = {}   # rid -> resubmit count
        self.failed: Dict[int, Request] = {}    # rid -> failed request
        self._round = 0

    @property
    def n_rings(self) -> int:
        return len(self.engines)

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               params: Optional[SamplingParams] = None,
               stream_cb: Optional[StreamCB] = None) -> int:
        """Route to the prefix-owning ring (``affinity="prefix"``) or
        the least-loaded sub-ring; returns a global rid."""
        prompt = list(prompt)
        aff = None
        if self.affinity == "prefix":
            aff = [e.prefix.peek(prompt) if e.prefix is not None else 0
                   for e in self.engines]
        ring = self.router.route(
            [e.pending_load() for e in self.engines], affinity=aff)
        req = Request(self._rid, prompt, max_new_tokens,
                      params or SamplingParams(0.0, 0, 1.0),
                      stream_cb=stream_cb)
        self._rid += 1
        self.engines[ring].submit(req)
        self.ring_of[req.rid] = ring
        return req.rid

    def cancel(self, rid: int) -> Optional[Request]:
        """Abort one in-flight request on whichever ring owns it (the
        ``ring_of`` map follows migrations).  Returns the request, or
        None if it already finished / terminally failed."""
        ring = self.ring_of.get(rid)
        if ring is None:
            return None
        return self.engines[ring].cancel(rid)

    def step(self) -> List[Request]:
        """One supervised round on every sub-ring that has work.

        Idle rings heartbeat for free; a working ring beats only when
        its round made progress (finished a step or a prefill, or ran
        out of work), so a wedged ring goes silent and the timeout
        check at the end of the round eventually drains it."""
        self._round += 1
        done: List[Request] = []
        for i, eng in enumerate(self.engines):
            if not eng.sched.has_work():
                self.hb.beat(i)
                continue
            before = eng.stats.steps + eng.stats.prefills
            t0 = time.perf_counter()
            try:
                done.extend(eng.step())
            except RingFailure as f:
                done.extend(
                    self._on_ring_failure(i, f.reason, dict(f.detail)))
                continue
            ev = self.monitors[i].record(self._round,
                                         time.perf_counter() - t0)
            if ev is not None:
                self.events.append(Event("straggler", self._round,
                                         {"ring": i, **ev.detail}))
                if self.ft_straggler_drain:
                    done.extend(self._on_ring_failure(
                        i, "straggler", dict(ev.detail)))
                    continue
            progressed = (eng.stats.steps + eng.stats.prefills) > before \
                or not eng.sched.has_work()
            if progressed:
                self.hb.beat(i)
        if isinstance(self._clock, ManualClock):
            self._clock.advance(self.round_dt)
        for i in self.hb.check():
            done.extend(self._on_ring_failure(
                i, "heartbeat_timeout",
                {"timeout_s": self.hb.timeout}))
        return done

    def _on_ring_failure(self, i: int, reason: str,
                         detail: dict) -> List[Request]:
        """Drain -> migrate -> rebuild one ring.  Returns the requests
        that exhausted their migration budget (terminally failed)."""
        eng = self.engines[i]
        eng.stats.ring_failures += 1
        self.events.append(Event("ring_failed", self._round,
                                 {"ring": i, "reason": reason, **detail}))
        orphans = eng.reset()
        self.hb.revive(i)
        self.events.append(Event("ring_rebuilt", self._round,
                                 {"ring": i, "orphans": len(orphans)}))
        failed: List[Request] = []
        for req in orphans:
            got = self._migrate(req, i)
            if got is not None:
                failed.append(got)
        return failed

    def _migrate(self, req: Request, source: int) -> Optional[Request]:
        """Resubmit one orphaned request through the recompute-resume
        path, preferring a surviving ring.  Returns the request if its
        retry budget is exhausted (now a structured failure), else
        None."""
        n = self._migrations.get(req.rid, 0)
        if n >= self.max_migrations:
            req.done = True
            req.failed = True
            req.error = (f"retries exhausted: migrated {n}x "
                         f"(max_migrations={self.max_migrations})")
            self.failed[req.rid] = req
            self.events.append(Event("request_failed", self._round,
                                     {"rid": req.rid, "migrations": n}))
            return req
        self._migrations[req.rid] = n + 1
        others = [j for j in range(len(self.engines)) if j != source]
        pool = others or [source]
        ring = min(pool, key=lambda j: (self.engines[j].pending_load(), j))
        tgt = self.engines[ring]
        tgt.submit(req)
        self.ring_of[req.rid] = ring
        tgt.stats.retries += 1
        if ring != source:
            tgt.stats.migrated_requests += 1
        return None

    def has_work(self) -> bool:
        return any(e.sched.has_work() for e in self.engines)

    def drain(self) -> Dict[int, List[int]]:
        while self.has_work():
            self.step()
        out: Dict[int, List[int]] = {}
        for eng in self.engines:
            out.update(eng.drain())
        # failed requests surface their partial streams, same contract
        # as per-engine rejection — callers check Request.failed/error
        # (the fleet keeps the Request itself in ``self.failed``)
        for rid, req in self.failed.items():
            out[rid] = req.out
        return out

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32,
                 params: Optional[SamplingParams] = None,
                 stream_cb: Optional[StreamCB] = None) -> List[List[int]]:
        rids = [self.submit(list(p), max_new_tokens, params,
                            stream_cb=stream_cb) for p in prompts]
        results = self.drain()
        return [results[r] for r in rids]

    def per_ring_stats(self) -> List[EngineStats]:
        return [e.stats for e in self.engines]

    def fleet_counters(self) -> Dict[str, int]:
        """Aggregate FT counters across the fleet (banner + bench)."""
        stats = self.per_ring_stats()
        return {
            "ring_failures": sum(s.ring_failures for s in stats),
            "migrated_requests": sum(s.migrated_requests for s in stats),
            "retries": sum(s.retries for s in stats),
            "rejected_requests": sum(s.rejected_requests for s in stats),
            "failed_requests": len(self.failed),
            "submitted": self._rid,
            "events": len(self.events)
                + sum(len(e.events) for e in self.engines),
        }
