"""HyperDex-style runtime layer: HuggingFace-like generation engine.

``LPUEngine`` mirrors the paper's runtime API surface
(AutoModelForCausalLM-ish): ``generate(prompts, max_new_tokens,
temperature/top_k/top_p, stream_cb)``.  Below the API sits the
slot-based **continuous batching** scheduler (the paper's "batch mode"
future work, implemented here): a fixed decode batch of B slots; new
requests claim free slots at step boundaries, finished sequences
release them mid-flight.  Per-request sampling params are carried per
slot (the paper's per-request control registers).

Monitoring hooks expose tokens/s, slot occupancy and step latency —
the datacenter-level statistics HyperDex exposes from its driver.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dist import make_axis_env
from repro.serving.sampler import SamplingParams, sample_sharded

StreamCB = Callable[[int, int], None]   # (request_id, token)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    params: SamplingParams = SamplingParams()
    out: List[int] = field(default_factory=list)
    done: bool = False
    stream_cb: Optional[StreamCB] = None


@dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    busy_slot_steps: int = 0
    slot_steps: int = 0
    wall: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall if self.wall else 0.0

    @property
    def occupancy(self) -> float:
        return self.busy_slot_steps / max(self.slot_steps, 1)


class LPUEngine:
    """Slot-based continuous-batching decode engine (single host)."""

    def __init__(self, model, params, *, slots: int = 4,
                 max_seq: int = 256, eos_id: Optional[int] = None,
                 rng: Optional[jax.Array] = None):
        self.model = model
        self.cfg = model.cfg
        self.plan = model.plan
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.env = make_axis_env(self.plan, batch=slots)
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.cache = model.init_cache(slots, max_seq)
        self.positions = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.last_token = np.zeros((slots,), np.int32)
        self.stats = EngineStats()
        self._decode = jax.jit(self._decode_fn, static_argnums=(5, 6, 7))
        self._prefill = jax.jit(self._prefill_fn, static_argnums=(3,))

    # -- jitted steps --------------------------------------------------

    def _decode_fn(self, params, cache, tokens, positions, rng, temp, topk,
                   topp):
        logits, new_cache, _ = self.model.forward(
            params, tokens, env=self.env, mode="decode",
            positions=positions, cache=cache)
        sp = SamplingParams(temp, topk, topp)
        nxt = sample_sharded(logits[:, -1], rng, sp, None, 1)
        return nxt, logits[:, -1], new_cache

    def _prefill_fn(self, params, cache, tokens, true_len):
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]),
                                     tokens.shape)
        logits, new_cache, _ = self.model.forward(
            params, tokens, env=self.env, mode="prefill", cache=cache,
            positions=positions)
        return logits[:, true_len - 1], new_cache

    # -- scheduling ------------------------------------------------------

    def _admit(self, queue: List[Request]):
        for s in range(self.slots):
            if self.active[s] is None and queue:
                req = queue.pop(0)
                ptoks = np.asarray(req.prompt, np.int32)[None]
                # prefill this slot (batch=slots: pad others, cheap here)
                full = np.zeros((self.slots, ptoks.shape[1]), np.int32)
                full[s] = ptoks
                logits, cache = self._prefill(self.params, self.cache,
                                              jnp.asarray(full),
                                              int(ptoks.shape[1]))
                self.cache = cache
                self.active[s] = req
                self.positions[s] = len(req.prompt)
                lg = np.asarray(logits[s])
                self.last_token[s] = int(lg.argmax())
                req.out.append(int(self.last_token[s]))
                if req.stream_cb:
                    req.stream_cb(req.rid, int(self.last_token[s]))

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32,
                 params: Optional[SamplingParams] = None,
                 stream_cb: Optional[StreamCB] = None) -> List[List[int]]:
        """HF-like entry point: batch of prompts -> generated ids."""
        params = params or SamplingParams(0.0, 0, 1.0)   # greedy default
        queue = [Request(i, list(p), max_new_tokens, params,
                         stream_cb=stream_cb)
                 for i, p in enumerate(prompts)]
        results: Dict[int, List[int]] = {}
        t0 = time.time()
        while queue or any(a is not None for a in self.active):
            self._admit(queue)
            toks = jnp.asarray(self.last_token[:, None])
            pos = jnp.asarray(self.positions)
            self.rng, sub = jax.random.split(self.rng)
            nxt, logits, self.cache = self._decode(
                self.params, self.cache, toks, pos, sub,
                params.temperature, params.top_k, params.top_p)
            nxt = np.asarray(nxt)
            self.stats.steps += 1
            self.stats.slot_steps += self.slots
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                self.stats.busy_slot_steps += 1
                self.stats.tokens += 1
                tok = int(nxt[s])
                req.out.append(tok)
                self.positions[s] += 1
                self.last_token[s] = tok
                if req.stream_cb:
                    req.stream_cb(req.rid, tok)
                if (len(req.out) >= req.max_new_tokens
                        or (self.eos_id is not None and tok == self.eos_id)
                        or self.positions[s] >= self.max_seq - 1):
                    req.done = True
                    results[req.rid] = req.out
                    self.active[s] = None     # release slot mid-flight
        self.stats.wall = time.time() - t0
        return [results[i] for i in sorted(results)]
