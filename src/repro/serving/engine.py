"""HyperDex-style runtime layer: continuous-batching serving engine.

``LPUEngine`` mirrors the paper's runtime API surface on top and a paged
KV-cache serving stack below; with a mesh it becomes the paper's
*multi-LPU* configuration — the whole prefill/decode path runs inside
one ``shard_map`` over the ``model`` ring so the ESL collectives (C2)
and the paged pool compose:

* **API** — the HF-like blocking ``generate(prompts, ...)`` plus a
  non-blocking ``submit(request) / step() / drain()`` interface for
  continuous serving (the paper's "batch mode" datacenter direction).
* **Scheduler** — a fixed decode batch of B slots; queued requests are
  admitted at step boundaries by :class:`repro.serving.scheduler.
  Scheduler`, finished sequences release their slot (and blocks)
  mid-flight.
* **KV cache** — paged by default for attention-only stacks: a shared
  pool of fixed-size blocks with per-request block tables
  (:mod:`repro.serving.kv_cache`), so the *persistent* cache scales
  with resident tokens instead of slots x max_seq.  Decode **streams**
  KV tiles straight from the pool through the scalar-prefetched paged
  Pallas kernel (``paged_kernel="stream"``, the default wherever the
  stored GQA layout allows): no contiguous per-request view is ever
  materialized.  ``paged_kernel="gather"`` keeps the old
  copy-then-attend path as the reference oracle (bit-trustworthy, 3x
  the KV bytes moved — see :meth:`LPUEngine.kv_bytes_moved_per_step`).
  The dense per-slot cache remains the contiguous fast path
  (``paged=False``, and the automatic fallback for recurrent-state
  families).
* **Prefill** — per-request at batch 1, padded to power-of-two length
  buckets so the prefill jit traces O(log2 max_seq) times instead of
  once per distinct prompt length; the resulting KV is scattered into
  the pool (or the slot's dense region).
* **Preemption** — when the pool is exhausted, the newest sequence is
  evicted and re-prefiled later (recompute), protecting old requests.

**Ring parallelism (C2)** — ``LPUEngine(model, params, mesh=...)`` with
a plan built for the mesh shards weights AND the KV pool over the
``model`` axis (stored kv heads split 1/tp per rank: same block ids on
every rank, 1/tp of the bytes).  Decode and prefill are jitted
``shard_map`` programs whose matmuls stream partial products around the
ICI ring (:mod:`repro.core.esl` ``ag_matmul``/``rs_matmul``); the
engine's host loop — admission, block tables, sampling — is unchanged,
because tables and sampled tokens are replicated ring-wide.  The token
stream matches the single-device engine (tests/test_serving.py).

**Sub-rings (C3)** — :class:`MultiRingEngine` carves the model axis
into ``RingConfig`` sub-rings (:mod:`repro.core.rings`) and runs one
independent ``LPUEngine`` per sub-mesh: disjoint device groups, so no
collective of one tenant can touch another's ring.  Requests are
admitted per-ring by :class:`repro.serving.scheduler.RingRouter`
(least outstanding tokens).

Monitoring hooks expose tokens/s, slot occupancy, prefill trace count,
preemptions and KV bytes (total and per rank) — the datacenter-level
statistics HyperDex exposes from its driver.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.core.dist import make_axis_env
from repro.core.rings import reconfigure, submeshes
from repro.kernels.decode_attention.ops import resolve_paged_kernel
from repro.serving.kv_cache import (LANE, BlockPool, cache_bytes,
                                    per_rank_block_bytes,
                                    pool_blocks_for_budget,
                                    scatter_prefill_dense,
                                    scatter_prefill_pages)
from repro.serving.sampler import SamplingParams, sample_local
from repro.serving.scheduler import RingRouter, Scheduler, SeqSlot

StreamCB = Callable[[int, int], None]   # (request_id, token)


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    params: SamplingParams = SamplingParams()
    out: List[int] = field(default_factory=list)
    done: bool = False
    stream_cb: Optional[StreamCB] = None

    def resume_tokens(self) -> List[int]:
        """Tokens whose KV must be resident before decoding continues.

        Fresh request: the prompt.  After preemption the generated tokens
        ride along — all but the last (which has been sampled, not yet
        fed through the model) are re-prefiled.
        """
        if not self.out:
            return list(self.prompt)
        return list(self.prompt) + list(self.out[:-1])


@dataclass
class EngineStats:
    steps: int = 0
    tokens: int = 0
    busy_slot_steps: int = 0
    slot_steps: int = 0
    wall: float = 0.0
    preemptions: int = 0
    prefill_traces: int = 0       # distinct prefill buckets traced
    prefills: int = 0             # total prefill launches (incl. resume)
    peak_pool_blocks: int = 0     # high-water block-pool occupancy

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall if self.wall else 0.0

    @property
    def occupancy(self) -> float:
        return self.busy_slot_steps / max(self.slot_steps, 1)


class LPUEngine:
    """Slot-based continuous-batching decode engine (single host).

    ``mesh=None`` is the single-device smoke configuration.  With a
    1-axis ``model`` mesh (and a plan built for it) the engine runs its
    jitted steps inside ``shard_map`` over the ring — weights, the KV
    pool and the prefill caches are placed with the mapper's
    PartitionSpecs; block tables, positions and sampled tokens stay
    replicated host state, identical to the single-device loop.
    """

    def __init__(self, model, params, *, slots: int = 4,
                 max_seq: int = 256, eos_id: Optional[int] = None,
                 rng: Optional[jax.Array] = None,
                 paged: Optional[bool] = None, block_size: int = 0,
                 num_blocks: int = 0, min_bucket: int = 16,
                 mesh=None, kv_budget_bytes: int = 0,
                 paged_kernel: str = "auto"):
        self.model = model
        self.cfg = model.cfg
        self.plan = model.plan
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.mesh = mesh
        if mesh is not None:
            assert self.plan.mesh_axes is not None and \
                tuple(mesh.axis_names) == tuple(self.plan.mesh_axes) and \
                tuple(mesh.devices.shape) == tuple(self.plan.mesh_shape), \
                (f"plan built for {self.plan.mesh_axes}"
                 f"{self.plan.mesh_shape} but engine mesh is "
                 f"{mesh.axis_names}{mesh.devices.shape}")
        self.tp = self.plan.tp if mesh is not None else 1
        self.env = make_axis_env(self.plan, batch=slots)
        self.env1 = make_axis_env(self.plan, batch=1)
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)

        if paged is None:
            paged = model.supports_paged_kv()
        self.paged = paged
        if paged_kernel not in ("auto", "stream", "gather"):
            raise ValueError(f"paged_kernel={paged_kernel!r} not in "
                             "('auto', 'stream', 'gather')")
        # pow2 prefill buckets pad the prompt with token 0; attention
        # masks padded KV by valid length, but recurrent state (mamba /
        # rwkv) folds every position in — those families prefill at the
        # exact prompt length (one trace per distinct length, as before)
        self.bucketed = model.supports_paged_kv()
        if paged:
            self.block_size = block_size or min(LANE, max_seq)
            assert max_seq % self.block_size == 0, \
                (max_seq, self.block_size)
            self.table_len = max_seq // self.block_size
            if not num_blocks and kv_budget_bytes:
                # size the pool from the per-rank HBM budget: heads are
                # sharded over the ring, so a tp-ring stretches the same
                # budget to tp x the resident tokens
                a = self.plan.attn
                num_blocks = pool_blocks_for_budget(
                    kv_budget_bytes,
                    per_rank_block_bytes(
                        self.cfg.n_layers, a.kv_per_rank, a.d_head,
                        self.block_size,
                        jnp.dtype(self.plan.cache_dtype).itemsize))
            # default pool: dense-equivalent capacity + the null block
            self.num_blocks = num_blocks or (slots * self.table_len + 1)
            pool = BlockPool(self.num_blocks, self.block_size)
            self.cache = model.init_cache(
                slots, max_seq, paged=True, num_blocks=self.num_blocks,
                block_size=self.block_size)
            self.block_tables = np.zeros((slots, self.table_len), np.int32)
        else:
            self.block_size = max_seq
            self.table_len = 1
            self.num_blocks = slots
            pool = None
            self.cache = model.init_cache(slots, max_seq)
            self.block_tables = None
        # paged decode dataflow: "stream" runs the Pallas paged kernel
        # straight off the pool (scalar-prefetched block table, no
        # contiguous per-request copy); "gather" keeps the materialized
        # (B, T*bs) view as the reference oracle; "auto" streams
        # whenever the stored GQA layout (and, compiled on TPU, the
        # tile alignment) allows it.  Resolved AFTER block_size so the
        # choice — and the kv_bytes_moved accounting keyed off it — is
        # what the decode program will actually execute.
        self.paged_kernel = (resolve_paged_kernel(
            self.plan, self.block_size, paged_kernel) if self.paged
            else None)
        self.sched = Scheduler(slots, max_seq, pool, min_bucket)
        self.stats = EngineStats()
        self._results: Dict[int, List[int]] = {}
        self._rid = 0
        self._buckets_traced: Set[int] = set()
        if mesh is None:
            self._decode = jax.jit(self._decode_fn)
            self._prefill = jax.jit(self._prefill_fn)
            self._write_pages = jax.jit(scatter_prefill_pages)
            self._write_dense = jax.jit(scatter_prefill_dense)
        else:
            self._build_mesh_fns()

    # -- jitted steps --------------------------------------------------

    def _decode_fn(self, params, cache, tokens, positions, tables):
        logits, new_cache, _ = self.model.forward(
            params, tokens, env=self.env, mode="decode",
            positions=positions, cache=cache, block_tables=tables,
            paged_kernel=self.paged_kernel or "gather")
        return logits[:, -1], new_cache

    def _prefill_fn(self, params, tokens, true_len):
        """Batch-1 prefill of a bucket-padded prompt.

        Traced once per bucket size (``tokens.shape[1]``); ``true_len``
        is dynamic so distinct prompt lengths inside one bucket share
        the trace.  Returns (last-valid-token logits row, filled cache).
        """
        B, S = tokens.shape
        cache = self.model.init_cache(1, S)
        positions = jnp.broadcast_to(jnp.arange(S), (1, S))
        logits, new_cache, _ = self.model.forward(
            params, tokens, env=self.env1, mode="prefill", cache=cache,
            positions=positions)
        row = lax.dynamic_index_in_dim(logits[0], true_len - 1, 0,
                                       keepdims=False)
        return row, new_cache

    # -- ring-parallel (shard_map) step construction -------------------

    def _named(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            spec_tree, is_leaf=lambda x: isinstance(x, P))

    def _build_mesh_fns(self) -> None:
        """shard_map-wrapped decode/prefill over the model ring.

        Everything the host loop touches stays replicated (tokens,
        positions, block tables in; the sampled-from logits row out, so
        sampling happens once, ring-consistent).  Weights, the KV pool
        and prefill caches live sharded per the mapper's specs; inside
        the program every matmul is an ESL collective matmul.
        """
        mesh, m = self.mesh, self.plan.tp_axis
        specs, _ = self.model.param_specs()
        self.params = jax.device_put(self.params, self._named(specs))
        cspecs = self.model.cache_specs(self.env, paged=self.paged)
        cspecs_named = self._named(cspecs)
        self.cache = jax.device_put(self.cache, cspecs_named)
        pf_cspecs = self.model.cache_specs(self.env1)
        self._pf_named = self._named(pf_cspecs)
        self._pf_zero: Dict[int, object] = {}   # bucket -> zeroed cache

        if self.paged:
            def dec(params, cache, tokens, positions, tables):
                return self._decode_fn(params, cache, tokens, positions,
                                       tables)
            dec_sm = jax.jit(shard_map(
                dec, mesh=mesh,
                in_specs=(specs, cspecs, P(None, None), P(None),
                          P(None, None)),
                out_specs=(P(None, m), cspecs), check_vma=False))
            self._decode = dec_sm
        else:
            def dec_d(params, cache, tokens, positions):
                return self._decode_fn(params, cache, tokens, positions,
                                       None)
            dec_sm = jax.jit(shard_map(
                dec_d, mesh=mesh,
                in_specs=(specs, cspecs, P(None, None), P(None)),
                out_specs=(P(None, m), cspecs), check_vma=False))
            self._decode = lambda p, c, t, pos, tables: dec_sm(p, c, t, pos)

        def pre(params, cache0, tokens, true_len):
            B, S = tokens.shape
            positions = jnp.broadcast_to(jnp.arange(S), (1, S))
            logits, new_cache, _ = self.model.forward(
                params, tokens, env=self.env1, mode="prefill",
                cache=cache0, positions=positions)
            row = lax.dynamic_index_in_dim(logits[0], true_len - 1, 0,
                                           keepdims=False)
            return row, new_cache

        pre_sm = jax.jit(shard_map(
            pre, mesh=mesh,
            in_specs=(specs, pf_cspecs, P(None, None), P()),
            out_specs=(P(m), pf_cspecs), check_vma=False))

        def prefill(params, tokens, true_len):
            # the bucket cache is an INPUT here (the single-device path
            # allocates it inside the jit): shard_map needs it placed
            # with the mapper's specs, and prefill overwrites the whole
            # [0:S) prefix, so one zeroed buffer per bucket is reusable
            S = int(tokens.shape[1])
            if S not in self._pf_zero:
                self._pf_zero[S] = jax.device_put(
                    self.model.init_cache(1, S), self._pf_named)
            return pre_sm(params, self._pf_zero[S], tokens, true_len)

        self._prefill = prefill
        self._write_pages = jax.jit(scatter_prefill_pages,
                                    out_shardings=cspecs_named)
        self._write_dense = jax.jit(scatter_prefill_dense,
                                    out_shardings=cspecs_named)

    # -- sampling ------------------------------------------------------

    def _sample(self, logits_np: np.ndarray, logits_dev,
                params: SamplingParams) -> int:
        if params.temperature <= 0.0:
            return int(np.argmax(logits_np))
        self.rng, sub = jax.random.split(self.rng)
        return int(sample_local(logits_dev[None], sub, params)[0])

    # -- prefill + admission -------------------------------------------

    def _refresh_tables(self) -> None:
        if not self.paged:
            return
        self.block_tables[:] = 0
        for slot, seq in enumerate(self.sched.active):
            if seq is not None and seq.blocks:
                self.block_tables[slot, :len(seq.blocks)] = seq.blocks

    def _should_finish(self, seq: SeqSlot, tok: int) -> bool:
        req = seq.req
        return (len(req.out) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id)
                or seq.pos >= self.max_seq - 1)

    def _finish(self, seq: SeqSlot) -> Request:
        req = seq.req
        req.done = True
        self._results[req.rid] = req.out
        self.sched.release(seq)
        return req

    def _do_prefill(self, seq: SeqSlot) -> Optional[Request]:
        """Run bucketed prefill for a just-admitted sequence; returns the
        request if it finished immediately (eos / max_new_tokens == 1)."""
        req = seq.req
        tokens = req.resume_tokens()
        bucket = (self.sched.bucket(len(tokens)) if self.bucketed
                  else len(tokens))
        buf = np.zeros((1, bucket), np.int32)
        buf[0, :len(tokens)] = tokens
        row, pc = self._prefill(self.params, jnp.asarray(buf),
                                jnp.int32(len(tokens)))
        self._buckets_traced.add(bucket)
        self.stats.prefills += 1
        slot = self.sched.slot_of(seq)
        if self.paged:
            table = np.zeros((bucket // self.block_size,), np.int32)
            table[:len(seq.blocks)] = seq.blocks
            self.cache = self._write_pages(self.cache, pc,
                                           jnp.asarray(table))
        else:
            self.cache = self._write_dense(self.cache, pc, jnp.int32(slot))
        if seq.resumed:
            seq.last_token = req.out[-1]
            return None
        row_np = np.asarray(row)
        tok = self._sample(row_np, row, req.params)
        req.out.append(tok)
        seq.last_token = tok
        if req.stream_cb:
            req.stream_cb(req.rid, tok)
        if self._should_finish(seq, tok):
            return self._finish(seq)
        return None

    # -- public API ----------------------------------------------------

    def submit(self, prompt: Union[Request, Sequence[int]],
               max_new_tokens: int = 32,
               params: Optional[SamplingParams] = None,
               stream_cb: Optional[StreamCB] = None) -> int:
        """Enqueue a request (non-blocking).  Returns its request id."""
        if isinstance(prompt, Request):
            req = prompt
        else:
            req = Request(self._rid, list(prompt), max_new_tokens,
                          params or SamplingParams(0.0, 0, 1.0),
                          stream_cb=stream_cb)
        if not req.prompt:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"prompt length {len(req.prompt)} >= max_seq "
                f"{self.max_seq}: no room to decode")
        self._rid = max(self._rid, req.rid) + 1
        self.sched.submit(req)
        return req.rid

    def step(self) -> List[Request]:
        """One scheduler round: admit + prefill, then one decode step for
        the whole slot batch.  Returns requests finished this round."""
        t0 = time.time()
        try:
            return self._step()
        finally:
            self.stats.wall += time.time() - t0

    def _step(self) -> List[Request]:
        finished: List[Request] = []
        while True:
            seq = self.sched.admit_next()
            if seq is None:
                break
            done = self._do_prefill(seq)
            if done is not None:
                finished.append(done)
        self.sched.ensure_decode_capacity()     # may preempt (recompute)
        self.stats.preemptions = self.sched.preemptions
        if self.sched.pool is not None:
            self.stats.peak_pool_blocks = max(self.stats.peak_pool_blocks,
                                              self.sched.pool.num_used)
        if self.sched.num_active() == 0:
            return finished
        self._refresh_tables()

        toks = np.zeros((self.slots, 1), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        for slot, seq in enumerate(self.sched.active):
            if seq is not None:
                toks[slot, 0] = seq.last_token
                pos[slot] = seq.pos
        tables = (jnp.asarray(self.block_tables) if self.paged else None)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            tables)
        logits_np = np.asarray(logits)

        self.stats.steps += 1
        self.stats.slot_steps += self.slots
        for slot, seq in enumerate(self.sched.active):
            if seq is None:
                continue
            req = seq.req
            self.stats.busy_slot_steps += 1
            self.stats.tokens += 1
            tok = self._sample(logits_np[slot], logits[slot], req.params)
            req.out.append(tok)
            seq.pos += 1
            seq.last_token = tok
            if req.stream_cb:
                req.stream_cb(req.rid, tok)
            if self._should_finish(seq, tok):
                finished.append(self._finish(seq))
        self.stats.prefill_traces = len(self._buckets_traced)
        return finished

    def drain(self) -> Dict[int, List[int]]:
        """Step until the queue and all slots are empty; returns
        {rid: generated tokens} finished since the last drain.

        Results are handed off exactly once (the buffer is cleared), so a
        long-running submit/step/drain server does not accumulate every
        request it ever served.
        """
        while self.sched.has_work():
            self.step()
        self.stats.prefill_traces = len(self._buckets_traced)
        out, self._results = self._results, {}
        return out

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32,
                 params: Optional[SamplingParams] = None,
                 stream_cb: Optional[StreamCB] = None) -> List[List[int]]:
        """HF-like entry point: batch of prompts -> generated ids."""
        rids = [self.submit(list(p), max_new_tokens, params,
                            stream_cb=stream_cb) for p in prompts]
        results = self.drain()
        return [results[r] for r in rids]

    # -- monitoring ----------------------------------------------------

    def kv_cache_bytes(self) -> int:
        """Bytes held by the KV cache (block pool or dense slot cache)."""
        return cache_bytes(self.cache)

    def per_rank_kv_bytes(self) -> int:
        """KV bytes resident on ONE ring rank (heads shard 1/tp)."""
        return self.kv_cache_bytes() // self.tp

    def pending_load(self) -> int:
        """Outstanding tokens (queued + active) — the router's signal."""
        return self.sched.pending_tokens()

    def kv_bytes_moved_per_step(self) -> int:
        """Analytic KV bytes MOVED through HBM per decode step (all ranks).

        * dense / streamed-paged: attention reads each resident KV tile
          exactly once (``V`` = the table-span view bytes); nothing is
          copied.
        * gather-paged: the contiguous per-request view is materialized
          first — read the pool span, write the view, then attention
          reads the view back: ``3 * V``.  This is the O(resident-tokens)
          copy per layer per token the streamed kernel removes.
        """
        a = self.plan.attn
        itemsize = jnp.dtype(self.plan.cache_dtype).itemsize
        v = 2 * self.cfg.n_layers * self.slots * self.table_len \
            * self.block_size * a.gp * a.d_head * itemsize
        return 3 * v if self.paged_kernel == "gather" else v

    def dense_equiv_bytes(self) -> int:
        """Bytes a dense (slots, max_seq) cache of this model would take."""
        if not self.paged:
            return self.kv_cache_bytes()
        per_tok = self.kv_cache_bytes() // (self.num_blocks
                                            * self.block_size)
        return per_tok * self.slots * self.max_seq


class MultiRingEngine:
    """C3 multi-tenant serving: one :class:`LPUEngine` per ESL sub-ring.

    The paper's router splits an 8-LPU ring into 2x4 / 4x2 sub-rings so
    several request streams are served concurrently with no cross-ring
    interference.  Here the ``model`` axis of ``mesh`` is carved by
    :func:`repro.core.rings.submeshes` into ``total // ring_size``
    disjoint sub-meshes; each gets an independent ring-parallel engine
    (its own weight replica, KV pool and scheduler), so no collective of
    one ring can involve another ring's devices — the paper's isolation
    property by construction.

    ``model`` must be built with a plan for ONE sub-ring (mesh axes
    ``("model",)``, shape ``(ring_size,)``); the same plan serves every
    ring because the sub-meshes are congruent.  Admission is per-ring:
    :class:`repro.serving.scheduler.RingRouter` sends each request to
    the ring with the fewest outstanding tokens.

    Concurrency caveat: isolation is the paper's property reproduced
    here; *wall-clock* concurrency is not.  ``step()`` dispatches the
    rings sequentially from one host thread, and each engine's step
    blocks on its host-side sampling sync — a real deployment runs one
    driver per sub-ring.  Throughput accounting must therefore use
    total tokens over fleet wall time, never the sum of per-ring rates
    (see ``benchmarks/serving_bench.py``).
    """

    def __init__(self, model, params, mesh, *, ring_size: int,
                 **engine_kw):
        total = mesh.devices.shape[-1]
        self.ring_cfg = reconfigure(total, ring_size)
        assert self.ring_cfg.validate_disjoint()
        assert model.plan.tp == ring_size, \
            (f"model planned for tp={model.plan.tp}, "
             f"ring_size={ring_size}")
        self.engines = [LPUEngine(model, params, mesh=sub, **engine_kw)
                        for sub in submeshes(mesh, ring_size)]
        self.router = RingRouter(len(self.engines))
        self.ring_of: Dict[int, int] = {}
        self._rid = 0

    @property
    def n_rings(self) -> int:
        return len(self.engines)

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               params: Optional[SamplingParams] = None,
               stream_cb: Optional[StreamCB] = None) -> int:
        """Route to the least-loaded sub-ring; returns a global rid."""
        ring = self.router.route([e.pending_load() for e in self.engines])
        req = Request(self._rid, list(prompt), max_new_tokens,
                      params or SamplingParams(0.0, 0, 1.0),
                      stream_cb=stream_cb)
        self._rid += 1
        self.engines[ring].submit(req)
        self.ring_of[req.rid] = ring
        return req.rid

    def step(self) -> List[Request]:
        """One round on every sub-ring that has work."""
        done: List[Request] = []
        for eng in self.engines:
            if eng.sched.has_work():
                done.extend(eng.step())
        return done

    def has_work(self) -> bool:
        return any(e.sched.has_work() for e in self.engines)

    def drain(self) -> Dict[int, List[int]]:
        while self.has_work():
            self.step()
        out: Dict[int, List[int]] = {}
        for eng in self.engines:
            out.update(eng.drain())
        return out

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32,
                 params: Optional[SamplingParams] = None,
                 stream_cb: Optional[StreamCB] = None) -> List[List[int]]:
        rids = [self.submit(list(p), max_new_tokens, params,
                            stream_cb=stream_cb) for p in prompts]
        results = self.drain()
        return [results[r] for r in rids]

    def per_ring_stats(self) -> List[EngineStats]:
        return [e.stats for e in self.engines]
