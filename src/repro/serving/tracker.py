"""Pluggable serving telemetry: the ``Tracker`` protocol and sinks.

Every layer of the serving stack — the async frontend, the SLO budget
scheduler, the fleet supervisor — emits structured time-series records
through ONE seam instead of printing banners.  The protocol is
levanter-style: a tracker is anything with ``log(record)`` / ``finish()``;
implementations here are deliberately boring (jsonl file, in-memory ring
buffer, composite fan-out) so tests and CI can consume the stream
without a metrics backend.

Record schema (``validate_record``): every record carries

* ``kind``  — ``"engine_window"`` | ``"request"`` | ``"event"``
* ``t``     — seconds, caller-supplied monotonic clock

plus per-kind required fields:

* ``engine_window`` — ``ring``, ``step``, ``dt_ms`` and a ``delta`` dict
  of **non-negative** EngineStats counter deltas since the previous
  window (see below)
* ``request`` — ``rid``, ``status`` (completed|failed|cancelled|
  rejected), ``tokens``, ``ttft_ms``, ``ms_per_token``
* ``event`` — ``name`` plus free-form detail

EngineStats delta accounting
----------------------------
``EngineStats`` counters are cumulative for the life of the engine —
*including* across ``reset()``/ring rebuilds (the engine banks subsystem
counter bases at reset, so assigned fields like ``preemptions`` and the
prefix counters never regress).  The tracker seam therefore works by
snapshot-and-diff: :class:`EngineTap` keeps the previous snapshot and
emits only the per-window delta, and because the cumulative stream is
monotone every delta is ``>= 0`` and the deltas sum back to the final
cumulative counters (tests/test_tracker.py locks both properties, with
a migration in the middle).
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import fields as dc_fields
from typing import Any, Deque, Dict, Iterable, List, Optional

# EngineStats fields excluded from delta accounting: gauges (high-water
# marks are not flows) and wall (float accumulation, tracked as dt_ms on
# the window record itself).
GAUGE_FIELDS = frozenset({"peak_pool_blocks", "wall"})

KINDS = ("engine_window", "request", "event")
REQUEST_STATUSES = ("completed", "failed", "cancelled", "rejected")
_REQUIRED = {
    "engine_window": ("ring", "step", "dt_ms", "delta"),
    "request": ("rid", "status", "tokens", "ttft_ms", "ms_per_token"),
    "event": ("name",),
}


def validate_record(rec: Dict[str, Any]) -> None:
    """Raise ``ValueError`` unless ``rec`` is schema-valid (see module
    docstring).  The jsonl sink validates on write so a malformed
    record fails the producer, never a downstream dashboard."""
    if not isinstance(rec, dict):
        raise ValueError(f"record must be a dict, got {type(rec).__name__}")
    kind = rec.get("kind")
    if kind not in KINDS:
        raise ValueError(f"record kind={kind!r} not in {KINDS}")
    t = rec.get("t")
    if not isinstance(t, (int, float)) or t != t:  # NaN guard
        raise ValueError(f"record t={t!r} must be a finite number")
    missing = [k for k in _REQUIRED[kind] if k not in rec]
    if missing:
        raise ValueError(f"{kind} record missing fields {missing}")
    if kind == "engine_window":
        delta = rec["delta"]
        if not isinstance(delta, dict):
            raise ValueError("engine_window delta must be a dict")
        neg = {k: v for k, v in delta.items() if v < 0}
        if neg:
            raise ValueError(
                f"engine_window delta went negative: {neg} — cumulative "
                "EngineStats regressed (reset() base accounting broken?)")
        if rec["dt_ms"] < 0:
            raise ValueError(f"dt_ms={rec['dt_ms']} must be >= 0")
    elif kind == "request":
        if rec["status"] not in REQUEST_STATUSES:
            raise ValueError(f"request status={rec['status']!r} not in "
                             f"{REQUEST_STATUSES}")
        if rec["tokens"] < 0:
            raise ValueError(f"tokens={rec['tokens']} must be >= 0")


class Tracker:
    """Protocol base: ``log`` one record, ``finish`` flushes/closes.

    Subclass and override; the base is a null sink so a tracker-less
    frontend can unconditionally call through it.
    """

    def log(self, rec: Dict[str, Any]) -> None:  # pragma: no cover
        pass

    def finish(self) -> None:
        pass

    # context-manager sugar so ``with JsonlTracker(p) as tr:`` closes
    def __enter__(self) -> "Tracker":
        return self

    def __exit__(self, *exc) -> None:
        self.finish()


class NullTracker(Tracker):
    """Explicit no-op sink (the default when telemetry is off)."""


class JsonlTracker(Tracker):
    """One schema-validated JSON object per line, append-only.

    The file format CI's tail-latency-smoke leg uploads as an artifact;
    ``read_jsonl`` round-trips it.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._fh = open(self.path, "w", encoding="utf-8")
        self.written = 0

    def log(self, rec: Dict[str, Any]) -> None:
        validate_record(rec)
        self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
        self.written += 1

    def finish(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load and re-validate a jsonl tracker file (tests + CI gate)."""
    out: List[Dict[str, Any]] = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            validate_record(rec)
            out.append(rec)
    return out


class RingBufferTracker(Tracker):
    """Keep the last ``capacity`` records in memory — the live-dashboard
    sink (a serve banner or test asserts over a bounded recent window,
    never an unbounded history)."""

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self.capacity = capacity
        self._buf: Deque[Dict[str, Any]] = deque(maxlen=capacity)
        self.seen = 0                  # total logged, incl. overwritten

    def log(self, rec: Dict[str, Any]) -> None:
        validate_record(rec)
        self._buf.append(rec)
        self.seen += 1

    def records(self) -> List[Dict[str, Any]]:
        return list(self._buf)

    def window(self, n: int) -> List[Dict[str, Any]]:
        """The most recent ``min(n, len)`` records, oldest first."""
        if n < 0:
            raise ValueError(f"n={n} must be >= 0")
        buf = list(self._buf)
        return buf[len(buf) - min(n, len(buf)):]


class CompositeTracker(Tracker):
    """Fan one stream out to several sinks (jsonl artifact + in-memory
    window is the usual pair)."""

    def __init__(self, trackers: Iterable[Tracker]):
        self.trackers = list(trackers)

    def log(self, rec: Dict[str, Any]) -> None:
        for tr in self.trackers:
            tr.log(rec)

    def finish(self) -> None:
        for tr in self.trackers:
            tr.finish()


# ---------------------------------------------------------------------------
# EngineStats snapshot / delta (the tracker seam)
# ---------------------------------------------------------------------------

def counter_fields(stats) -> List[str]:
    """The monotone counter fields of an ``EngineStats`` (everything but
    the gauges) — derived from the dataclass so a new counter is picked
    up by telemetry without touching this module."""
    return [f.name for f in dc_fields(stats) if f.name not in GAUGE_FIELDS]


def snapshot_stats(stats) -> Dict[str, int]:
    """Copy the cumulative counters out of an ``EngineStats``."""
    return {k: getattr(stats, k) for k in counter_fields(stats)}


def stats_delta(prev: Dict[str, int], cur: Dict[str, int]) -> Dict[str, int]:
    """Per-window counter flow between two snapshots.  Raises if any
    counter regressed — cumulative EngineStats are monotone by contract
    (the engine banks subsystem bases across ``reset()``), so a negative
    delta is a bug upstream, never something to clamp away silently."""
    d = {k: cur[k] - prev.get(k, 0) for k in cur}
    neg = {k: v for k, v in d.items() if v < 0}
    if neg:
        raise ValueError(f"EngineStats counters regressed: {neg}")
    return d


class EngineTap:
    """Snapshot-and-diff adapter from one engine's ``EngineStats`` to
    ``engine_window`` records.  Quiet windows (all-zero delta) are
    skipped so an idle fleet does not flood the sink."""

    def __init__(self, engine, ring: int = 0):
        self.engine = engine
        self.ring = ring
        self._prev = snapshot_stats(engine.stats)
        self.windows = 0

    def emit(self, tracker: Tracker, t: float,
             dt_ms: float = 0.0) -> Optional[Dict[str, Any]]:
        cur = snapshot_stats(self.engine.stats)
        delta = stats_delta(self._prev, cur)
        self._prev = cur
        if not any(delta.values()):
            return None
        rec = {"kind": "engine_window", "t": float(t), "ring": self.ring,
               "step": self.engine.stats.steps,
               "dt_ms": float(max(dt_ms, 0.0)), "delta": delta}
        tracker.log(rec)
        self.windows += 1
        return rec


class RequestTimeline:
    """Per-request latency timestamps: submit, first token (TTFT), every
    token (ms/token), terminal status.  The frontend owns one per
    stream and emits a ``request`` record at the end."""

    def __init__(self, rid: int, t_submit: float, tenant: Optional[str] = None):
        self.rid = rid
        self.tenant = tenant
        self.t_submit = t_submit
        self.t_first: Optional[float] = None
        self.t_last: Optional[float] = None
        self.tokens = 0

    def on_token(self, t: float) -> None:
        if self.t_first is None:
            self.t_first = t
        self.t_last = t
        self.tokens += 1

    @property
    def ttft_ms(self) -> float:
        if self.t_first is None:
            return float("nan")
        return (self.t_first - self.t_submit) * 1e3

    @property
    def ms_per_token(self) -> float:
        """Mean inter-token latency over the decode phase (excludes
        TTFT; a 0/1-token stream has no decode phase -> 0)."""
        if self.tokens < 2 or self.t_first is None or self.t_last is None:
            return 0.0
        return (self.t_last - self.t_first) * 1e3 / (self.tokens - 1)

    def record(self, status: str, t: float) -> Dict[str, Any]:
        rec = {"kind": "request", "t": float(t), "rid": self.rid,
               "status": status, "tokens": self.tokens,
               "ttft_ms": (self.ttft_ms if self.t_first is not None
                           else -1.0),
               "ms_per_token": self.ms_per_token}
        if self.tenant is not None:
            rec["tenant"] = self.tenant
        return rec
