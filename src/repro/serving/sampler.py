"""Token sampler — the VXE "sampling with sort" instruction.

The paper puts sampling ON the LPU (a vector-execution-engine sort over
the logits) because shipping a full vocabulary row to the host per token
would serialize the generation loop on PCIe.  The analog here has three
layers:

* :func:`sample_local` — temperature / top-k / top-p over a full
  logits row, host- or device-side.  top-p keeps the smallest prefix of
  the sorted distribution with cumulative mass >= p (nucleus), top-k
  thresholds at the k-th sorted logit (clamped to the row width, so
  ``top_k > vocab`` degrades to no filter instead of indexing out of
  bounds); temperature <= 0 short-circuits to greedy argmax so the
  deterministic path never consumes RNG — that invariant is what makes
  the engine's greedy token streams bit-reproducible across runs and
  across tp configurations (tests/test_serving.py ring parity).

* :func:`sample_batched` — the FUSED form the serving engine jits into
  its decode program: per-slot ``temperature/top_k/top_p`` arrive as
  device arrays, every slot's row is sampled in one call, and the RNG
  rides along as device state (:func:`split_rng_chain`).  Bit-compatible
  with the host loop that visits slots in order and calls
  :func:`sample_local` per stochastic slot — the engine's synced-mode
  oracle (tests/test_fused_decode.py).

* :func:`sample_sharded` / :func:`sample_sharded_batched` — the ring
  form for vocab-sharded logits (``lm_logits`` never materializes the
  full row): each rank pre-selects its local top-k (k <= 64), only the
  tiny (tp x k) candidate set is all-gathered, and the final
  softmax/sort runs on that.  Every rank draws with the SAME rng, so the
  chosen token is replicated ring-wide without a broadcast.  The batched
  form runs inside the engine's ``shard_map`` decode program, so the
  full vocabulary row never leaves the ranks — the paper's C1 rationale
  realized end to end.

Mirrors the on-chip sort rationale of the paper's C1 datapath; the
serving engine (:mod:`repro.serving.engine`) consumes
:class:`SamplingParams` per request.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


class SamplingParams(NamedTuple):
    temperature: float = 1.0
    top_k: int = 0              # 0 = off
    top_p: float = 1.0          # 1 = off


MAX_LOCAL_K = 64


def sample_local(logits: jax.Array, rng: jax.Array,
                 params: SamplingParams) -> jax.Array:
    """logits: (B, V) full -> (B,) sampled token ids."""
    lg = logits.astype(jnp.float32)
    if params.temperature <= 0.0:
        return jnp.argmax(lg, -1).astype(jnp.int32)
    lg = lg / jnp.maximum(params.temperature, 1e-6)
    if params.top_k and params.top_k > 0:
        # clamp to the row width: top_k >= V keeps every entry (and the
        # unclamped -top_k would index out of bounds)
        k = min(int(params.top_k), lg.shape[-1])
        kth = jnp.sort(lg, -1)[:, -k][:, None]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    if params.top_p < 1.0:
        sorted_lg = jnp.sort(lg, -1)[:, ::-1]
        probs = jax.nn.softmax(sorted_lg, -1)
        cum = jnp.cumsum(probs, -1)
        # keep the smallest prefix with cumulative mass >= top_p: the
        # cutoff is the SMALLEST kept logit (jnp.min over the prefix —
        # the old jnp.max collapsed every non-tied row to argmax, a bug
        # the speculative statistical suite caught)
        keep = cum - probs < params.top_p
        cutoff = jnp.min(jnp.where(keep, sorted_lg, jnp.inf), -1,
                         keepdims=True)
        lg = jnp.where(lg >= cutoff, lg, -jnp.inf)
    return jax.random.categorical(rng, lg, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# fused (in-jit) batched sampling — per-slot params as device arrays
# ---------------------------------------------------------------------------

def split_rng_chain(rng: jax.Array, stoch: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Replicate the host loop's sequential RNG splits, in-jit.

    The host engine visits slots in order and calls
    ``rng, sub = jax.random.split(rng)`` ONLY for active stochastic
    slots — greedy and idle slots consume nothing, which is what keeps
    greedy streams bit-identical across batch compositions.  This scan
    reproduces that exact chain on device: ``stoch`` (B,) marks the
    consuming slots; the returned per-slot keys equal the host loop's
    ``sub`` values bit-for-bit (non-consuming slots get a don't-care
    key).  ``rng`` is a raw uint32 PRNGKey (the engine's convention).
    """
    def body(r, s):
        nxt = jax.random.split(r)
        return jnp.where(s, nxt[0], r), jnp.where(s, nxt[1], r)
    return lax.scan(body, rng, stoch)


def _filter_row(lg_raw: jax.Array, temp: jax.Array, top_k: jax.Array,
                top_p: jax.Array) -> jax.Array:
    """Temperature / top-k / top-p filter over one logits row (V,).

    The shared filter chain behind both :func:`_sample_row` and the
    speculative verify path: returns the row scaled by temperature with
    everything outside the top-k/top-p support set to ``-inf``, so
    ``softmax(_filter_row(row))`` IS the per-step target distribution
    the engine samples from.  Same order as :func:`sample_local`
    (temperature -> top-k -> top-p, each re-sorting the already-filtered
    row), so filtered draws bit-match the host path.
    """
    V = lg_raw.shape[-1]
    lg = lg_raw / jnp.maximum(temp, 1e-6)
    asc = jnp.sort(lg, -1)
    kth = lax.dynamic_index_in_dim(asc, V - jnp.clip(top_k, 1, V), 0,
                                   keepdims=False)
    lg = jnp.where((top_k > 0) & (lg < kth), -jnp.inf, lg)
    desc = jnp.sort(lg, -1)[::-1]
    probs = jax.nn.softmax(desc, -1)
    cum = jnp.cumsum(probs, -1)
    keep = cum - probs < top_p
    cutoff = jnp.min(jnp.where(keep, desc, jnp.inf), -1)
    return jnp.where((top_p < 1.0) & (lg < cutoff), -jnp.inf, lg)


def _sample_row(lg_raw: jax.Array, key: jax.Array, temp: jax.Array,
                top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """One logits row (V,) -> token id, with TRACED per-slot params.

    Bit-matches :func:`sample_local` on the same row: greedy
    (``temp <= 0``) is argmax of the raw row and touches no RNG bits;
    otherwise the :func:`_filter_row` chain and the same categorical
    draw — a (V,) gumbel stream generates the same bits as the host's
    (1, V) call, so fused == synced token for token.
    """
    lg = _filter_row(lg_raw, temp, top_k, top_p)
    stoch_tok = jax.random.categorical(key, lg, -1)
    return jnp.where(temp <= 0.0, jnp.argmax(lg_raw, -1),
                     stoch_tok).astype(jnp.int32)


def sample_batched(logits: jax.Array, rng: jax.Array, temps: jax.Array,
                   top_ks: jax.Array, top_ps: jax.Array,
                   active: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Fused per-slot sampling: (B, V) full logits -> ((B,) ids, rng').

    One jitted call samples every slot — per-slot ``temps/top_ks/top_ps``
    are device arrays, so mixed greedy/stochastic batches share one
    program.  ``active`` (B,) masks idle slots: they draw a don't-care
    token and, like greedy rows, consume NO rng, preserving the host
    loop's split order for the slots that do.
    """
    if active is None:
        active = jnp.ones(temps.shape, bool)
    stoch = active & (temps > 0.0)
    rng, keys = split_rng_chain(rng, stoch)
    toks = jax.vmap(_sample_row)(logits.astype(jnp.float32), keys, temps,
                                 top_ks, top_ps)
    return toks, rng


def sample_sharded_batched(logits_loc: jax.Array, rng: jax.Array,
                           temps: jax.Array, top_ks: jax.Array,
                           top_ps: jax.Array,
                           active: Optional[jax.Array],
                           axis_name: Optional[str], tp: int
                           ) -> Tuple[jax.Array, jax.Array]:
    """Fused per-slot sampling over vocab-sharded logits (B, V/tp).

    The ring form of :func:`sample_batched` for use INSIDE ``shard_map``:
    each rank pre-selects its local top-``MAX_LOCAL_K`` candidates, only
    the (tp x k) candidate set is all-gathered, and the filtered draw
    runs on that — the full vocabulary row never leaves the ranks.
    Every rank consumes the identical rng chain, so the sampled ids (and
    the new rng) come out replicated without a broadcast.  Greedy rows
    reduce to argmax over the candidate set == the global argmax.
    """
    if axis_name is None or tp == 1:
        return sample_batched(logits_loc, rng, temps, top_ks, top_ps,
                              active)
    if active is None:
        active = jnp.ones(temps.shape, bool)
    B, v_loc = logits_loc.shape
    k = min(MAX_LOCAL_K, v_loc)
    vals, idx = lax.top_k(logits_loc.astype(jnp.float32), k)
    r = lax.axis_index(axis_name)
    gidx = idx + r * v_loc
    vals_all = lax.all_gather(vals, axis_name, axis=1).reshape(B, tp * k)
    gidx_all = lax.all_gather(gidx, axis_name, axis=1).reshape(B, tp * k)
    stoch = active & (temps > 0.0)
    rng, keys = split_rng_chain(rng, stoch)
    chosen = jax.vmap(_sample_row)(vals_all, keys, temps, top_ks, top_ps)
    toks = jnp.take_along_axis(gidx_all, chosen[:, None], 1)[:, 0]
    return toks.astype(jnp.int32), rng


# ---------------------------------------------------------------------------
# speculative decoding — rejection-sampling verification of drafted tokens
# ---------------------------------------------------------------------------

def split_spec_rng_chain(rng: jax.Array, stoch: jax.Array, n: int
                         ) -> Tuple[jax.Array, jax.Array]:
    """Per-slot rng keys for one speculative verify window, in-jit.

    The host oracle visits slots in order and, for each active
    stochastic slot, performs ``n`` sequential
    ``rng, sub = jax.random.split(rng)`` draws — one subkey per verify
    position (k drafts + 1 bonus).  Greedy and idle slots consume
    NOTHING, exactly like :func:`split_rng_chain`, so greedy streams
    stay bit-reproducible whether or not speculation is on.  Returns
    ``(rng', keys)`` with ``keys`` shaped (B, n, 2); non-consuming slots
    get don't-care keys.
    """
    def per_slot(r, s):
        def inner(r2, _):
            nxt = jax.random.split(r2)
            return nxt[0], nxt[1]
        r_new, subs = lax.scan(inner, r, None, length=n)
        return (jnp.where(s, r_new, r),
                jnp.where(s, subs, jnp.broadcast_to(r, subs.shape)))
    return lax.scan(per_slot, rng, stoch)


def _verify_rows(vals: jax.Array, ids: jax.Array, draft: jax.Array,
                 keys: jax.Array, temp: jax.Array, top_k: jax.Array,
                 top_p: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Rejection-sample one slot's verify window.

    ``vals`` (K+1, C) are raw logits over a candidate set whose global
    token ids are ``ids`` (K+1, C) — the full vocabulary (``ids`` =
    iota) on a single ring, or the all-gathered (tp x 64) top-k set
    under tp.  Row i scores the token AFTER verify position i, i.e. the
    row the engine would have sampled from had it fed ``draft[i-1]``
    sequentially; ``draft`` (K,) are the proposed tokens for rows
    0..K-1.

    The drafter is deterministic, so its proposal q is one-hot at
    ``draft[i]`` and Leviathan-style rejection sampling collapses to:
    accept ``draft[i]`` with probability ``p_i(draft[i])`` (p_i =
    softmax of the :func:`_filter_row`-filtered row), else resample from
    p_i with the draft token masked out — which makes every emitted
    token an EXACT draw from p_i regardless of what the drafter
    proposed.  Greedy rows (``temp <= 0``) take the plain argmax and a
    draft is "accepted" iff it equals it, so the greedy output equals
    the sequential greedy stream bit for bit.  ``n_acc`` is the length
    of the leading accepted run; the emitted tokens for the window are
    ``out[0 .. n_acc]`` (accepted drafts + one resample/bonus token).
    A draft token absent from the candidate set has p = 0 and is always
    rejected, which keeps the tp form conservative, never wrong.

    ``keys`` (K+1, 2) come from :func:`split_spec_rng_chain`; position i
    derives its accept-uniform from ``fold_in(keys[i], 0)`` and its
    resample/bonus categorical from ``fold_in(keys[i], 1)``, so fused
    and host verify consume identical rng bits.
    """
    K = draft.shape[0]
    lg = jax.vmap(lambda rw: _filter_row(rw, temp, top_k, top_p))(vals)
    g_idx = jnp.argmax(vals, -1)
    g_out = jnp.take_along_axis(ids, g_idx[:, None], 1)[:, 0]
    probs = jax.nn.softmax(lg, -1)
    is_d = ids[:K] == draft[:, None]
    p_draft = jnp.sum(jnp.where(is_d, probs[:K], 0.0), -1)
    u = jax.vmap(lambda kk: jax.random.uniform(
        jax.random.fold_in(kk, 0)))(keys[:K])
    acc = u < p_draft
    lg_mask = jnp.where(is_d, -jnp.inf, lg[:K])
    res_idx = jax.vmap(lambda l, kk: jax.random.categorical(
        jax.random.fold_in(kk, 1), l))(lg_mask, keys[:K])
    res = jnp.take_along_axis(ids[:K], res_idx[:, None], 1)[:, 0]
    bonus_idx = jax.random.categorical(jax.random.fold_in(keys[K], 1),
                                       lg[K])
    bonus = ids[K, bonus_idx]
    s_out = jnp.concatenate([jnp.where(acc, draft, res), bonus[None]])
    greedy = temp <= 0.0
    out = jnp.where(greedy, g_out, s_out).astype(jnp.int32)
    match = jnp.where(greedy, g_out[:K] == draft, acc)
    n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32)))
    return out, n_acc.astype(jnp.int32)


def spec_verify_rows(rows: jax.Array, draft: jax.Array, keys: jax.Array,
                     temp: jax.Array, top_k: jax.Array, top_p: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """One slot's verify over FULL logits rows (K+1, V).

    The host oracle: the engine's ``sampling="host"`` speculative path
    reads the verify logits back and calls this per slot with the keys
    from its sequential split chain — the same function the fused path
    vmaps, so fused == host bit for bit by construction.
    Returns ``(out (K+1,), n_acc)``.
    """
    K1, V = rows.shape
    ids = jnp.broadcast_to(jnp.arange(V, dtype=jnp.int32)[None], (K1, V))
    return _verify_rows(rows.astype(jnp.float32), ids, draft, keys,
                        temp, top_k, top_p)


def speculative_verify_batched(logits: jax.Array, draft: jax.Array,
                               rng: jax.Array, temps: jax.Array,
                               top_ks: jax.Array, top_ps: jax.Array,
                               active: Optional[jax.Array] = None
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused rejection sampling for one verify window.

    ``logits`` (B, K+1, V) full rows, ``draft`` (B, K) proposed tokens.
    Returns ``(out (B, K+1), n_acc (B,), rng')`` — slot b emits
    ``out[b, 0 .. n_acc[b]]``.  Per-slot sampling params ride as device
    arrays exactly like :func:`sample_batched`; greedy/idle slots
    consume no rng.
    """
    B, K1, V = logits.shape
    if active is None:
        active = jnp.ones(temps.shape, bool)
    stoch = active & (temps > 0.0)
    rng, keys = split_spec_rng_chain(rng, stoch, K1)
    ids = jnp.broadcast_to(jnp.arange(V, dtype=jnp.int32)[None, None],
                           (B, K1, V))
    out, n_acc = jax.vmap(_verify_rows)(logits.astype(jnp.float32), ids,
                                        draft, keys, temps, top_ks,
                                        top_ps)
    return out, n_acc, rng


def speculative_verify_sharded(logits_loc: jax.Array, draft: jax.Array,
                               rng: jax.Array, temps: jax.Array,
                               top_ks: jax.Array, top_ps: jax.Array,
                               active: Optional[jax.Array],
                               axis_name: Optional[str], tp: int
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Ring form of :func:`speculative_verify_batched` for vocab-sharded
    verify logits (B, K+1, V/tp), for use INSIDE ``shard_map``.

    Mirrors :func:`sample_sharded_batched`: each rank pre-selects its
    local top-``MAX_LOCAL_K`` per row, only the (tp x k) candidate set
    is all-gathered, and the accept/resample draws run on that with
    every rank consuming the identical rng chain — accepted prefixes and
    resampled tokens come out replicated.  Greedy verification reduces
    to argmax over the candidate set == the global argmax, so greedy
    parity with tp=1 is exact; stochastic draws use the same candidate
    -set approximation the non-speculative ring sampler already uses.
    """
    if axis_name is None or tp == 1:
        return speculative_verify_batched(logits_loc, draft, rng, temps,
                                          top_ks, top_ps, active)
    if active is None:
        active = jnp.ones(temps.shape, bool)
    B, K1, v_loc = logits_loc.shape
    k = min(MAX_LOCAL_K, v_loc)
    vals, idx = lax.top_k(logits_loc.astype(jnp.float32), k)
    r = lax.axis_index(axis_name)
    gidx = idx + r * v_loc
    vals_all = lax.all_gather(vals, axis_name, axis=2).reshape(
        B, K1, tp * k)
    gidx_all = lax.all_gather(gidx, axis_name, axis=2).reshape(
        B, K1, tp * k)
    stoch = active & (temps > 0.0)
    rng, keys = split_spec_rng_chain(rng, stoch, K1)
    out, n_acc = jax.vmap(_verify_rows)(vals_all, gidx_all, draft, keys,
                                        temps, top_ks, top_ps)
    return out, n_acc, rng


def sample_sharded(logits_loc: jax.Array, rng: jax.Array,
                   params: SamplingParams, axis_name: Optional[str],
                   tp: int) -> jax.Array:
    """logits_loc: (B, V/tp) vocab-sharded -> (B,) global token ids.

    Single-call convenience form of :func:`sample_sharded_batched`
    (one static ``SamplingParams`` broadcast across the batch) — a thin
    delegate, so the ring sampling path has exactly ONE implementation,
    the one the serving engine jits and tests.
    """
    B = logits_loc.shape[0]
    toks, _ = sample_sharded_batched(
        logits_loc, rng,
        jnp.full((B,), params.temperature, jnp.float32),
        jnp.full((B,), params.top_k, jnp.int32),
        jnp.full((B,), params.top_p, jnp.float32),
        None, axis_name, tp)
    return toks
