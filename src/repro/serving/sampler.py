"""Token sampler — the VXE "sampling with sort" instruction.

The paper puts sampling ON the LPU (a vector-execution-engine sort over
the logits) because shipping a full vocabulary row to the host per token
would serialize the generation loop on PCIe.  The analog here has three
layers:

* :func:`sample_local` — temperature / top-k / top-p over a full
  logits row, host- or device-side.  top-p keeps the smallest prefix of
  the sorted distribution with cumulative mass >= p (nucleus), top-k
  thresholds at the k-th sorted logit (clamped to the row width, so
  ``top_k > vocab`` degrades to no filter instead of indexing out of
  bounds); temperature <= 0 short-circuits to greedy argmax so the
  deterministic path never consumes RNG — that invariant is what makes
  the engine's greedy token streams bit-reproducible across runs and
  across tp configurations (tests/test_serving.py ring parity).

* :func:`sample_batched` — the FUSED form the serving engine jits into
  its decode program: per-slot ``temperature/top_k/top_p`` arrive as
  device arrays, every slot's row is sampled in one call, and the RNG
  rides along as device state (:func:`split_rng_chain`).  Bit-compatible
  with the host loop that visits slots in order and calls
  :func:`sample_local` per stochastic slot — the engine's synced-mode
  oracle (tests/test_fused_decode.py).

* :func:`sample_sharded` / :func:`sample_sharded_batched` — the ring
  form for vocab-sharded logits (``lm_logits`` never materializes the
  full row): each rank pre-selects its local top-k (k <= 64), only the
  tiny (tp x k) candidate set is all-gathered, and the final
  softmax/sort runs on that.  Every rank draws with the SAME rng, so the
  chosen token is replicated ring-wide without a broadcast.  The batched
  form runs inside the engine's ``shard_map`` decode program, so the
  full vocabulary row never leaves the ranks — the paper's C1 rationale
  realized end to end.

Mirrors the on-chip sort rationale of the paper's C1 datapath; the
serving engine (:mod:`repro.serving.engine`) consumes
:class:`SamplingParams` per request.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


class SamplingParams(NamedTuple):
    temperature: float = 1.0
    top_k: int = 0              # 0 = off
    top_p: float = 1.0          # 1 = off


MAX_LOCAL_K = 64


def sample_local(logits: jax.Array, rng: jax.Array,
                 params: SamplingParams) -> jax.Array:
    """logits: (B, V) full -> (B,) sampled token ids."""
    lg = logits.astype(jnp.float32)
    if params.temperature <= 0.0:
        return jnp.argmax(lg, -1).astype(jnp.int32)
    lg = lg / jnp.maximum(params.temperature, 1e-6)
    if params.top_k and params.top_k > 0:
        # clamp to the row width: top_k >= V keeps every entry (and the
        # unclamped -top_k would index out of bounds)
        k = min(int(params.top_k), lg.shape[-1])
        kth = jnp.sort(lg, -1)[:, -k][:, None]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    if params.top_p < 1.0:
        sorted_lg = jnp.sort(lg, -1)[:, ::-1]
        probs = jax.nn.softmax(sorted_lg, -1)
        cum = jnp.cumsum(probs, -1)
        # keep the smallest prefix with cumulative mass >= top_p
        keep = cum - probs < params.top_p
        cutoff = jnp.max(jnp.where(keep, sorted_lg, -jnp.inf), -1,
                         keepdims=True)
        lg = jnp.where(lg >= cutoff, lg, -jnp.inf)
    return jax.random.categorical(rng, lg, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# fused (in-jit) batched sampling — per-slot params as device arrays
# ---------------------------------------------------------------------------

def split_rng_chain(rng: jax.Array, stoch: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Replicate the host loop's sequential RNG splits, in-jit.

    The host engine visits slots in order and calls
    ``rng, sub = jax.random.split(rng)`` ONLY for active stochastic
    slots — greedy and idle slots consume nothing, which is what keeps
    greedy streams bit-identical across batch compositions.  This scan
    reproduces that exact chain on device: ``stoch`` (B,) marks the
    consuming slots; the returned per-slot keys equal the host loop's
    ``sub`` values bit-for-bit (non-consuming slots get a don't-care
    key).  ``rng`` is a raw uint32 PRNGKey (the engine's convention).
    """
    def body(r, s):
        nxt = jax.random.split(r)
        return jnp.where(s, nxt[0], r), jnp.where(s, nxt[1], r)
    return lax.scan(body, rng, stoch)


def _sample_row(lg_raw: jax.Array, key: jax.Array, temp: jax.Array,
                top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """One logits row (V,) -> token id, with TRACED per-slot params.

    Bit-matches :func:`sample_local` on the same row: greedy
    (``temp <= 0``) is argmax of the raw row and touches no RNG bits;
    otherwise the same filter order (temperature -> top-k -> top-p, each
    re-sorting the already-filtered row exactly like the host path) and
    the same categorical draw — a (V,) gumbel stream generates the same
    bits as the host's (1, V) call, so fused == synced token for token.
    """
    V = lg_raw.shape[-1]
    lg = lg_raw / jnp.maximum(temp, 1e-6)
    asc = jnp.sort(lg, -1)
    kth = lax.dynamic_index_in_dim(asc, V - jnp.clip(top_k, 1, V), 0,
                                   keepdims=False)
    lg = jnp.where((top_k > 0) & (lg < kth), -jnp.inf, lg)
    desc = jnp.sort(lg, -1)[::-1]
    probs = jax.nn.softmax(desc, -1)
    cum = jnp.cumsum(probs, -1)
    keep = cum - probs < top_p
    cutoff = jnp.max(jnp.where(keep, desc, -jnp.inf), -1)
    lg = jnp.where((top_p < 1.0) & (lg < cutoff), -jnp.inf, lg)
    stoch_tok = jax.random.categorical(key, lg, -1)
    return jnp.where(temp <= 0.0, jnp.argmax(lg_raw, -1),
                     stoch_tok).astype(jnp.int32)


def sample_batched(logits: jax.Array, rng: jax.Array, temps: jax.Array,
                   top_ks: jax.Array, top_ps: jax.Array,
                   active: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Fused per-slot sampling: (B, V) full logits -> ((B,) ids, rng').

    One jitted call samples every slot — per-slot ``temps/top_ks/top_ps``
    are device arrays, so mixed greedy/stochastic batches share one
    program.  ``active`` (B,) masks idle slots: they draw a don't-care
    token and, like greedy rows, consume NO rng, preserving the host
    loop's split order for the slots that do.
    """
    if active is None:
        active = jnp.ones(temps.shape, bool)
    stoch = active & (temps > 0.0)
    rng, keys = split_rng_chain(rng, stoch)
    toks = jax.vmap(_sample_row)(logits.astype(jnp.float32), keys, temps,
                                 top_ks, top_ps)
    return toks, rng


def sample_sharded_batched(logits_loc: jax.Array, rng: jax.Array,
                           temps: jax.Array, top_ks: jax.Array,
                           top_ps: jax.Array,
                           active: Optional[jax.Array],
                           axis_name: Optional[str], tp: int
                           ) -> Tuple[jax.Array, jax.Array]:
    """Fused per-slot sampling over vocab-sharded logits (B, V/tp).

    The ring form of :func:`sample_batched` for use INSIDE ``shard_map``:
    each rank pre-selects its local top-``MAX_LOCAL_K`` candidates, only
    the (tp x k) candidate set is all-gathered, and the filtered draw
    runs on that — the full vocabulary row never leaves the ranks.
    Every rank consumes the identical rng chain, so the sampled ids (and
    the new rng) come out replicated without a broadcast.  Greedy rows
    reduce to argmax over the candidate set == the global argmax.
    """
    if axis_name is None or tp == 1:
        return sample_batched(logits_loc, rng, temps, top_ks, top_ps,
                              active)
    if active is None:
        active = jnp.ones(temps.shape, bool)
    B, v_loc = logits_loc.shape
    k = min(MAX_LOCAL_K, v_loc)
    vals, idx = lax.top_k(logits_loc.astype(jnp.float32), k)
    r = lax.axis_index(axis_name)
    gidx = idx + r * v_loc
    vals_all = lax.all_gather(vals, axis_name, axis=1).reshape(B, tp * k)
    gidx_all = lax.all_gather(gidx, axis_name, axis=1).reshape(B, tp * k)
    stoch = active & (temps > 0.0)
    rng, keys = split_rng_chain(rng, stoch)
    chosen = jax.vmap(_sample_row)(vals_all, keys, temps, top_ks, top_ps)
    toks = jnp.take_along_axis(gidx_all, chosen[:, None], 1)[:, 0]
    return toks.astype(jnp.int32), rng


def sample_sharded(logits_loc: jax.Array, rng: jax.Array,
                   params: SamplingParams, axis_name: Optional[str],
                   tp: int) -> jax.Array:
    """logits_loc: (B, V/tp) vocab-sharded -> (B,) global token ids.

    Single-call convenience form of :func:`sample_sharded_batched`
    (one static ``SamplingParams`` broadcast across the batch) — a thin
    delegate, so the ring sampling path has exactly ONE implementation,
    the one the serving engine jits and tests.
    """
    B = logits_loc.shape[0]
    toks, _ = sample_sharded_batched(
        logits_loc, rng,
        jnp.full((B,), params.temperature, jnp.float32),
        jnp.full((B,), params.top_k, jnp.int32),
        jnp.full((B,), params.top_p, jnp.float32),
        None, axis_name, tp)
    return toks
