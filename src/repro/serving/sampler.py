"""Token sampler — the VXE "sampling with sort" instruction.

The paper puts sampling ON the LPU (a vector-execution-engine sort over
the logits) because shipping a full vocabulary row to the host per token
would serialize the generation loop on PCIe.  The analog here has two
layers:

* :func:`sample_local` — temperature / top-k / top-p over a full
  logits row, host- or device-side.  top-p keeps the smallest prefix of
  the sorted distribution with cumulative mass >= p (nucleus), top-k
  thresholds at the k-th sorted logit; temperature <= 0 short-circuits
  to greedy argmax so the deterministic path never consumes RNG — that
  invariant is what makes the engine's greedy token streams
  bit-reproducible across runs and across tp configurations
  (tests/test_serving.py ring parity).

* :func:`sample_sharded` — the ring form for vocab-sharded logits
  (``lm_logits`` never materializes the full row): each rank pre-selects
  its local top-k (k <= 64), only the tiny (tp x k) candidate set is
  all-gathered, and the final softmax/sort runs on that.  Every rank
  draws with the SAME rng, so the chosen token is replicated ring-wide
  without a broadcast — the same no-divergence trick the serving engine
  relies on when it samples once on the host from gathered logits.

Mirrors the on-chip sort rationale of the paper's C1 datapath; the
serving engine (:mod:`repro.serving.engine`) consumes
:class:`SamplingParams` per request.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax


class SamplingParams(NamedTuple):
    temperature: float = 1.0
    top_k: int = 0              # 0 = off
    top_p: float = 1.0          # 1 = off


MAX_LOCAL_K = 64


def sample_local(logits: jax.Array, rng: jax.Array,
                 params: SamplingParams) -> jax.Array:
    """logits: (B, V) full -> (B,) sampled token ids."""
    lg = logits.astype(jnp.float32)
    if params.temperature <= 0.0:
        return jnp.argmax(lg, -1).astype(jnp.int32)
    lg = lg / jnp.maximum(params.temperature, 1e-6)
    if params.top_k and params.top_k > 0:
        kth = jnp.sort(lg, -1)[:, -params.top_k][:, None]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    if params.top_p < 1.0:
        sorted_lg = jnp.sort(lg, -1)[:, ::-1]
        probs = jax.nn.softmax(sorted_lg, -1)
        cum = jnp.cumsum(probs, -1)
        # keep the smallest prefix with cumulative mass >= top_p
        keep = cum - probs < params.top_p
        cutoff = jnp.max(jnp.where(keep, sorted_lg, -jnp.inf), -1,
                         keepdims=True)
        lg = jnp.where(lg >= cutoff, lg, -jnp.inf)
    return jax.random.categorical(rng, lg, -1).astype(jnp.int32)


def sample_sharded(logits_loc: jax.Array, rng: jax.Array,
                   params: SamplingParams, axis_name: Optional[str],
                   tp: int) -> jax.Array:
    """logits_loc: (B, V/tp) vocab-sharded -> (B,) global token ids.

    Every rank computes the same result (same rng), so the output is
    replicated across the ring — no divergence.
    """
    if axis_name is None or tp == 1:
        return sample_local(logits_loc, rng, params)
    B, v_loc = logits_loc.shape
    k = min(MAX_LOCAL_K, v_loc)
    vals, idx = lax.top_k(logits_loc.astype(jnp.float32), k)
    r = lax.axis_index(axis_name)
    gidx = idx + r * v_loc
    vals_all = lax.all_gather(vals, axis_name, axis=1)    # (B, tp, k)
    gidx_all = lax.all_gather(gidx, axis_name, axis=1)
    vals_all = vals_all.reshape(B, tp * k)
    gidx_all = gidx_all.reshape(B, tp * k)
    chosen = sample_local(vals_all, rng, params)          # (B,) in [0,tp*k)
    return jnp.take_along_axis(gidx_all, chosen[:, None], 1)[:, 0]
