"""Async streaming front end over :class:`LPUEngine`/:class:`MultiRingEngine`.

The blocking ``submit/step/drain`` API measures throughput; users feel
per-request latency.  This module is the seam between the two: an
asyncio front end that

* streams tokens out as each decode window reconciles —
  :meth:`AsyncFrontend.submit` returns a :class:`TokenStream` (an async
  iterator) fed by the engine's ``stream_cb`` the moment the host sees
  each token;
* bounds admission — at most ``max_pending`` streams in flight, beyond
  which ``submit`` raises a structured :class:`AdmissionRejected`
  (backpressure belongs at the edge, not as an unbounded queue inside
  the scheduler);
* supports cancellation that actually frees resources —
  :meth:`TokenStream.cancel` releases the request's slot and pool
  blocks between steps (`LPUEngine.cancel`), so an abandoned stream
  never holds KV;
* drives SLO scheduling — with a :class:`repro.serving.budget.
  BudgetScheduler` attached, every pump tick re-plans ``prefill_chunk``
  and ``steps_per_sync`` from the measured-step-time EWMA before
  stepping the engine;
* emits telemetry — an optional :class:`repro.serving.tracker.Tracker`
  receives per-window ``EngineStats`` deltas (snapshot-and-diff via
  :class:`EngineTap`) and a per-request TTFT / ms-per-token record at
  each stream's end.

Concurrency model: ONE event loop, no threads.  The pump task calls the
engine's synchronous ``step()`` directly and yields
(``await asyncio.sleep(0)``) between steps, so consumers drain their
queues exactly at window-reconcile granularity.  That keeps the token
streams bit-identical to the blocking path (greedy — it is the same
engine stepping in the same order; tests/test_frontend.py locks this)
and makes cancellation race-free by construction: every frontend entry
point runs between engine steps.
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from repro.serving.budget import BudgetScheduler
from repro.serving.engine import LPUEngine, MultiRingEngine, Request
from repro.serving.sampler import SamplingParams
from repro.serving.tracker import (EngineTap, NullTracker, RequestTimeline,
                                   Tracker)


class AdmissionRejected(RuntimeError):
    """Structured backpressure signal: the frontend's in-flight window
    is full.  Carries the numbers a client needs to back off sensibly
    instead of parsing a message string."""

    def __init__(self, pending: int, limit: int):
        self.pending = pending
        self.limit = limit
        super().__init__(
            f"admission rejected: {pending} streams in flight >= "
            f"max_pending={limit}")


class TokenStream:
    """One request's async token stream.

    ``async for tok in stream`` yields generated token ids as the
    engine reconciles them; iteration ends when the request completes,
    fails, or is cancelled — ``status`` / ``error`` say which.  The
    accumulated tokens are also kept in ``tokens`` (bit-identical to
    the blocking path's ``results[rid]``).
    """

    def __init__(self, rid: int, frontend: "AsyncFrontend",
                 timeline: RequestTimeline):
        self.rid = rid
        self.tokens: List[int] = []
        self.status = "streaming"     # -> completed | failed | cancelled
        self.error: Optional[str] = None
        self.timeline = timeline
        self._pending: Deque[int] = deque()
        self._event = asyncio.Event()
        self._frontend = frontend

    @property
    def done(self) -> bool:
        return self.status != "streaming"

    def _push(self, tok: int) -> None:
        self.tokens.append(tok)
        self._pending.append(tok)
        self._event.set()

    def _finish(self, status: str, error: Optional[str] = None) -> None:
        self.status = status
        self.error = error
        self._event.set()

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        while True:
            if self._pending:
                return self._pending.popleft()
            if self.done:
                raise StopAsyncIteration
            self._event.clear()
            await self._event.wait()

    async def drain(self) -> List[int]:
        """Consume the stream to the end; returns all tokens."""
        async for _ in self:
            pass
        return self.tokens

    async def cancel(self) -> bool:
        """Abort this stream and free its engine resources.  True if
        the cancellation landed (False: already finished)."""
        return self._frontend.cancel(self.rid)


class AsyncFrontend:
    """Async serving facade over one engine or a multi-ring fleet.

    Use as an async context manager::

        async with AsyncFrontend(engine, max_pending=64) as fe:
            stream = fe.submit(prompt, max_new_tokens=32)
            async for tok in stream: ...

    ``counters`` tracks the admission ledger; at any quiesced point
    ``completed + failed + cancelled == submitted`` (and ``rejected``
    counts submits that never reached the engine).
    """

    def __init__(self, engine, *, max_pending: Optional[int] = None,
                 budget: Optional[BudgetScheduler] = None,
                 tracker: Optional[Tracker] = None,
                 clock=time.perf_counter):
        self.engine = engine
        self.engines: List[LPUEngine] = (
            list(engine.engines) if isinstance(engine, MultiRingEngine)
            else [engine])
        cfg = self.engines[0].config
        self.max_pending = (cfg.max_pending if max_pending is None
                            else int(max_pending))
        if budget is None and cfg.budget_ms > 0:
            budget = BudgetScheduler(cfg.budget_ms)
        self.budget = budget
        self.tracker = tracker if tracker is not None else NullTracker()
        self.clock = clock
        self._taps = [EngineTap(e, ring=i)
                      for i, e in enumerate(self.engines)]
        self._streams: Dict[int, TokenStream] = {}
        self._inflight: Dict[int, TokenStream] = {}
        self.counters = dict(submitted=0, completed=0, failed=0,
                             cancelled=0, rejected=0)
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._idle.set()
        self._closing = False
        self._task: Optional[asyncio.Task] = None

    # -- lifecycle -----------------------------------------------------

    async def __aenter__(self) -> "AsyncFrontend":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def start(self) -> None:
        if self._task is None:
            self._closing = False
            self._task = asyncio.create_task(self._pump())

    async def stop(self) -> None:
        """Finish in-flight work, stop the pump, flush the tracker."""
        self._closing = True
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
        self.tracker.finish()

    async def join(self) -> None:
        """Wait until every in-flight stream has ended."""
        await self._idle.wait()

    # -- submission / cancellation ------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               params: Optional[SamplingParams] = None,
               tenant: Optional[str] = None) -> TokenStream:
        """Admit one request; returns its :class:`TokenStream`.

        Raises :class:`AdmissionRejected` when ``max_pending`` streams
        are already in flight — backpressure, not queueing.
        """
        if self.max_pending and len(self._inflight) >= self.max_pending:
            self.counters["rejected"] += 1
            self.tracker.log({"kind": "event", "t": self.clock(),
                              "name": "admission_rejected",
                              "pending": len(self._inflight),
                              "limit": self.max_pending})
            raise AdmissionRejected(len(self._inflight), self.max_pending)
        t0 = self.clock()
        rid = self.engine.submit(list(prompt), max_new_tokens, params,
                                 stream_cb=self._on_token)
        stream = TokenStream(rid, self, RequestTimeline(rid, t0,
                                                        tenant=tenant))
        self._streams[rid] = stream
        self._inflight[rid] = stream
        self.counters["submitted"] += 1
        self._idle.clear()
        self._wake.set()
        return stream

    def cancel(self, rid: int) -> bool:
        """Abort one in-flight stream; frees its slot and pool blocks.
        False if the request already finished (nothing to do)."""
        stream = self._inflight.get(rid)
        if stream is None:
            return False
        req = self.engine.cancel(rid)
        if req is None:
            # not in the engine anymore: it finished inside the current
            # pump tick and will be finalized when step() returns
            return False
        self._finalize(rid, "cancelled")
        return True

    # -- the pump ------------------------------------------------------

    def _on_token(self, rid: int, tok: int) -> None:
        stream = self._streams.get(rid)
        if stream is None:            # e.g. blocking-path co-tenant
            return
        stream.timeline.on_token(self.clock())
        stream._push(tok)

    def _finalize(self, rid: int, status: str,
                  error: Optional[str] = None) -> None:
        stream = self._inflight.pop(rid, None)
        if stream is None:
            return
        self.counters[status] += 1
        stream._finish(status, error)
        self.tracker.log(stream.timeline.record(status, self.clock()))
        if not self._inflight:
            self._idle.set()

    def _has_work(self) -> bool:
        return self.engine.has_work()

    def _apply_budget(self) -> None:
        for eng in self.engines:
            chunk, steps = self.budget.plan(
                chunked=eng.prefill_chunk > 0,
                fused=eng.sampling == "fused")
            eng.set_step_knobs(prefill_chunk=chunk, steps_per_sync=steps)

    def _observe_budget(self, dt_s: float, deltas: List[Dict[str, int]]
                        ) -> None:
        steps = sum(d.get("steps", 0) for d in deltas)
        chunks = sum(d.get("prefill_chunks", 0) for d in deltas)
        tokens = sum(d.get("prefill_chunks", 0) * e.prefill_chunk
                     for d, e in zip(deltas, self.engines))
        if steps and not chunks:
            self.budget.observe_window(dt_s, steps)
        elif chunks and not steps:
            self.budget.observe_chunk(dt_s, tokens)
        elif steps and chunks:
            # mixed tick (interleaved admission runs a prefill chunk AND
            # a decode window in the same step): split the measured wall
            # between the phases in proportion to the model's current
            # predictions.  A fully interleaved workload would otherwise
            # never train the EWMA — every tick mixed, every tick
            # skipped — and self-consistent splitting still converges:
            # whichever phase the model underestimates absorbs a larger
            # share of the residual on the next update.
            pred_w = self.budget.mu_step * steps
            pred_c = self.budget.mu_tok * max(tokens, 1)
            total = pred_w + pred_c
            if total > 0:
                self.budget.observe_window(dt_s * pred_w / total, steps)
                self.budget.observe_chunk(dt_s * pred_c / total,
                                          max(tokens, 1))

    def _tick(self) -> None:
        """One engine step with SLO planning + telemetry around it."""
        if self.budget is not None:
            self._apply_budget()
        t0 = self.clock()
        done = self.engine.step()
        dt = self.clock() - t0
        deltas = []
        for tap in self._taps:
            before = dict(tap._prev)
            rec = tap.emit(self.tracker, self.clock(), dt_ms=dt * 1e3)
            deltas.append(rec["delta"] if rec is not None else
                          {k: 0 for k in before})
        if self.budget is not None:
            self._observe_budget(dt, deltas)
        for req in done:
            if req.rid not in self._inflight:
                continue
            if req.failed:
                self._finalize(req.rid, "failed", req.error)
            else:
                self._finalize(req.rid, "completed")

    async def _pump(self) -> None:
        while True:
            if not self._has_work():
                if self._closing:
                    return
                self._wake.clear()
                # re-check: a submit may have landed before clear()
                if self._has_work() or self._closing:
                    continue
                await self._wake.wait()
                continue
            self._tick()
            # yield so consumers drain at window granularity
            await asyncio.sleep(0)


async def serve_trace(frontend: AsyncFrontend, trace,
                      speed: float = 1.0) -> List[TokenStream]:
    """Replay a :mod:`benchmarks.traces` trace against a frontend:
    submit each request at ``arrival_s / speed`` (wall), collect every
    stream, and wait for the fleet to quiesce.  Rejected submits are
    recorded as ``None`` placeholders so callers can count them."""
    loop = asyncio.get_event_loop()
    t0 = loop.time()
    streams: List[Optional[TokenStream]] = []
    for req in trace:
        delay = req.arrival_s / speed - (loop.time() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        try:
            streams.append(frontend.submit(
                req.prompt, req.max_new_tokens, tenant=req.tenant))
        except AdmissionRejected:
            streams.append(None)
    await frontend.join()
    return streams
