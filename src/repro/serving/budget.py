"""Latency-budget (SLO) step scheduling for the async frontend.

The engine has two per-step latency knobs:

* ``steps_per_sync`` (S) — decode tokens fused into one device window
  before the host syncs.  Bigger S amortizes dispatch/sync overhead
  (throughput) but delays stream reconciliation by S tokens (tail
  latency): a window IS the granularity at which tokens reach users.
* ``prefill_chunk`` (C) — prompt tokens made resident per step in
  chunked-prefill mode.  Bigger C admits faster (TTFT of the admitting
  request) but each chunk launch occupies the step for longer,
  stretching every in-flight stream's inter-token gap.

:class:`BudgetScheduler` picks both each step so one engine step fits a
caller-given latency budget: an EWMA of *measured* per-decode-step and
per-chunk-token times, seeded from the analytic prior
(:func:`repro.core.latency_model.step_time_prior`) so the very first
step is already tuned instead of warming up blind — this folds in the
ROADMAP's open chunk-autotuning item (pick C from measured step time
rather than a hand-set constant).

Chunk widths are quantized to powers of two: the chunk program jit
retraces per distinct width, so free-running C would trade its latency
win back as compile stalls.  Window sizes need no such care — the
engine caches one traced program per S.
"""
from __future__ import annotations

from typing import Optional, Tuple


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


class BudgetScheduler:
    """EWMA-tracked per-step latency model + knob planner.

    Parameters
    ----------
    budget_ms:
        Target wall time of ONE engine step.  The planner chooses the
        largest S (and C) whose predicted cost stays under it.
    prior_step_s:
        Analytic seed for the per-decode-step EWMA (seconds), normally
        ``step_time_prior(cfg, n_devices, hw)``.  ``0`` falls back to
        ``budget_ms`` itself (first window = 1 step, then measure).
    prior_chunk_tok_s:
        Seed for the per-prefill-token EWMA.  ``0`` derives a pessimistic
        seed from ``prior_step_s`` (one prompt token ~ one decode step's
        compute upper-bounds the chunked path, which amortizes weight
        streaming across the chunk); the first measured chunk corrects it.
    """

    def __init__(self, budget_ms: float, *, prior_step_s: float = 0.0,
                 prior_chunk_tok_s: float = 0.0, alpha: float = 0.25,
                 max_steps_per_sync: int = 16, min_chunk: int = 8,
                 max_chunk: int = 256):
        if budget_ms <= 0:
            raise ValueError(f"budget_ms={budget_ms} must be > 0")
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha={alpha} must be in (0, 1]")
        if max_steps_per_sync < 1:
            raise ValueError(
                f"max_steps_per_sync={max_steps_per_sync} must be >= 1")
        if not (0 < min_chunk <= max_chunk):
            raise ValueError(f"need 0 < min_chunk <= max_chunk, got "
                             f"({min_chunk}, {max_chunk})")
        self.budget_s = budget_ms * 1e-3
        self.alpha = alpha
        self.max_steps_per_sync = int(max_steps_per_sync)
        self.min_chunk = int(min_chunk)
        self.max_chunk = int(max_chunk)
        self.mu_step = float(prior_step_s) or self.budget_s
        self.mu_tok = float(prior_chunk_tok_s) or self.mu_step
        self.observed_windows = 0
        self.observed_chunks = 0
        self.planned: list = []         # (chunk, steps) telemetry trail

    # -- measurement ---------------------------------------------------

    def observe_window(self, dt_s: float, steps: int) -> None:
        """Fold one measured decode window (``steps`` fused device
        steps in ``dt_s`` seconds) into the per-step EWMA."""
        if steps < 1 or dt_s < 0:
            return
        x = dt_s / steps
        self.mu_step += self.alpha * (x - self.mu_step)
        self.observed_windows += 1

    def observe_chunk(self, dt_s: float, tokens: int) -> None:
        """Fold one measured prefill chunk into the per-token EWMA."""
        if tokens < 1 or dt_s < 0:
            return
        x = dt_s / tokens
        self.mu_tok += self.alpha * (x - self.mu_tok)
        self.observed_chunks += 1

    # -- planning ------------------------------------------------------

    def plan_steps(self) -> int:
        """Largest fused window predicted to fit the budget."""
        s = int(self.budget_s / max(self.mu_step, 1e-9))
        return max(1, min(s, self.max_steps_per_sync))

    def plan_chunk(self) -> int:
        """Largest pow2 chunk width predicted to fit the budget."""
        c = int(self.budget_s / max(self.mu_tok, 1e-9))
        c = max(self.min_chunk, min(c, self.max_chunk))
        return _pow2_floor(c)

    def plan(self, *, chunked: bool = True,
             fused: bool = True) -> Tuple[Optional[int], int]:
        """One (prefill_chunk, steps_per_sync) decision for the next
        engine step.  ``chunked=False`` (engine runs monolithic prefill)
        returns ``None`` for the chunk so the caller leaves that knob
        alone; ``fused=False`` (host sampling) pins S to 1."""
        chunk = self.plan_chunk() if chunked else None
        steps = self.plan_steps() if fused else 1
        self.planned.append((chunk, steps))
        return chunk, steps
