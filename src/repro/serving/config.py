"""Typed engine construction config — ONE object instead of ~20 kwargs.

``EngineConfig`` groups every scalar construction knob of
:class:`repro.serving.engine.LPUEngine` (the runtime objects — mesh,
rng, drafter, draft model/params — stay direct constructor arguments:
they are per-process resources, not serializable configuration).  The
groups mirror the engine's subsystems:

* paged pool:      ``paged``, ``block_size``, ``num_blocks``,
                   ``kv_budget_bytes``, ``min_bucket``
* kernel dataflow: ``paged_kernel``, ``block_s``
* sampling loop:   ``sampling``, ``steps_per_sync``, ``pipeline``
* prefill:         ``prefill_chunk``, ``prefix_cache``
* speculation:     ``speculate``, ``draft_k``
* precision:       ``kv_dtype``, ``w_dtype``  (NEW in this config —
                   deliberately never added as constructor kwarg #21)
* fault tolerance: ``chaos``, ``max_migrations``,
                   ``heartbeat_timeout_s``, ``ft_straggler_drain``
                   (the serving FT subsystem — see
                   :mod:`repro.serving.ft` and docs/serving.md)
* front end:       ``affinity``, ``budget_ms``, ``max_pending``
                   (async serving layer — :mod:`repro.serving.frontend`,
                   :mod:`repro.serving.budget`)

Legacy construction (``LPUEngine(model, params, slots=8, ...)``) still
works through :func:`resolve_engine_config`, which folds the kwargs
into an ``EngineConfig`` and warns once per process — the shim is
parity-tested (tests/test_engine_config.py) and slated for removal.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, fields, replace
from typing import Optional

KV_DTYPES = ("auto", "float16", "fp16", "bfloat16", "bf16", "float32",
             "fp32", "int8", "fp8", "float8_e4m3fn")
W_DTYPES = ("auto", "int8")


@dataclass(frozen=True)
class EngineConfig:
    """Scalar construction knobs of an :class:`LPUEngine`.

    Defaults are EXACTLY the legacy constructor defaults, so
    ``EngineConfig()`` builds the same engine as the historical
    no-kwarg call.
    """
    # core batch/sequence geometry
    slots: int = 4
    max_seq: int = 256
    eos_id: Optional[int] = None
    # paged KV pool
    paged: Optional[bool] = None       # None = auto (attention-only stacks)
    block_size: int = 0                # 0 = min(LANE, max_seq)
    num_blocks: int = 0                # 0 = budget- or dense-equivalent
    kv_budget_bytes: int = 0           # per-rank HBM budget for the pool
    min_bucket: int = 16               # smallest pow2 prefill bucket
    # kernel dataflow
    paged_kernel: str = "auto"         # auto | stream | gather
    block_s: int = 0                   # flash-chunk override (gather/dense)
    # sampling loop
    sampling: str = "fused"            # fused | host
    steps_per_sync: int = 1            # fused window length
    pipeline: bool = True              # double-buffer window dispatch
    # prefill
    prefill_chunk: int = 0             # 0 = monolithic bucketed prefill
    prefix_cache: bool = False
    # speculation
    speculate: str = "off"             # off | ngram | model
    draft_k: int = 4
    # precision (the quantized-KV / int8-weight knobs live ONLY here)
    kv_dtype: str = "auto"             # auto|float16|bfloat16|float32|
                                       # int8|fp8 — pool storage precision
    w_dtype: str = "auto"              # auto|int8 — streamed weight
                                       # precision (gemv chain)
    # fault tolerance (serving FT subsystem; see repro.serving.ft)
    chaos: str = ""                    # "" = off; else deterministic
                                       # fault spec "kind@step[:ring],..."
                                       # with kinds ring|stall|nan|corrupt
    max_migrations: int = 3            # recompute-migrations per request
                                       # before it surfaces a structured
                                       # failure (never an engine crash)
    heartbeat_timeout_s: float = 30.0  # ring liveness timeout (clock is
                                       # injected; deterministic in chaos
                                       # runs via ManualClock)
    ft_straggler_drain: bool = False   # drain/rebuild a straggler-flagged
                                       # ring (default: log the event only)
    # serving front end (repro.serving.frontend / budget / tracker)
    affinity: str = "least_loaded"     # least_loaded | prefix — fleet
                                       # routing policy: "prefix" sends a
                                       # request to the ring whose
                                       # PrefixCache owns the deepest
                                       # prefix of its prompt
    budget_ms: float = 0.0             # per-step latency budget for the
                                       # SLO scheduler (0 = off): the
                                       # frontend retunes prefill_chunk /
                                       # steps_per_sync each step from an
                                       # EWMA seeded by step_time_prior
    max_pending: int = 0               # frontend admission bound (0 =
                                       # unbounded): in-flight streams
                                       # above this are rejected with a
                                       # structured AdmissionRejected

    def __post_init__(self):
        if self.affinity not in ("least_loaded", "prefix"):
            raise ValueError(f"affinity={self.affinity!r} not in "
                             "('least_loaded', 'prefix')")
        if self.budget_ms < 0:
            raise ValueError(f"budget_ms={self.budget_ms} must be >= 0")
        if self.max_pending < 0:
            raise ValueError(f"max_pending={self.max_pending} must be >= 0")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype={self.kv_dtype!r} not in "
                             f"{KV_DTYPES}")
        if self.w_dtype not in W_DTYPES:
            raise ValueError(f"w_dtype={self.w_dtype!r} not in {W_DTYPES}")
        if self.chaos:
            from repro.serving.ft import parse_chaos
            parse_chaos(self.chaos)    # fail at construction, not mid-run
        if self.max_migrations < 0:
            raise ValueError(
                f"max_migrations={self.max_migrations} must be >= 0")
        if self.heartbeat_timeout_s <= 0:
            raise ValueError(
                f"heartbeat_timeout_s={self.heartbeat_timeout_s} "
                "must be > 0")

    def with_overrides(self, **kw) -> "EngineConfig":
        """A copy with the given fields replaced (frozen-safe)."""
        return replace(self, **kw)


_FIELD_NAMES = tuple(f.name for f in fields(EngineConfig))
_legacy_warned = False


def resolve_engine_config(config: Optional[EngineConfig],
                          legacy_kwargs: dict) -> EngineConfig:
    """Fold a (config, legacy kwargs) construction call into ONE config.

    * config given, no legacy kwargs — the modern path, returned as-is.
    * legacy kwargs only — folded into an ``EngineConfig``; a
      ``DeprecationWarning`` fires ONCE per process (every legacy kwarg
      has an identically-named config field, so migration is mechanical).
    * both — an error: silently merging two sources of truth is how
      config drift starts.
    * unknown kwarg — ``TypeError``, same contract as a real signature.
    """
    global _legacy_warned
    unknown = set(legacy_kwargs) - set(_FIELD_NAMES)
    if unknown:
        raise TypeError(
            f"unknown engine option(s) {sorted(unknown)}; valid fields: "
            f"{_FIELD_NAMES}")
    if config is not None:
        if legacy_kwargs:
            raise ValueError(
                "pass construction knobs through config=EngineConfig(...) "
                f"OR as legacy kwargs, not both (got config plus "
                f"{sorted(legacy_kwargs)})")
        if not isinstance(config, EngineConfig):
            raise TypeError(f"config must be an EngineConfig, got "
                            f"{type(config).__name__}")
        return config
    if legacy_kwargs and not _legacy_warned:
        _legacy_warned = True
        warnings.warn(
            "constructing LPUEngine from loose kwargs is deprecated; "
            "pass config=EngineConfig(...) (fields are named identically)",
            DeprecationWarning, stacklevel=3)
    return EngineConfig(**legacy_kwargs)
