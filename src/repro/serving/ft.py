"""Serving fault-tolerance policies: detection, chaos, and clocks.

The ROADMAP's fleet-scale story ("millions of users") dies on the first
wedged ring unless failure is a first-class input, so the policies that
used to sit unused beside the training driver live here now, driven by
per-ring *serving* telemetry:

* :class:`StragglerMonitor` — EWMA/σ step-time outlier detection over
  an engine's measured ``step()`` wall time.  ``mu0`` seeds the mean
  from the analytic latency model
  (:func:`repro.core.latency_model.step_time_prior`) so detection is
  armed before the warmup window closes.
* :class:`HeartbeatTracker` — per-ring liveness with a configurable
  timeout and an **injected clock** (any ``() -> float`` callable;
  defaults to ``time.time``), so liveness transitions are testable
  without sleeping.  :meth:`HeartbeatTracker.revive` returns a rebuilt
  ring to rotation.
* :class:`FailureInjector` — deterministic chaos.  The legacy
  ``fail_at_steps`` / :meth:`FailureInjector.maybe_fail` contract (raise
  once at a configured step) is kept for the training driver; serving
  uses :func:`parse_chaos` specs and :meth:`FailureInjector.fire`,
  which returns each configured :class:`ChaosEvent` exactly once when
  its (step, ring) comes up.
* :class:`RingFailure` — the structured exception an engine raises when
  it detects (or chaos injects) a ring-level fault;
  ``MultiRingEngine.step`` catches it and runs the drain → migrate →
  rebuild cycle instead of crashing the fleet.
* :class:`ManualClock` — a deterministic clock for liveness tests and
  chaos runs: ``clock()`` reads it, ``advance(dt)`` moves it.

Recovery is *recompute*-shaped, like preemption: a failed ring's
in-flight requests resume from ``Request.resume_tokens()`` on a
surviving ring, so greedy token streams are bit-identical to a
fault-free run (tests/test_fault_tolerance.py holds that gate).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclass
class Event:
    kind: str            # 'straggler' | 'worker_failed' | 'rebalance' |
                         # 'ring_failed' | 'ring_rebuilt' |
                         # 'request_failed' | 'request_rejected'
    step: int
    detail: dict


CHAOS_KINDS = ("ring", "stall", "nan", "corrupt")


@dataclass(frozen=True)
class ChaosEvent:
    """One deterministic fault: ``kind`` fires at engine step ``step``
    on ring ``ring`` (and never again).

    * ``ring``    — the engine raises :class:`RingFailure` outright
                    (a crashed/partitioned ring).
    * ``stall``   — the engine stops making progress (a wedged window);
                    only the heartbeat timeout can clear it.
    * ``nan``     — the next decode program's logits are poisoned with
                    NaN *on device*, exercising the finite-logits guard.
    * ``corrupt`` — a resident KV pool block is overwritten with NaN,
                    exercising the same guard one hop downstream.
    """
    kind: str
    step: int
    ring: int = 0


def parse_chaos(spec: str) -> List[ChaosEvent]:
    """Parse a ``--chaos`` spec: comma-separated ``kind@step[:ring]``.

    Example: ``"ring@3,stall@5:1,nan@7,corrupt@9:0"`` — a ring failure
    at step 3 of ring 0, a stalled window at step 5 of ring 1, NaN
    logits at step 7 of ring 0, a corrupted pool block at step 9 of
    ring 0.  Steps count an engine's own ``step()`` calls from 1.
    """
    events: List[ChaosEvent] = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        try:
            kind, rest = part.split("@", 1)
            if ":" in rest:
                step_s, ring_s = rest.split(":", 1)
            else:
                step_s, ring_s = rest, "0"
            step, ring = int(step_s), int(ring_s)
        except ValueError:
            raise ValueError(
                f"bad chaos event {part!r}: expected kind@step[:ring]")
        if kind not in CHAOS_KINDS:
            raise ValueError(
                f"bad chaos kind {kind!r}: expected one of {CHAOS_KINDS}")
        if step < 1 or ring < 0:
            raise ValueError(
                f"bad chaos event {part!r}: step >= 1, ring >= 0")
        events.append(ChaosEvent(kind, step, ring))
    return events


class RingFailure(RuntimeError):
    """A ring-level fault detected (or injected) inside an engine step.

    Carries enough structure for the supervisor's recovery path and the
    event log: ``reason`` ('injected_ring_failure' | 'nan_logits' |
    'heartbeat_timeout' | 'straggler'), the engine step and ring id,
    and a free-form ``detail`` dict.
    """

    def __init__(self, reason: str, step: int = 0, ring: int = 0,
                 detail: Optional[dict] = None):
        super().__init__(f"[ring {ring}] {reason} at step {step}")
        self.reason = reason
        self.step = step
        self.ring = ring
        self.detail = detail or {}


class ManualClock:
    """A deterministic injectable clock: ``clock()`` reads seconds,
    ``advance(dt)`` moves time forward.  Chaos runs and liveness tests
    use it so a 30 s heartbeat timeout never means 30 s of wall time."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


class StragglerMonitor:
    """EWMA + variance step-time tracking; flags > mu + k*sigma.

    ``mu0`` (optional) seeds the running mean from a prior — serving
    seeds it with the analytic latency model's step-time estimate
    (:func:`repro.core.latency_model.step_time_prior`) so the very
    first slow step can already be judged against *something* instead
    of silently becoming the baseline.
    """

    def __init__(self, alpha: float = 0.1, k_sigma: float = 3.0,
                 warmup: int = 5, cooldown: int = 20,
                 min_slack: float = 0.25, mu0: Optional[float] = None):
        self.alpha = alpha
        self.k = k_sigma
        self.warmup = warmup
        self.cooldown = cooldown
        self.min_slack = min_slack     # never flag < (1+slack)*mu drift
        self.mu: Optional[float] = mu0
        self.var: float = 0.0
        self.n = 0
        self._last_flag = -10 ** 9
        self.events: List[Event] = []

    def record(self, step: int, dt: float) -> Optional[Event]:
        self.n += 1
        if self.mu is None:
            self.mu = dt
            return None
        thresh = max(self.mu + self.k * math.sqrt(self.var + 1e-12),
                     self.mu * (1.0 + self.min_slack))
        flagged = (self.n > self.warmup and dt > thresh
                   and step - self._last_flag >= self.cooldown)
        # EWMA update (skip outliers so one straggler doesn't poison mu)
        if not flagged:
            d = dt - self.mu
            self.mu += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        if flagged:
            self._last_flag = step
            ev = Event("straggler", step,
                       {"dt": dt, "mu": self.mu, "thresh": thresh})
            self.events.append(ev)
            return ev
        return None


class HeartbeatTracker:
    """Per-worker (per-ring) liveness with an injected clock.

    ``clock`` is any ``() -> float``; explicit ``now=`` arguments win
    over it call by call (the pre-existing test contract).  A worker
    whose last beat is older than ``timeout_s`` is reported failed by
    :meth:`check` exactly once; :meth:`revive` clears the failed mark
    and restamps the beat — the rebuilt-ring half of drain/rebuild.
    """

    def __init__(self, n_workers: int, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.time):
        self.timeout = timeout_s
        self.clock = clock
        self.last: Dict[int, float] = {i: clock()
                                       for i in range(n_workers)}
        self.failed: List[int] = []

    def beat(self, worker: int, now: Optional[float] = None):
        self.last[worker] = now if now is not None else self.clock()

    def check(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else self.clock()
        newly = [w for w, t in self.last.items()
                 if now - t > self.timeout and w not in self.failed]
        self.failed.extend(newly)
        return newly

    def revive(self, worker: int, now: Optional[float] = None):
        """Return a rebuilt worker to rotation: clear its failed mark
        and restamp its beat so it is judged fresh from now on."""
        if worker in self.failed:
            self.failed.remove(worker)
        self.beat(worker, now)


class FailureInjector:
    """Deterministic chaos, two contracts:

    * legacy (training driver): ``fail_at_steps`` raises RuntimeError
      the first time each configured step is reached
      (:meth:`maybe_fail`).
    * serving: ``chaos`` is a list of :class:`ChaosEvent`; :meth:`fire`
      returns each event exactly once when its (step, ring) matches —
      the caller decides what the kind means.  The fired-set survives
      an engine rebuild, so a replayed step number cannot re-fire.
    """

    def __init__(self, fail_at_steps: Sequence[int] = (),
                 chaos: Sequence[ChaosEvent] = ()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()
        self.chaos = list(chaos)
        self._chaos_fired: set = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"[chaos] injected failure at step {step}")

    def fire(self, step: int, ring: int = 0) -> List[ChaosEvent]:
        """Chaos events configured for (step, ring), each at most once."""
        out: List[ChaosEvent] = []
        for idx, ev in enumerate(self.chaos):
            if ev.step == step and ev.ring == ring \
                    and idx not in self._chaos_fired:
                self._chaos_fired.add(idx)
                out.append(ev)
        return out
