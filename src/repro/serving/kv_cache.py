"""Paged KV-cache: a shared pool of fixed-size blocks + per-request tables.

The dense engine allocated a ``(slots, max_seq)`` KV cache per layer, so
HBM scales with the *worst-case* sequence length times the slot count —
the paper's "batch mode" datacenter scenario (many users, short typical
prompts) wastes most of it.  Here KV lives in a pool of LANE-aligned
fixed-size blocks; each request owns only the blocks its tokens actually
fill, tracked by a block table (logical block -> physical block id).

Block id 0 is reserved as the **null block**: table entries past a
request's used length point at it, padded prefill tokens are written to
it, and inactive decode slots scatter into it — reads are masked by the
valid-length anyway, so it absorbs all don't-care traffic without
branching inside jit.

Device-side helpers (:func:`scatter_prefill_pages`,
:func:`scatter_prefill_dense`) copy a freshly prefiled batch=1 cache into
the shared pool / the dense slot cache; the engine jits them per bucket.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

LANE = 128

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# host-side accounting
# ---------------------------------------------------------------------------

def bucket_for(n: int, max_seq: int, min_bucket: int = 16) -> int:
    """Pad a prompt length to its power-of-two prefill bucket.

    The prefill jit re-traces per *shape*, so padding to pow2 buckets
    bounds the trace count by O(log2 max_seq) instead of one per
    distinct prompt length.
    """
    if n > max_seq:
        raise ValueError(f"prompt length {n} exceeds max_seq {max_seq}")
    b = max(min_bucket, 1)
    while b < n:
        b *= 2
    return min(b, max_seq)


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of KV blocks needed to hold ``n_tokens``."""
    return max(1, math.ceil(n_tokens / block_size))


def per_rank_block_bytes(n_layers: int, kv_heads_per_rank: int,
                         d_head: int, block_size: int,
                         dtype_bytes: int = 2) -> int:
    """Bytes ONE pool block occupies on ONE ring rank (K and V).

    Under tensor parallelism the pool's stored-head dim is sharded over
    the model ring, so each rank holds ``kv_heads_per_rank`` of every
    block — pool HBM divides by tp, which is what lets a tp-wide ring
    serve proportionally longer contexts at a fixed per-chip budget.
    """
    return 2 * n_layers * block_size * kv_heads_per_rank * d_head \
        * dtype_bytes


def pool_blocks_for_budget(budget_bytes: int, block_bytes: int) -> int:
    """Largest pool (incl. the null block) fitting a per-rank HBM budget.

    ``block_bytes`` is the per-rank footprint from
    :func:`per_rank_block_bytes`.  Raises when the budget cannot hold the
    null block plus one allocatable block — a pool that small can never
    admit a request.
    """
    n = int(budget_bytes // max(block_bytes, 1))
    if n < 2:
        raise ValueError(
            f"KV budget {budget_bytes}B holds {n} blocks of "
            f"{block_bytes}B/rank; need >= 2 (null block + 1)")
    return n


class BlockPool:
    """Free-list allocator over the shared block pool.

    Block 0 is reserved (null block) and never handed out.  ``alloc``
    returns None when the request cannot be satisfied — the scheduler
    turns that into queueing or preemption, never a partial grant.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (one is the null block)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if not 0 < b < self.num_blocks:
                raise ValueError(f"bad block id {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)

    def used_bytes(self, bytes_per_block: int) -> int:
        return self.num_used * bytes_per_block


# ---------------------------------------------------------------------------
# device-side pool plumbing (pure functions; the engine jits them)
# ---------------------------------------------------------------------------

def cache_bytes(cache: Params) -> int:
    """Total bytes of a KV cache pytree (dense slot cache or block pool)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def scatter_prefill_pages(cache: Params, prefill_cache: Params,
                          table: jax.Array) -> Params:
    """Copy a batch=1 prefill cache into the shared block pool.

    cache:         {lj: {"k": (n_sb, N, bs, gp, dh), "v": ...}}
    prefill_cache: {lj: {"k": (n_sb, 1, S, gp, dh), "v": ...}} with S a
                   multiple of bs
    table:         (S // bs,) physical block ids; pad entries point at the
                   null block 0, which absorbs the padded tokens' KV.
    """
    out: Params = {}
    for lj, c in cache.items():
        pc = prefill_cache[lj]
        layer: Params = {}
        for key in ("k", "v"):
            pg, dn = c[key], pc[key]
            n_sb, _, bs = pg.shape[0], pg.shape[1], pg.shape[2]
            S = dn.shape[2]
            nb = S // bs
            chunks = dn[:, 0].reshape((n_sb, nb, bs) + dn.shape[3:])
            layer[key] = pg.at[:, table].set(chunks.astype(pg.dtype))
        out[lj] = layer
    return out


def scatter_chunk_rows(pages: jax.Array, rows: jax.Array,
                       block_table: jax.Array, positions: jax.Array,
                       valid: jax.Array) -> jax.Array:
    """Positionwise scatter of ONE prefill chunk into the block pool.

    The monolithic prefill path materializes a whole batch=1 cache and
    copies it block-aligned (:func:`scatter_prefill_pages`); a *chunk*
    of a partially-prefilled prompt instead lands token by token — its
    start offset is arbitrary (chunks need not align to block
    boundaries), so each row resolves its own (physical block, offset)
    through the request's table, exactly like the decode path's
    one-token scatter.

    pages:       (N, bs, G, dh) one layer of the shared pool
    rows:        (C, G, dh) the chunk's freshly computed K (or V)
    block_table: (T,) the request's physical block ids
    positions:   (C,) absolute token positions of the chunk rows
    valid:       (C,) bool; padded rows are routed to the null block 0
                 (absorbed don't-care traffic, masked on read).
    """
    bs = pages.shape[1]
    T = block_table.shape[0]
    idx = jnp.clip(positions // bs, 0, T - 1)
    blk = jnp.where(valid, block_table[idx], 0)
    off = positions % bs
    return pages.at[blk, off].set(rows.astype(pages.dtype))


def scatter_prefill_dense(cache: Params, prefill_cache: Params,
                          slot: jax.Array) -> Params:
    """Copy a batch=1 prefill cache into one slot of the dense cache.

    KV leaves ("k"/"v") scatter along the sequence prefix of the slot;
    recurrent-state leaves (mamba conv/ssm, rwkv shift/wkv) replace the
    slot's state wholesale.
    """
    out: Params = {}
    for lj, c in cache.items():
        pc = prefill_cache[lj]
        layer: Params = {}
        for key, tgt in c.items():
            dn = pc[key]
            if key in ("k", "v"):
                layer[key] = jax.lax.dynamic_update_slice(
                    tgt, dn.astype(tgt.dtype)[:, 0:1],
                    (0, slot, 0) + (0,) * (tgt.ndim - 3))
            else:
                layer[key] = jax.lax.dynamic_update_slice(
                    tgt, dn.astype(tgt.dtype)[:, 0:1],
                    (0, slot) + (0,) * (tgt.ndim - 2))
        out[lj] = layer
    return out
