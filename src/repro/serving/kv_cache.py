"""Paged KV-cache: a shared pool of fixed-size blocks + per-request tables.

The dense engine allocated a ``(slots, max_seq)`` KV cache per layer, so
HBM scales with the *worst-case* sequence length times the slot count —
the paper's "batch mode" datacenter scenario (many users, short typical
prompts) wastes most of it.  Here KV lives in a pool of LANE-aligned
fixed-size blocks; each request owns only the blocks its tokens actually
fill, tracked by a block table (logical block -> physical block id).

Block id 0 is reserved as the **null block**: table entries past a
request's used length point at it, padded prefill tokens are written to
it, and inactive decode slots scatter into it — reads are masked by the
valid-length anyway, so it absorbs all don't-care traffic without
branching inside jit.

Device-side helpers (:func:`scatter_prefill_pages`,
:func:`scatter_prefill_dense`) copy a freshly prefiled batch=1 cache into
the shared pool / the dense slot cache; the engine jits them per bucket.
"""
from __future__ import annotations

import math
import zlib
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

LANE = 128

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# host-side accounting
# ---------------------------------------------------------------------------

def bucket_for(n: int, max_seq: int, min_bucket: int = 16) -> int:
    """Pad a prompt length to its power-of-two prefill bucket.

    The prefill jit re-traces per *shape*, so padding to pow2 buckets
    bounds the trace count by O(log2 max_seq) instead of one per
    distinct prompt length.
    """
    if n > max_seq:
        raise ValueError(f"prompt length {n} exceeds max_seq {max_seq}")
    b = max(min_bucket, 1)
    while b < n:
        b *= 2
    return min(b, max_seq)


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of KV blocks needed to hold ``n_tokens``."""
    return max(1, math.ceil(n_tokens / block_size))


def per_rank_block_bytes(n_layers: int, kv_heads_per_rank: int,
                         d_head: int, block_size: int,
                         dtype_bytes: int = 2,
                         scale_bytes: int = 0) -> int:
    """Bytes ONE pool block occupies on ONE ring rank (K and V).

    Under tensor parallelism the pool's stored-head dim is sharded over
    the model ring, so each rank holds ``kv_heads_per_rank`` of every
    block — pool HBM divides by tp, which is what lets a tp-wide ring
    serve proportionally longer contexts at a fixed per-chip budget.

    ``scale_bytes`` is the per-(row, head) side-array cost of a
    quantized pool (``KVPrecision.scale_itemsize``), so budget sizing
    (``--kv-budget-mb``) stays honest about the scales it must co-locate
    — an int8 pool admits ~2x the fp16 blocks, not exactly 2x.
    """
    return 2 * n_layers * block_size * kv_heads_per_rank \
        * (d_head * dtype_bytes + scale_bytes)


def pool_blocks_for_budget(budget_bytes: int, block_bytes: int) -> int:
    """Largest pool (incl. the null block) fitting a per-rank HBM budget.

    ``block_bytes`` is the per-rank footprint from
    :func:`per_rank_block_bytes`.  Raises when the budget cannot hold the
    null block plus one allocatable block — a pool that small can never
    admit a request.
    """
    n = int(budget_bytes // max(block_bytes, 1))
    if n < 2:
        raise ValueError(
            f"KV budget {budget_bytes}B holds {n} blocks of "
            f"{block_bytes}B/rank; need >= 2 (null block + 1)")
    return n


class BlockPool:
    """Refcounting allocator over the shared block pool.

    Block 0 is reserved (null block) and never handed out.  ``alloc``
    returns None when the request cannot be satisfied — the scheduler
    turns that into queueing or preemption, never a partial grant.

    Blocks carry a **refcount** so a prefix-cache hit can map the same
    physical block into several block tables (:meth:`share`); ``free``
    decrements and only returns the block to circulation at zero.  A
    block *registered* in the prefix index (:meth:`mark_cached`) is not
    recycled eagerly at refcount zero — it parks in an LRU and its KV
    stays valid for future hits; ``alloc`` drains the plain free list
    first and only then evicts LRU-oldest cached blocks, firing
    ``on_evict`` so the index drops its entries (counted in
    ``evicted_blocks``).
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (one is the null block)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.ref: List[int] = [0] * num_blocks
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._cached: set = set()
        self.on_evict: Optional[Callable[[int], None]] = None
        self.evicted_blocks = 0

    @property
    def num_free(self) -> int:
        return len(self._free) + len(self._lru)

    @property
    def num_used(self) -> int:
        return (self.num_blocks - 1) - self.num_free

    def can_alloc(self, n: int) -> bool:
        return n <= self.num_free

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > self.num_free:
            return None
        out: List[int] = []
        for _ in range(n):
            if self._free:
                b = self._free.pop()
            else:
                # evict the least-recently-used cached block; its KV is
                # reusable only via the index, which on_evict invalidates
                b, _ = self._lru.popitem(last=False)
                self._cached.discard(b)
                self.evicted_blocks += 1
                if self.on_evict is not None:
                    self.on_evict(b)
            self.ref[b] = 1
            out.append(b)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        """Drop one reference per listed block.

        Raises on block ids outside the pool, on the null block, and on
        blocks whose refcount is already zero (double free, or freeing a
        never-allocated id) — once blocks are shared between tables a
        silent bad free corrupts another request's KV.
        """
        for b in blocks:
            if not 0 < b < self.num_blocks:
                raise ValueError(f"bad block id {b}")
            if self.ref[b] <= 0:
                raise ValueError(
                    f"double free (or free of never-allocated) block {b}")
            self.ref[b] -= 1
            if self.ref[b] == 0:
                if b in self._cached:
                    self._lru[b] = None       # most-recently-used end
                else:
                    self._free.append(b)

    def share(self, blocks: Sequence[int]) -> None:
        """Take an extra reference on each block (a prefix-cache hit
        mapping cached blocks into a new table).  Blocks parked in the
        LRU (refcount 0, index-reachable) are revived; live blocks just
        gain a reference."""
        for b in blocks:
            if not 0 < b < self.num_blocks:
                raise ValueError(f"bad block id {b}")
            if self.ref[b] == 0:
                if b not in self._lru:
                    raise ValueError(
                        f"share of free, un-cached block {b}")
                del self._lru[b]
            self.ref[b] += 1

    def mark_cached(self, block: int) -> None:
        """Flag a live block as registered in the prefix index: at
        refcount zero it parks in the LRU instead of the free list."""
        if self.ref[block] <= 0:
            raise ValueError(f"mark_cached of free block {block}")
        self._cached.add(block)

    def touch(self, blocks: Sequence[int]) -> None:
        """Refresh LRU recency for cached blocks hit while parked."""
        for b in blocks:
            if b in self._lru:
                self._lru.move_to_end(b)

    def used_bytes(self, bytes_per_block: int) -> int:
        return self.num_used * bytes_per_block


def assert_pool_balanced(pool: BlockPool,
                         prefix: Optional["PrefixCache"] = None) -> None:
    """Refcount-balance invariant after a full drain (zero live requests).

    Every block's refcount must be zero (cached blocks park in the LRU
    at refcount zero, so this holds for them too) and the free + LRU
    lists must account for every non-null block.  With a prefix cache,
    every index entry must point at an LRU-parked block.  Raises
    ``AssertionError`` naming the leaked block ids — the gate the
    fault-tolerance tests and serving_bench's chaos row hold after a
    ring drain/rebuild cycle (a rebuild that leaked references would
    silently shrink the pool every failure).
    """
    leaked = [b for b in range(1, pool.num_blocks) if pool.ref[b] != 0]
    if leaked:
        raise AssertionError(
            f"leaked blocks (nonzero refcount after drain): {leaked}")
    if pool.num_used != 0:
        raise AssertionError(
            f"pool accounting imbalance: {pool.num_used} blocks used "
            "after drain (free + LRU lists lost track of them)")
    if prefix is not None:
        stray = [b for b in prefix._by_block if b not in pool._lru]
        if stray:
            raise AssertionError(
                f"prefix index entries for non-parked blocks: {stray}")


# ---------------------------------------------------------------------------
# prefix cache: block-aligned hash index over token prefixes
# ---------------------------------------------------------------------------

def _chain_hash(prev: int, tokens: Sequence[int]) -> int:
    """crc32-chained hash of one block's tokens, keyed by the hash of
    everything before it.  crc32 (not ``hash``) so the index is
    deterministic across processes — the tp=2 parity tests replay the
    same trace in subprocesses."""
    data = prev.to_bytes(4, "little") + \
        b"".join(int(t).to_bytes(4, "little", signed=True) for t in tokens)
    return zlib.crc32(data)


class PrefixCache:
    """Block-aligned prefix index over the pool.

    Maps the chained hash of each *full* block of prompt tokens to the
    physical block holding its KV.  Consulted at admission: the longest
    chain of consecutive full-block hits is mapped (refcounted) into
    the new request's table and only the tail is prefilled.  One index
    entry per physical block; eviction from the pool's LRU invalidates
    the entry via ``pool.on_evict``.

    The index never has to invalidate on writes: a registered block's
    contents are immutable — any KV write into a block with refcount > 1
    goes through copy-on-write, and a sole owner appending into its
    registered tail block would first diverge from the hashed token
    string only at positions past the hashed span (full blocks hash all
    ``block_size`` tokens, so appends always land in later blocks).
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.block_size = pool.block_size
        self._by_hash: Dict[int, int] = {}
        self._by_block: Dict[int, int] = {}
        self.lookups = 0
        self.hits = 0
        self.hit_blocks = 0
        self.tokens_saved = 0
        pool.on_evict = self._evict

    def _evict(self, block: int) -> None:
        h = self._by_block.pop(block, None)
        if h is not None and self._by_hash.get(h) == block:
            del self._by_hash[h]

    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``.

        Returns ``(shared_blocks, cached_tokens)``.  ``cached_tokens``
        is capped at ``len(tokens) - 1`` so at least one tail token is
        always prefilled — prefill produces the logits row the first
        sampled token comes from, and a fully resident prompt would
        leave nothing to run.  When that cap lands mid-block, the final
        shared block is the one a later divergent append copy-on-writes.
        """
        self.lookups += 1
        bs = self.block_size
        n = len(tokens)
        hit: List[int] = []
        h = 0
        for i in range(n // bs):
            h = _chain_hash(h, tokens[i * bs:(i + 1) * bs])
            b = self._by_hash.get(h)
            if b is None:
                break
            hit.append(b)
        cached = min(len(hit) * bs, n - 1)
        if cached <= 0:
            return [], 0
        shared = hit[:blocks_for(cached, bs)]
        self.pool.touch(shared)
        return shared, cached

    def peek(self, tokens: Sequence[int]) -> int:
        """Stats-neutral probe: prefix tokens of ``tokens`` this index
        could map right now, with :meth:`match`'s same ``n - 1`` cap.

        Used by the fleet's prefix-affinity router to ask every ring
        "how much of this prompt do you already own?" BEFORE choosing
        one — so it must not count as a lookup (hit-rate telemetry
        stays an admission-path property) and must not ``touch`` the
        LRU (probing all rings would otherwise rejuvenate blocks on
        rings the request never lands on).
        """
        bs = self.block_size
        n = len(tokens)
        hit = 0
        h = 0
        for i in range(n // bs):
            h = _chain_hash(h, tokens[i * bs:(i + 1) * bs])
            if self._by_hash.get(h) is None:
                break
            hit += 1
        return max(min(hit * bs, n - 1), 0)

    def note_hit(self, shared: Sequence[int], cached: int) -> None:
        """Count a hit that actually admitted (the scheduler calls this
        after the tail allocation succeeds, so a request that waits and
        retries is not double-counted)."""
        self.hits += 1
        self.hit_blocks += len(shared)
        self.tokens_saved += cached

    def register(self, tokens: Sequence[int], blocks: Sequence[int]) -> None:
        """Index every full block of a freshly prefilled prompt.

        First-wins on hash collision at the index level; the physical
        block keeps exactly one index entry (re-registering a shared
        block that already carries its own hash is a no-op)."""
        bs = self.block_size
        h = 0
        for i in range(len(tokens) // bs):
            h = _chain_hash(h, tokens[i * bs:(i + 1) * bs])
            if i >= len(blocks):
                break
            b = blocks[i]
            if self._by_hash.get(h) == b:
                continue                       # already indexed (shared hit)
            if h in self._by_hash or b in self._by_block:
                continue                       # first-wins; keep 1:1 mapping
            self._by_hash[h] = b
            self._by_block[b] = h
            self.pool.mark_cached(b)


def copy_pool_block(cache: Params, src: jax.Array, dst: jax.Array) -> Params:
    """Copy one physical block's KV across the whole pool pytree
    (copy-on-write: a shared block is duplicated before the writer's
    next scatter).  ``src``/``dst`` are int32 scalars so one jitted
    trace serves every copy."""
    return jax.tree.map(lambda pg: pg.at[:, dst].set(pg[:, src]), cache)


# ---------------------------------------------------------------------------
# quantized storage: absmax row quantization + the pool's scale side-arrays
# ---------------------------------------------------------------------------

def qmax_for_dtype(dtype) -> float:
    """Symmetric clip bound of a quantized pool leaf dtype."""
    d = jnp.dtype(dtype)
    if d == jnp.int8:
        return 127.0
    if d.name == "float8_e4m3fn":
        return 448.0
    raise ValueError(f"not a quantized KV storage dtype: {d.name}")


def quantize_kv_rows(rows: jax.Array, store_dtype,
                     scale_dtype) -> Tuple[jax.Array, jax.Array]:
    """Symmetric absmax quantization of KV rows along the head dim.

    rows: (..., dh) float K (or V) rows.  Returns ``(q, scales)`` with
    ``q`` shaped like ``rows`` in ``store_dtype`` and ``scales`` shaped
    ``rows.shape[:-1]`` in ``scale_dtype`` — one scale per stored token
    row per kv head, the side array the pool carries next to the values.
    All-zero rows get scale 0 (dequantizes to exact zeros, the null
    block's contract); the divisor is made safe so they never NaN.
    """
    qmax = qmax_for_dtype(store_dtype)
    x = rows.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = amax / qmax
    y = x / jnp.where(scale > 0, scale, 1.0)[..., None]
    if jnp.dtype(store_dtype) == jnp.int8:
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(store_dtype)
    else:
        q = jnp.clip(y, -qmax, qmax).astype(store_dtype)
    return q, scale.astype(scale_dtype)


def dequantize_kv(q: jax.Array, scales: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_kv_rows` (fp32 out)."""
    return q.astype(jnp.float32) * scales.astype(jnp.float32)[..., None]


# ---------------------------------------------------------------------------
# device-side pool plumbing (pure functions; the engine jits them)
# ---------------------------------------------------------------------------

def cache_bytes(cache: Params) -> int:
    """Total bytes of a KV cache pytree (dense slot cache or block pool).

    Scale side-arrays of a quantized pool are ordinary pytree leaves, so
    the reported bytes include them — pool accounting stays honest."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def scatter_prefill_pages(cache: Params, prefill_cache: Params,
                          table: jax.Array) -> Params:
    """Copy a batch=1 prefill cache into the shared block pool.

    cache:         {lj: {"k": (n_sb, N, bs, gp, dh), "v": ...}}
    prefill_cache: {lj: {"k": (n_sb, 1, S, gp, dh), "v": ...}} with S a
                   multiple of bs
    table:         (S // bs,) physical block ids; pad entries point at the
                   null block 0, which absorbs the padded tokens' KV.

    A quantized pool carries ``k_scale``/``v_scale`` side-array leaves
    ((n_sb, N, bs, gp)); prefill stays full-precision in its bucket
    cache and quantization happens HERE, at pool-write time, so the
    quantized path shares one numerical contract with the chunked /
    speculative row scatters.
    """
    out: Params = {}
    for lj, c in cache.items():
        pc = prefill_cache[lj]
        layer: Params = {}
        for key in ("k", "v"):
            pg, dn = c[key], pc[key]
            n_sb, _, bs = pg.shape[0], pg.shape[1], pg.shape[2]
            S = dn.shape[2]
            nb = S // bs
            chunks = dn[:, 0].reshape((n_sb, nb, bs) + dn.shape[3:])
            skey = key + "_scale"
            if skey in c:
                spg = c[skey]
                q, s = quantize_kv_rows(chunks, pg.dtype, spg.dtype)
                layer[key] = pg.at[:, table].set(q)
                layer[skey] = spg.at[:, table].set(s)
            else:
                layer[key] = pg.at[:, table].set(chunks.astype(pg.dtype))
        out[lj] = layer
    return out


def scatter_chunk_rows(pages: jax.Array, rows: jax.Array,
                       block_table: jax.Array, positions: jax.Array,
                       valid: jax.Array) -> jax.Array:
    """Positionwise scatter of ONE prefill chunk into the block pool.

    The monolithic prefill path materializes a whole batch=1 cache and
    copies it block-aligned (:func:`scatter_prefill_pages`); a *chunk*
    of a partially-prefilled prompt instead lands token by token — its
    start offset is arbitrary (chunks need not align to block
    boundaries), so each row resolves its own (physical block, offset)
    through the request's table, exactly like the decode path's
    one-token scatter.

    pages:       (N, bs, G, dh) one layer of the shared pool
    rows:        (C, G, dh) the chunk's freshly computed K (or V)
    block_table: (T,) the request's physical block ids
    positions:   (C,) absolute token positions of the chunk rows
    valid:       (C,) bool; padded rows are routed to the null block 0
                 (absorbed don't-care traffic, masked on read).

    Shapes generalize over the trailing dims: a quantized pool's scale
    side-array ((N, bs, G) pages, (C, G) rows) scatters through the
    SAME function, so values and scales stay row-consistent by
    construction.
    """
    bs = pages.shape[1]
    T = block_table.shape[0]
    idx = jnp.clip(positions // bs, 0, T - 1)
    blk = jnp.where(valid, block_table[idx], 0)
    off = positions % bs
    return pages.at[blk, off].set(rows.astype(pages.dtype))


def scatter_spec_rows(pages: jax.Array, rows: jax.Array,
                      block_tables: jax.Array, positions: jax.Array,
                      valid: jax.Array) -> jax.Array:
    """Per-query scatter for a speculative verify window.

    The verify pass flattens every slot's (k drafts + 1 last token) into
    a batch of single-token queries, each with its OWN block table — the
    per-query generalization of :func:`scatter_chunk_rows` (whose rows
    all share one request's table).  Rejected drafts are never
    un-written: their rows sit past the slot's resident length, so every
    later read masks them out and the next window overwrites them
    idempotently (logical rollback, zero device work).

    pages:        (N, bs, G, dh) one layer of the shared pool
    rows:         (Q, G, dh) the verify queries' freshly computed K (V)
    block_tables: (Q, T) each query's physical block ids
    positions:    (Q,) absolute token positions
    valid:        (Q,) bool; idle-slot rows route to the null block 0.
    """
    bs = pages.shape[1]
    T = block_tables.shape[1]
    idx = jnp.clip(positions // bs, 0, T - 1)
    blk = jnp.where(valid,
                    jnp.take_along_axis(block_tables, idx[:, None],
                                        1)[:, 0], 0)
    off = positions % bs
    return pages.at[blk, off].set(rows.astype(pages.dtype))


def scatter_prefill_dense(cache: Params, prefill_cache: Params,
                          slot: jax.Array) -> Params:
    """Copy a batch=1 prefill cache into one slot of the dense cache.

    KV leaves ("k"/"v") scatter along the sequence prefix of the slot;
    recurrent-state leaves (mamba conv/ssm, rwkv shift/wkv) replace the
    slot's state wholesale.
    """
    out: Params = {}
    for lj, c in cache.items():
        pc = prefill_cache[lj]
        layer: Params = {}
        for key, tgt in c.items():
            dn = pc[key]
            if key in ("k", "v"):
                layer[key] = jax.lax.dynamic_update_slice(
                    tgt, dn.astype(tgt.dtype)[:, 0:1],
                    (0, slot, 0) + (0,) * (tgt.ndim - 3))
            else:
                layer[key] = jax.lax.dynamic_update_slice(
                    tgt, dn.astype(tgt.dtype)[:, 0:1],
                    (0, slot) + (0,) * (tgt.ndim - 2))
        out[lj] = layer
    return out
