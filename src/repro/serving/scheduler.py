"""Request-level scheduler: admission, bucketed prefill, preemption.

Sits between the engine's ``submit()`` queue and the fixed decode batch of
``slots``.  All policy is host-side (no jax) — the device programs only
ever see a full slot batch plus replicated block tables, which is what
lets the same scheduler drive the single-device engine and the
ring-parallel ``shard_map`` engine unchanged (the paper's host/LPU
split: the driver sequences work, the accelerators never branch).

Five policies live here:

* **Admission** — FIFO: a queued request is admitted when a slot is free
  AND (paged mode) the block pool can cover its prompt.  Prompt lengths
  are padded to power-of-two buckets (:func:`repro.serving.kv_cache.
  bucket_for`) so the prefill jit traces O(log2 max_seq) times total.
* **Chunked admission** (``admit_next(chunk=C)``) — the engine's
  ``--prefill-chunk`` interleave admits against the FIRST chunk's
  blocks only; the prompt becomes resident C tokens per engine step
  (:meth:`Scheduler.chunk_reserve` grows the block set per chunk,
  never preempting while decode streams can still free blocks), and
  the sequence joins decode windows only once fully prefilled
  (``SeqSlot.prefilling``).
* **Growth** — before every decode step each decode-ready sequence must
  own the block its next token lands in; blocks are allocated lazily
  one at a time as sequences cross block boundaries.
* **Preemption** — when growth cannot be satisfied, the most recently
  admitted *other* sequence is evicted (recompute-style: its blocks are
  freed, it re-enters the queue front, and its tokens so far are
  re-prefiled on re-admission).  LIFO victim choice protects the oldest
  requests' latency, mirroring vLLM's recompute preemption.
* **Per-ring admission** — with reconfigurable sub-rings (paper C3, one
  engine per sub-ring), :class:`RingRouter` assigns each incoming
  request to the ring with the fewest outstanding tokens
  (:meth:`Scheduler.pending_tokens`), keeping tenant rings balanced
  without any cross-ring coupling once a request is placed.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence, Tuple

from repro.serving.kv_cache import (BlockPool, PrefixCache, blocks_for,
                                    bucket_for)


@dataclass
class SeqSlot:
    """An active request's per-slot serving state.

    ``pos`` always means *tokens resident in KV* — for a decode-ready
    sequence that is prompt + generated-so-far; for a sequence admitted
    under chunked prefill it starts at 0 and advances one chunk at a
    time (``prefilled == pos`` until the prompt is fully resident).
    """
    req: "object"                 # repro.serving.engine.Request
    pos: int                      # tokens resident in KV cache
    blocks: List[int] = field(default_factory=list)
    admit_seq: int = 0            # admission order (monotonic)
    resumed: bool = False         # re-admitted after preemption
    last_token: int = 0           # sampled but not yet fed to the model
    prefilled: int = 0            # prompt tokens resident (chunked mode)
    prefill_target: int = 0       # prompt tokens to make resident (0 =
                                  # monolithic prefill, done at admit)
    cached: int = 0               # prompt tokens covered by a prefix-cache
                                  # hit at admission (shared blocks mapped
                                  # in; only tokens past this are prefilled)

    @property
    def prefilling(self) -> bool:
        """True while the prompt is only partially resident: the slot
        owns blocks and advances a chunk per engine step, but takes no
        part in decode windows (its table rows stay null-block)."""
        return self.prefilled < self.prefill_target


class Scheduler:
    """Slot + block-pool bookkeeping for the serving engine.

    ``pool`` is None in dense mode: every slot owns an implicit
    max_seq-sized region, capacity checks reduce to the max_seq bound and
    preemption never triggers.
    """

    def __init__(self, slots: int, max_seq: int,
                 pool: Optional[BlockPool] = None, min_bucket: int = 16,
                 prefix: Optional[PrefixCache] = None):
        self.slots = slots
        self.max_seq = max_seq
        self.pool = pool
        self.prefix = prefix
        self.min_bucket = min_bucket
        if pool is not None:
            self.min_bucket = max(min_bucket, pool.block_size)
            assert max_seq % pool.block_size == 0, \
                (max_seq, pool.block_size)
        self.queue: Deque = deque()
        self.active: List[Optional[SeqSlot]] = [None] * slots
        self.preemptions = 0
        self._admit_counter = 0
        # requests that can NEVER be admitted (their resume state
        # outgrew the pool): popped off the queue with a reason instead
        # of raising — one oversized request must not take down the
        # co-tenants sharing this engine.  The engine harvests these
        # via :meth:`take_rejected` and surfaces a structured
        # per-request failure.
        self.rejected: List[Tuple[object, str]] = []

    # -- queries ----------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.active)

    def num_active(self) -> int:
        return sum(1 for s in self.active if s is not None)

    def pending_tokens(self) -> int:
        """Outstanding work in tokens: queued prompts still to prefill
        plus every request's remaining decode budget.  Host-side only —
        this is the load signal :class:`RingRouter` balances on."""
        load = 0
        for req in self.queue:
            load += len(req.resume_tokens()) \
                + max(req.max_new_tokens - len(req.out), 0)
        for s in self.active:
            if s is not None:
                load += max(s.req.max_new_tokens - len(s.req.out), 1)
        return load

    def bucket(self, n_tokens: int) -> int:
        return bucket_for(n_tokens, self.max_seq, self.min_bucket)

    # -- admission --------------------------------------------------------

    def submit(self, req) -> None:
        if self.pool is not None:
            need = blocks_for(len(req.prompt), self.pool.block_size)
            if need > self.pool.num_blocks - 1:
                raise ValueError(
                    f"prompt needs {need} blocks but the pool only has "
                    f"{self.pool.num_blocks - 1} allocatable blocks")
        self.queue.append(req)

    def admit_next(self, chunk: int = 0) -> Optional[SeqSlot]:
        """Admit the head of the queue if a slot and blocks are available.

        ``chunk == 0`` (monolithic prefill): the whole prompt's blocks
        are reserved at admission and ``pos`` starts fully resident —
        the engine runs one bucketed prefill immediately after.

        ``chunk > 0`` (chunked prefill): only the FIRST chunk's blocks
        are reserved; the slot starts with ``pos == prefilled == 0`` and
        ``prefill_target == len(resume_tokens)``, and the engine makes
        the prompt resident one chunk per step
        (:meth:`chunk_reserve` grows the block set chunk by chunk), so
        admission never has to find room for a whole long prompt up
        front — the per-chunk analog of decode's lazy block growth.

        With a prefix cache, the longest chain of cached full blocks is
        pinned (refcounted) into the new table FIRST — pinning before
        the tail allocation keeps ``alloc``'s LRU eviction from cycling
        the very blocks the hit needs — and only the un-cached tail's
        blocks are allocated; on shortfall the pin is rolled back and
        the request waits as usual.

        A queue head that can never fit — the whole pool is free yet
        still short of its resume-state blocks — is **rejected**, not
        raised over: it is popped into :attr:`rejected` with a reason
        and the next queued request gets its chance in the same call,
        so one oversized request can neither livelock admission nor
        kill the engine its co-tenants share (the engine turns the
        rejection into a structured per-request failure).

        Returns the newly filled SeqSlot (prefill is the engine's job)
        or None when nothing can be admitted right now.
        """
        while self.queue:
            free_slot = next((i for i, s in enumerate(self.active)
                              if s is None), None)
            if free_slot is None:
                return None
            req = self.queue[0]
            tokens = req.resume_tokens()
            n_tok = len(tokens)
            blocks: List[int] = []
            shared: List[int] = []
            cached = 0
            if self.pool is not None:
                if self.prefix is not None:
                    shared, cached = self.prefix.match(tokens)
                    if shared:
                        self.pool.share(shared)
                reserve = min(n_tok, cached + chunk) if chunk else n_tok
                need = blocks_for(reserve, self.pool.block_size) \
                    - len(shared)
                got = self.pool.alloc(max(need, 0))
                if got is None:
                    if shared:
                        self.pool.free(shared)    # unpin; blocks return
                                                  # to the LRU, index kept
                    if self.num_active() == 0 and \
                            self.pool.num_used == 0:
                        # whole pool free yet still short: this request
                        # can never be admitted (its resume state
                        # outgrew the pool after preemption) — reject
                        # it and move on to the next queued request
                        self.queue.popleft()
                        self.rejected.append((req, (
                            f"needs "
                            f"{blocks_for(reserve, self.pool.block_size)}"
                            f" blocks but the pool holds only "
                            f"{self.pool.num_blocks - 1}; increase "
                            f"num_blocks")))
                        continue
                    return None      # pool pressure: wait for finishes
                blocks = shared + got
                if shared:
                    self.prefix.note_hit(shared, cached)
            self.queue.popleft()
            seq = SeqSlot(req=req, pos=cached if chunk else n_tok,
                          blocks=blocks,
                          admit_seq=self._admit_counter,
                          resumed=bool(req.out),
                          prefilled=cached if chunk else 0,
                          prefill_target=n_tok if chunk else 0,
                          cached=cached)
            self._admit_counter += 1
            self.active[free_slot] = seq
            return seq
        return None

    def take_rejected(self) -> List[Tuple[object, str]]:
        """Hand off (request, reason) pairs rejected since the last
        call — exactly once, like the engine's results buffer."""
        out, self.rejected = self.rejected, []
        return out

    def slot_of(self, seq: SeqSlot) -> int:
        return self.active.index(seq)

    def prefilling(self) -> List[SeqSlot]:
        """Active sequences whose prompt is still partially resident, in
        admission order.  The engine runs ONE chunk per step for one of
        these, ROUND-ROBIN over the admission order (it rotates from
        the last sequence served), so neither a long prompt at the head
        nor later arrivals can starve the others — see
        ``LPUEngine._admit_and_chunk``."""
        return sorted((s for s in self.active
                       if s is not None and s.prefilling),
                      key=lambda s: s.admit_seq)

    def num_decoding(self) -> int:
        """Active sequences that take part in decode windows (fully
        prefilled); the complement of :meth:`prefilling`."""
        return sum(1 for s in self.active
                   if s is not None and not s.prefilling)

    # -- growth / preemption ----------------------------------------------

    def chunk_reserve(self, seq: SeqSlot, chunk: int,
                      allow_preempt: bool = False) -> List[SeqSlot] | None:
        """Reserve the blocks the next prefill chunk of ``seq`` lands in.

        The chunked analog of :meth:`ensure_decode_capacity`'s lazy
        growth: before each chunk the sequence must own every block up
        to ``min(prefilled + chunk, prefill_target)`` tokens.  By
        default this NEVER preempts — on shortfall nothing is allocated
        and the caller simply retries next step (in-flight decode
        streams keep freeing blocks as they finish); with
        ``allow_preempt=True`` (the engine sets it only when no decode
        stream is active, i.e. nothing else will ever free blocks) the
        usual newest-victim recompute preemption applies.

        Returns the list of preempted SeqSlots on success (usually
        empty), or None when the chunk cannot be covered right now.
        """
        if self.pool is None:
            return []
        target = min(seq.prefilled + chunk, seq.prefill_target)
        preempted: List[SeqSlot] = []
        while True:
            short = blocks_for(target, self.pool.block_size) \
                - len(seq.blocks)
            if short <= 0:
                return preempted
            got = self.pool.alloc(short)
            if got is not None:
                seq.blocks.extend(got)
                return preempted
            if not allow_preempt:
                return None
            victim = self._pick_victim(exclude=seq)
            if victim is None:
                raise RuntimeError(
                    "KV block pool exhausted by a single prefilling "
                    "sequence; increase num_blocks or lower max_seq")
            self._preempt(victim)
            preempted.append(victim)

    def ensure_decode_capacity(self) -> List[SeqSlot]:
        """Guarantee every decode-ready sequence owns the block its next
        token writes into, preempting the newest other sequences if the
        pool is exhausted.  Returns the list of preempted SeqSlots
        (engine resets their host decode state).

        Sequences still prefilling are skipped: their block growth is
        chunk-driven (:meth:`chunk_reserve`) and they write no decode
        token this round — but they CAN be picked as preemption victims
        (newest-first), in which case the whole partial prefill is
        recomputed on re-admission."""
        if self.pool is None:
            return []
        preempted: List[SeqSlot] = []
        for i in range(self.slots):
            seq = self.active[i]
            if seq is None or seq.prefilling:
                continue
            need_blocks = blocks_for(seq.pos + 1, self.pool.block_size)
            while len(seq.blocks) < need_blocks:
                got = self.pool.alloc(1)
                if got is not None:
                    seq.blocks.extend(got)
                    continue
                victim = self._pick_victim(exclude=seq)
                if victim is None:
                    raise RuntimeError(
                        "KV block pool exhausted by a single sequence; "
                        "increase num_blocks or lower max_seq")
                self._preempt(victim)
                preempted.append(victim)
        return preempted

    def cow_alloc(self, seq: SeqSlot, allow_preempt: bool = True
                  ) -> "tuple[Optional[int], List[SeqSlot]]":
        """One fresh block for a copy-on-write split of a shared block
        in ``seq``'s table.

        Same grow-or-preempt policy as decode growth: newest-victim
        recompute preemption when the pool is dry, unless
        ``allow_preempt`` is False (retry-capable chunk path) — then
        ``(None, [])`` and the caller tries again next step.  Returns
        ``(block, preempted)``; the engine resets the victims' host
        decode state exactly as after :meth:`ensure_decode_capacity`.
        """
        preempted: List[SeqSlot] = []
        while True:
            got = self.pool.alloc(1)
            if got is not None:
                return got[0], preempted
            if not allow_preempt:
                return None, preempted
            victim = self._pick_victim(exclude=seq)
            if victim is None:
                raise RuntimeError(
                    "KV block pool exhausted by a copy-on-write split; "
                    "increase num_blocks")
            self._preempt(victim)
            preempted.append(victim)

    def reserve_lookahead(self, steps: int, draft_k: int = 0) -> bool:
        """All-or-nothing block reservation for a multi-step decode window.

        The engine's fused ``steps_per_sync`` window runs ``steps`` decode
        steps in one device program, so every active sequence must own
        the blocks its next ``steps`` tokens land in BEFORE dispatch —
        there is no host boundary mid-window to allocate at.  Unlike
        :meth:`ensure_decode_capacity` this NEVER preempts: speculative
        lookahead must not evict resident work, so on shortfall nothing
        is allocated and the caller falls back to single-step dispatch
        (where the usual grow-or-preempt policy applies).

        ``draft_k``: extra KV slots per sequence for a speculative
        verify window — the window WRITES KV at positions
        ``pos .. pos + steps + draft_k - 1`` (k drafts beyond the
        committed token) before the host learns how many were accepted,
        so an all-accept window landing at a block boundary would
        otherwise scatter past the sequence's last block into the null
        block and silently corrupt later reads.  Reserved-but-unused
        blocks stay owned by the sequence and are freed at release, so
        the pool accounting matches a non-speculative run after drain.

        Prefilling sequences are skipped: they sit out decode windows
        (frozen null-block rows), so reserving decode lookahead for
        them would only race :meth:`chunk_reserve` for the same blocks.
        """
        if self.pool is None:
            return True
        needs = []
        for seq in self.active:
            if seq is None or seq.prefilling:
                continue
            target = min(seq.pos + steps + draft_k, self.max_seq)
            short = blocks_for(target, self.pool.block_size) \
                - len(seq.blocks)
            if short > 0:
                needs.append((seq, short))
        if sum(n for _, n in needs) > self.pool.num_free:
            return False
        for seq, n in needs:
            seq.blocks.extend(self.pool.alloc(n))
        return True

    def _pick_victim(self, exclude: SeqSlot) -> Optional[SeqSlot]:
        cands = [s for s in self.active
                 if s is not None and s is not exclude]
        if not cands:
            return None
        return max(cands, key=lambda s: s.admit_seq)

    def _preempt(self, seq: SeqSlot) -> None:
        slot = self.slot_of(seq)
        self.pool.free(seq.blocks)
        seq.blocks = []
        self.active[slot] = None
        self.queue.appendleft(seq.req)
        self.preemptions += 1

    # -- release ----------------------------------------------------------

    def release(self, seq: SeqSlot) -> None:
        slot = self.slot_of(seq)
        if self.pool is not None and seq.blocks:
            self.pool.free(seq.blocks)
        seq.blocks = []
        self.active[slot] = None


class RingRouter:
    """Per-ring admission across sub-ring engines (paper C3).

    Stateless beyond a routed-count: the decision each time is simply
    the ring with the least outstanding tokens (ties -> lowest ring id,
    so an idle fleet fills round-robin).  Deliberately NOT work-stealing:
    once placed, a request's KV lives in one ring's pool, and moving it
    would mean a cross-ring recompute — the paper's rings share nothing.

    Prefix-affinity (``EngineConfig.affinity="prefix"``): because KV is
    ring-local, a prompt whose prefix is resident in ring i's
    ``PrefixCache`` prefills its shared span for free ONLY on ring i.
    The fleet probes every ring's index (``PrefixCache.peek``, stats-
    and LRU-neutral) and passes per-ring cached-token counts here;
    ``route`` then sends the request to the deepest owner, falling back
    to least-loaded when no ring owns any of the prompt.  Affinity wins
    TTFT (tokens never re-prefilled) at the cost of load skew, which is
    why it is opt-in and why the bench reports both rows.
    """

    def __init__(self, n_rings: int):
        assert n_rings >= 1
        self.n_rings = n_rings
        self.routed = [0] * n_rings
        self.affinity_routed = [0] * n_rings

    def route(self, loads: Sequence[int],
              affinity: Optional[Sequence[int]] = None) -> int:
        """Pick the target ring for one request given per-ring loads
        (:meth:`Scheduler.pending_tokens` of each ring's engine) and,
        optionally, per-ring prefix-affinity scores (cached prompt
        tokens from ``PrefixCache.peek``; deepest owner wins, ties ->
        lowest ring id, all-zero -> least-loaded fallback)."""
        assert len(loads) == self.n_rings, (len(loads), self.n_rings)
        if affinity is not None:
            assert len(affinity) == self.n_rings, \
                (len(affinity), self.n_rings)
            best = max(affinity)
            if best > 0:
                ring = min(i for i in range(self.n_rings)
                           if affinity[i] == best)
                self.routed[ring] += 1
                self.affinity_routed[ring] += 1
                return ring
        ring = min(range(self.n_rings), key=lambda i: (loads[i], i))
        self.routed[ring] += 1
        return ring
