"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state.  Single-pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips.  The ``model`` axis is
innermost = the ICI ring the ESL schedule runs on; the ``pod`` axis is the
cross-DCI data-parallel (and gradient-compression) domain.
"""
from __future__ import annotations

import jax

from repro.core.compat import make_mesh

# TPU v5e hardware constants used by the roofline / latency model
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~ring direction)
DCI_BW = 6.25e9                 # cross-pod per chip (assumed, data-center)
CHIP_POWER_W = 200.0            # board TDP-ish for the energy model


def make_production_mesh(*, multi_pod: bool = False, tp: int = 16):
    """tp<16: the mapper refactors the same chips as (.., dpx, model) —
    a logical re-slicing of the physical torus (no rewiring), trading
    ring width for extra data parallelism (§Perf: collective-bound
    training cells want a narrower ESL ring)."""
    axes, shape = mesh_axes_shape(multi_pod, tp)
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-process-free CPU tests."""
    return make_mesh(shape, axes)


def make_serving_mesh(tp: int = 1, rings: int = 1):
    """1-D ``model`` mesh for the serving engine: ``tp * rings`` devices.

    The full axis is the physical ICI ring; :func:`repro.core.rings.
    submeshes` carves it into ``rings`` independent ``tp``-wide sub-rings
    (the paper's C3 reconfiguration), one LPUEngine per sub-ring.
    """
    total = tp * rings
    n = len(jax.devices())
    assert total <= n, \
        f"serving mesh wants {total} devices but only {n} are visible " \
        f"(set XLA_FLAGS=--xla_force_host_platform_device_count={total} " \
        f"for CPU experiments)"
    return make_mesh((total,), ("model",),
                     devices=jax.devices()[:total])


def mesh_axes_shape(multi_pod: bool, tp: int = 16):
    assert 16 % tp == 0
    if tp == 16:
        if multi_pod:
            return ("pod", "data", "model"), (2, 16, 16)
        return ("data", "model"), (16, 16)
    dpx = 16 // tp
    if multi_pod:
        return ("pod", "data", "dpx", "model"), (2, 16, dpx, tp)
    return ("data", "dpx", "model"), (16, dpx, tp)
