import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
# ShapeDtypeStruct stand-ins (no allocation), print memory/cost analysis,
# and dump the roofline artifacts consumed by benchmarks/roofline.py.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
# --------------------------------------------------------------------------
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compiler.mapper import plan_model, summarize
from repro.configs import SHAPES, assigned_cells, get_config, get_shape
from repro.core import hlo as hlo_mod
from repro.core import hlo_cost
from repro.core.dist import make_axis_env
from repro.core.steps import (batch_specs, build_prefill_step,
                              build_serve_step, build_train_step)
from repro.launch import mesh as mesh_mod
from repro.models.registry import build_model
from repro.optim import AdamW, get_schedule

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _sds(tree, mesh, specs):
    """ShapeDtypeStructs carrying NamedShardings (no device allocation)."""
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(
            t.shape, t.dtype, sharding=NamedSharding(mesh, s)),
        tree, specs)


def make_inputs(cfg, shape, plan, env):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    toks = jnp.int32
    out = {}
    if shape.kind == "train":
        text = s
        if cfg.family == "vlm":
            text = s - cfg.vlm.n_patches
        out["tokens"] = jax.ShapeDtypeStruct((b, text), toks)
        out["labels"] = jax.ShapeDtypeStruct((b, text), toks)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.enc_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vlm.n_patches, cfg.vlm.patch_embed_dim), jnp.bfloat16)
    elif shape.kind == "prefill":
        text = s - (cfg.vlm.n_patches if cfg.family == "vlm" else 0)
        out["tokens"] = jax.ShapeDtypeStruct((b, text), toks)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.enc_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vlm.n_patches, cfg.vlm.patch_embed_dim), jnp.bfloat16)
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), toks)
        out["positions"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    return out


def plan_for_cell(cfg, shape, mesh_axes, mesh_shape, *, esl_overlap=True,
                  remat="block", seq_shard_kv=False):
    mode = "train" if shape.kind == "train" else "serve"
    kv_seq_axis = None
    if (shape.name == "long_500k" and cfg.family in ("hybrid",)
            and shape.kind == "decode"):
        kv_seq_axis = "data"          # sequence-parallel KV (flash-decode)
    # decode cells lower in f32 end-to-end: the CPU dry-run backend has
    # no native bf16 dot and otherwise inserts whole-stack convert/copy
    # churn that exists on no TPU; report TPU-native (bf16) as half the
    # measured stream (EXPERIMENTS.md §Roofline).
    dtypes = {}
    if shape.kind == "decode":
        dtypes = dict(compute_dtype="float32", param_dtype="float32")
    return plan_model(cfg, mesh_axes, mesh_shape, mode,
                      esl_overlap=esl_overlap, remat=remat,
                      seq_shard_kv=seq_shard_kv, kv_seq_axis=kv_seq_axis,
                      **dtypes)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               esl_overlap: bool = True, remat: str = "block",
               mesh=None, save: bool = True, tag: str = "",
               tp: int = 16, accum: int = 1):
    """Lower + compile one cell; return the artifact row."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if not cfg.supports_shape(shape_name):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch skips long_500k (DESIGN.md)"}
    mesh_axes, mesh_shape = mesh_mod.mesh_axes_shape(multi_pod, tp)
    if mesh is None:
        mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod, tp=tp)
    plan = plan_for_cell(cfg, shape, mesh_axes, mesh_shape,
                         esl_overlap=esl_overlap, remat=remat)
    model = build_model(cfg, plan)
    env = make_axis_env(plan, batch=shape.global_batch)
    inputs = make_inputs(cfg, shape, plan, env)
    bspecs = batch_specs(model, env, shape.kind)

    t0 = time.time()
    if shape.kind == "train":
        opt = AdamW(lr=get_schedule("cosine", 3e-4, 100, 10_000))
        step, meta = build_train_step(model, opt, mesh, shape.global_batch,
                                      accum_steps=accum)
        specs = meta["param_specs"]
        params, _ = model.abstract_params()
        opt_sds = opt.init_abstract(params)
        p_sds = _sds(params, mesh, specs)
        o_specs = type(opt_sds)(P(), jax.tree.map(lambda s: s, specs),
                                jax.tree.map(lambda s: s, specs))
        o_sds = _sds(opt_sds, mesh, o_specs)
        b_sds = {k: jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
            for k, v in inputs.items()}
        lowered = jax.jit(step).lower(p_sds, o_sds, b_sds)
    elif shape.kind == "prefill":
        stepf, meta = build_prefill_step(model, mesh, shape.global_batch,
                                         shape.seq_len)
        specs, cspecs = meta["param_specs"], meta["cache_specs"]
        params, _ = model.abstract_params()
        cache = model.init_cache(shape.global_batch, shape.seq_len,
                                 abstract=True)
        p_sds = _sds(params, mesh, specs)
        c_sds = _sds(cache, mesh, cspecs)
        t_sds = jax.ShapeDtypeStruct(
            inputs["tokens"].shape, jnp.int32,
            sharding=NamedSharding(mesh, bspecs["tokens"]))
        extra = []
        for k in ("frames", "patch_embeds"):
            if k in inputs:
                extra.append(jax.ShapeDtypeStruct(
                    inputs[k].shape, inputs[k].dtype,
                    sharding=NamedSharding(mesh, bspecs[k])))
            else:
                extra.append(jax.ShapeDtypeStruct((), jnp.bfloat16))
        lowered = jax.jit(stepf).lower(p_sds, c_sds, t_sds, *extra)
    else:  # decode
        stepf, meta = build_serve_step(model, mesh, shape.global_batch,
                                       shape.seq_len)
        specs, cspecs = meta["param_specs"], meta["cache_specs"]
        params, _ = model.abstract_params()
        cache = model.init_cache(shape.global_batch, shape.seq_len,
                                 abstract=True)
        p_sds = _sds(params, mesh, specs)
        c_sds = _sds(cache, mesh, cspecs)
        t_sds = jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32,
            sharding=NamedSharding(mesh, bspecs["tokens"]))
        pos_sds = jax.ShapeDtypeStruct(
            (shape.global_batch,), jnp.int32,
            sharding=NamedSharding(mesh, bspecs["positions"]))
        lowered = jax.jit(stepf, donate_argnums=(1,)).lower(
            p_sds, c_sds, t_sds, pos_sds)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = hlo_cost.xla_cost_analysis(compiled)
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    n_dev = 1
    for s_ in mesh_shape:
        n_dev *= s_
    # trip-count-aware costs (XLA's cost_analysis counts scan bodies once)
    cost = hlo_cost.module_cost(txt, default_group=plan.tp)
    coll = hlo_mod.collective_stats(txt, default_group=plan.tp)
    row = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "x".join(map(str, mesh_shape)), "multi_pod": multi_pod,
        "esl_overlap": esl_overlap, "remat": remat, "tag": tag,
        "status": "ok",
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.hbm_bytes,
        "wire_bytes_per_device": cost.wire_bytes,
        "coll_counts": cost.coll_counts,
        "xla_flops_once": ca.get("flops", 0.0),
        "xla_bytes_once": ca.get("bytes accessed", 0.0),
        "collectives_once": coll.row(),
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(ma, "argument_size_in_bytes", 0)
                           + getattr(ma, "temp_size_in_bytes", 0)),
        },
        "plan": summarize(plan),
        "op_census": hlo_mod.op_census(txt),
    }
    print(f"[dryrun] {arch} x {shape_name} mesh={row['mesh']} "
          f"overlap={esl_overlap} : lower {t_lower:.1f}s compile "
          f"{t_compile:.1f}s flops/dev={row['flops_per_device']:.3e} "
          f"bytes/dev={row['bytes_per_device']:.3e} "
          f"wire={cost.wire_bytes:.3e} "
          f"temp={row['memory']['temp_bytes']/2**30:.2f}GiB")
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape_name}__{row['mesh']}" + \
            ("" if esl_overlap else "__noesl") + \
            (f"__{tag}" if tag else "")
        (ART_DIR / f"{name}.json").write_text(json.dumps(row, indent=1))
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--no-esl", action="store_true",
                    help="blocking-collective baseline (paper's GPU-style)")
    ap.add_argument("--remat", type=str, default="block")
    ap.add_argument("--tp", type=int, default=16)
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--tag", type=str, default="")
    args = ap.parse_args()

    meshes = []
    if args.all:
        meshes = [False, True] if not args.single_pod_only else [False]
    else:
        meshes = [args.multi_pod]

    results = []
    if args.all:
        run, skip = assigned_cells()
        for mp in meshes:
            mesh = mesh_mod.make_production_mesh(multi_pod=mp)
            for arch, shp in run:
                try:
                    results.append(lower_cell(
                        arch, shp, mp, esl_overlap=not args.no_esl,
                        remat=args.remat, mesh=mesh, tag=args.tag))
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shp,
                                    "mesh": mp, "status": "FAILED",
                                    "error": str(e)[:400]})
        for arch, shp in skip:
            results.append({"arch": arch, "shape": shp, "status": "skipped",
                            "reason": "sub-quadratic shape on full-attention arch"})
    else:
        results.append(lower_cell(args.arch, args.shape, args.multi_pod,
                                  esl_overlap=not args.no_esl,
                                  remat=args.remat, tag=args.tag,
                                  tp=args.tp, accum=args.accum))
    bad = [r for r in results if r.get("status") == "FAILED"]
    print(f"\n[dryrun] {len(results)} cells, {len(bad)} failed")
    if bad:
        for r in bad:
            print("  FAILED:", r["arch"], r["shape"], r.get("error", "")[:160])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
