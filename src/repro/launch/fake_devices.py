"""Pre-jax-import CPU device bootstrap for the ring-parallel drivers.

``--xla_force_host_platform_device_count`` only takes effect if set
before jax initializes, i.e. before ``import jax`` anywhere in the
process — so the serving CLI and benchmark call this at module top,
ahead of their jax imports.  Deliberately jax-free.
"""
from __future__ import annotations

import os
from typing import Sequence


def flag_value(argv: Sequence[str], flag: str, default: int) -> int:
    """Parse an integer ``--flag N`` / ``--flag=N`` from raw argv."""
    for i, tok in enumerate(argv):
        if tok == flag:
            try:
                return int(argv[i + 1])
            except (IndexError, ValueError):
                return default
        if tok.startswith(flag + "="):
            try:
                return int(tok.split("=", 1)[1])
            except ValueError:
                return default
    return default


def ensure_host_devices(argv: Sequence[str]) -> None:
    """Fake enough CPU devices for ``--tp``/``--rings`` runs.

    No-op when the product is 1 or the user already set XLA_FLAGS
    (their setting wins — we never clobber an explicit device count).
    """
    need = flag_value(argv, "--tp", 1) * flag_value(argv, "--rings", 1)
    if need > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={need}"
