"""Serving driver: load (or init) a model and run the LPU engine.

CLI (CPU-feasible defaults):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm --reduced \
      --requests 8 --max-new 16

Ring parallelism: ``--tp N`` shards the model over an N-wide ESL ring
(weights AND the paged KV pool split 1/N per rank); ``--rings R`` serves
R independent sub-rings concurrently — ``tp * rings`` devices total,
one engine per sub-ring with least-loaded request routing (paper C2/C3).
On CPU the driver fakes the devices automatically
(``--xla_force_host_platform_device_count``), so
``--tp 2 --rings 2`` is runnable on a laptop.

Paged-KV knobs: ``--block-size`` (tokens per KV block), ``--num-blocks``
(pool size incl. the reserved null block; 0 = dense-equivalent capacity),
``--kv-budget-mb`` (size the pool from a per-rank HBM budget instead),
``--min-bucket`` (smallest power-of-two prefill bucket), ``--dense``
(force the contiguous per-slot cache), ``--paged-kernel
{auto,stream,gather}`` (stream KV tiles through the Pallas paged kernel
vs. materialize the contiguous gather view — see docs/serving.md).

Sampling / dispatch knobs: ``--sampling {fused,host}`` (fused = sample
inside the jitted decode program, only token ids reach the host — the
paper's on-chip "sampling with sort"; host = the synced baseline that
ships the full logits row per token), ``--steps-per-sync N`` (run N
decode steps per host readback via one lax.scan window), ``--block-s``
(override the planned KV stream tile / flash chunk for hardware tuning),
``--prefill-chunk C`` (chunked prefill: prompts become resident C tokens
per step, interleaved with decode windows, so a long prompt never stalls
in-flight streams — 0 = today's monolithic bucketed prefill),
``--prefix-cache {on,off}`` (prefix caching: a shared system prompt's
blocks are prefilled once and mapped — refcounted, copy-on-write — into
every later request's table; only the un-cached tail prefills),
``--speculate {off,ngram,model}`` + ``--draft-k K`` (speculative
decoding: a drafter proposes K tokens per slot, ONE chunk-as-batch
verify pass scores them against the pool, and rejection sampling
accepts a prefix — greedy streams bit-identical, stochastic streams
exactly target-distributed; ``model`` drafts with a reduced smollm-135m
running greedily at batch 1).

Precision knobs: ``--kv-dtype {auto,float16,bfloat16,float32,int8,fp8}``
(KV pool storage precision — int8/fp8 store per-(row, head) absmax
scales beside the pool and dequantize inside the paged kernel's tile
loop, halving the KV stream's HBM bytes) and ``--w-dtype {auto,int8}``
(streamed weight precision of the gemv chain).  See docs/serving.md
"KV & weight precision".

Front-end knobs (any of these routes the batch through the async
streaming frontend instead of the blocking generate loop):
``--affinity {least_loaded,prefix}`` (fleet routing: least-loaded vs
route-to-the-ring-whose-prefix-cache-owns-the-prompt),
``--budget-ms B`` (SLO scheduling: retune prefill_chunk /
steps_per_sync each step from a measured EWMA seeded by the analytic
step-time prior), ``--max-pending N`` (admission bound with structured
backpressure), ``--tracker PATH`` (jsonl telemetry: per-window
EngineStats deltas + per-request TTFT / ms-per-token records).  See
docs/serving.md "Async front end, SLO scheduling & telemetry".
"""
from __future__ import annotations

import argparse
import sys

from repro.launch.fake_devices import ensure_host_devices

ensure_host_devices(sys.argv)   # must precede the jax import

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.compiler.mapper import plan_model  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core.latency_model import LPU_FPGA, step_time_prior  # noqa: E402
from repro.launch.mesh import make_serving_mesh  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.serving.config import EngineConfig  # noqa: E402
from repro.serving.engine import LPUEngine, MultiRingEngine  # noqa: E402
from repro.serving.sampler import SamplingParams  # noqa: E402


def serve_async(engine, cfg, args, prompts, sp):
    """Drive the batch through the async streaming frontend instead of
    the blocking ``generate()`` loop — the path ``--budget-ms`` /
    ``--max-pending`` / ``--tracker`` select.  Backpressure rejections
    are retried after the fleet quiesces (a CLI batch has nowhere to
    shed load to), and the per-request TTFT / ms-per-token summary the
    frontend's timelines collect is printed at the end."""
    import asyncio

    from repro.serving.budget import BudgetScheduler
    from repro.serving.frontend import AdmissionRejected, AsyncFrontend
    from repro.serving.tracker import JsonlTracker

    budget = None
    if args.budget_ms > 0:
        prior = step_time_prior(cfg, max(args.tp, 1), LPU_FPGA,
                                kv_len=args.max_seq,
                                steps_per_sync=args.steps_per_sync)
        budget = BudgetScheduler(args.budget_ms, prior_step_s=prior,
                                 max_chunk=args.max_seq)
    tracker = JsonlTracker(args.tracker) if args.tracker else None
    retries = 0

    async def go():
        nonlocal retries
        async with AsyncFrontend(engine, budget=budget,
                                 tracker=tracker) as fe:
            streams = []
            for p in prompts:
                while True:
                    try:
                        streams.append(fe.submit(p, args.max_new, sp))
                        break
                    except AdmissionRejected:
                        retries += 1
                        await fe.join()     # backpressure: drain first
            outs = [await s.drain() for s in streams]
        return fe, streams, outs

    fe, streams, outs = asyncio.run(go())
    tl = [s.timeline for s in streams if s.timeline.t_first is not None]
    ttft = sorted(t.ttft_ms for t in tl)
    mpt = sorted(t.ms_per_token for t in tl if t.tokens >= 2)
    c = fe.counters
    print(f"[serve] frontend: {c['completed']} completed "
          f"{c['failed']} failed {c['cancelled']} cancelled "
          f"({c['rejected']} backpressure rejections, {retries} retried)")
    if ttft:
        print(f"[serve] ttft p50/max {ttft[len(ttft) // 2]:.1f}/"
              f"{ttft[-1]:.1f} ms"
              + (f", ms/token p50/max {mpt[len(mpt) // 2]:.2f}/"
                 f"{mpt[-1]:.2f}" if mpt else ""))
    if budget is not None:
        print(f"[serve] budget={args.budget_ms}ms: {len(budget.planned)} "
              f"plans, mu_step {budget.mu_step * 1e3:.3f} ms "
              f"({budget.observed_windows} windows, "
              f"{budget.observed_chunks} chunks observed)")
    if tracker is not None:
        print(f"[serve] tracker: {tracker.written} records -> "
              f"{tracker.path}")
    return outs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--tp", type=int, default=1,
                    help="ESL ring width (devices per model replica)")
    ap.add_argument("--rings", type=int, default=1,
                    help="independent sub-rings (engines); uses "
                         "tp*rings devices")
    ap.add_argument("--no-overlap", action="store_true",
                    help="blocking collectives baseline (vs ESL overlap)")
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot KV cache")
    ap.add_argument("--block-size", type=int, default=0,
                    help="tokens per KV block (0 = min(128, max_seq))")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="KV pool size incl. null block "
                         "(0 = dense-equivalent capacity)")
    ap.add_argument("--kv-budget-mb", type=int, default=0,
                    help="per-rank KV HBM budget in MiB (sizes the pool "
                         "when --num-blocks is 0)")
    ap.add_argument("--min-bucket", type=int, default=16,
                    help="smallest power-of-two prefill bucket")
    ap.add_argument("--paged-kernel", default="auto",
                    choices=("auto", "stream", "gather"),
                    help="paged decode dataflow: stream KV tiles through "
                         "the Pallas kernel (no per-request copy), gather "
                         "the contiguous view (reference oracle), or auto")
    ap.add_argument("--sampling", default="fused",
                    choices=("fused", "host"),
                    help="fused: sample in-jit, only token ids reach the "
                         "host; host: per-token logits readback baseline")
    ap.add_argument("--steps-per-sync", type=int, default=1,
                    help="decode steps per host sync (fused sampling "
                         "only): N steps run as one lax.scan window")
    ap.add_argument("--block-s", type=int, default=0,
                    help="KV stream tile / flash chunk override threaded "
                         "to plan_block_s (0 = planned default)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: make prompts resident N "
                         "tokens per step, interleaved with decode "
                         "windows (paged only; 0 = monolithic bucketed "
                         "prefill)")
    ap.add_argument("--prefix-cache", default="off",
                    choices=("on", "off"),
                    help="prefix caching: admissions whose prompt hits "
                         "a cached block-aligned prefix map the shared "
                         "blocks (refcounted, copy-on-write) into their "
                         "table and prefill only the tail (paged only)")
    ap.add_argument("--speculate", default="off",
                    choices=("off", "ngram", "model"),
                    help="speculative decoding: draft k tokens per slot "
                         "(ngram: suffix-match over the visible stream; "
                         "model: a reduced smollm-135m drafter), verify "
                         "all of them in ONE chunk-as-batch pass and "
                         "accept a rejection-sampled prefix (paged only)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens proposed per speculative round")
    ap.add_argument("--kv-dtype", default="auto",
                    choices=("auto", "float16", "bfloat16", "float32",
                             "int8", "fp8"),
                    help="KV pool storage precision: fp dtypes restore "
                         "the pool; int8/fp8 quantize per-(row, head) "
                         "with absmax scales dequantized in-kernel "
                         "(paged only; auto = the plan's cache dtype)")
    ap.add_argument("--w-dtype", default="auto",
                    choices=("auto", "int8"),
                    help="streamed weight precision of the gemv chain "
                         "(int8 with per-output-column scales)")
    ap.add_argument("--chaos", default="",
                    help="deterministic fault injection: comma-separated "
                         "kind@step[:ring] with kinds ring|stall|nan|"
                         "corrupt (e.g. 'ring@3,nan@7:1'); forces the "
                         "supervised fleet driver — see docs/serving.md "
                         "'Fault tolerance'")
    ap.add_argument("--max-migrations", type=int, default=3,
                    help="recompute-migrations per request before it "
                         "surfaces a structured failure")
    ap.add_argument("--heartbeat-timeout", type=float, default=30.0,
                    help="ring liveness timeout in (virtual, under "
                         "chaos) seconds before drain/rebuild")
    ap.add_argument("--affinity", default="least_loaded",
                    choices=("least_loaded", "prefix"),
                    help="fleet request routing: least-loaded, or "
                         "prefix-affinity (route to the ring whose "
                         "prefix cache owns the prompt's deepest "
                         "block-aligned prefix; needs --prefix-cache on)")
    ap.add_argument("--budget-ms", type=float, default=0.0,
                    help="per-step latency budget (SLO): the async "
                         "frontend retunes prefill_chunk / "
                         "steps_per_sync each step from a measured "
                         "EWMA seeded by the analytic step-time prior "
                         "(0 = off; forces the async frontend path)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="frontend admission bound: in-flight streams "
                         "above this are rejected with a structured "
                         "AdmissionRejected (0 = unbounded; forces the "
                         "async frontend path)")
    ap.add_argument("--tracker", default="",
                    help="write per-window EngineStats deltas and "
                         "per-request TTFT/ms-per-token records to this "
                         "jsonl file (forces the async frontend path)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tp, rings = args.tp, args.rings
    if tp > 1 or rings > 1:
        mesh = make_serving_mesh(tp=tp, rings=rings)
        plan = plan_model(cfg, ("model",), (tp,), "serve",
                          esl_overlap=not args.no_overlap, remat="none",
                          compute_dtype="float32", param_dtype="float32")
    else:
        mesh = None
        plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                          remat="none", compute_dtype="float32",
                          param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    draft_model = draft_params = None
    if args.speculate == "model":
        # the drafter is its own tiny model: always single-device (it
        # proposes on the host loop), reduced so it is cheap relative
        # to the target
        dcfg = get_config("smollm-135m").reduced()
        dplan = plan_model(dcfg, None, (1,), "serve", esl_overlap=False,
                           remat="none", compute_dtype="float32",
                           param_dtype="float32")
        draft_model = build_model(dcfg, dplan)
        draft_params, _ = draft_model.init(jax.random.PRNGKey(1))
    econf = EngineConfig(slots=args.slots, max_seq=args.max_seq,
                         paged=False if args.dense else None,
                         block_size=args.block_size,
                         num_blocks=args.num_blocks,
                         kv_budget_bytes=args.kv_budget_mb << 20,
                         min_bucket=args.min_bucket,
                         paged_kernel=args.paged_kernel,
                         sampling=args.sampling,
                         steps_per_sync=args.steps_per_sync,
                         block_s=args.block_s,
                         prefill_chunk=args.prefill_chunk,
                         prefix_cache=args.prefix_cache == "on",
                         speculate=args.speculate, draft_k=args.draft_k,
                         kv_dtype=args.kv_dtype, w_dtype=args.w_dtype,
                         chaos=args.chaos,
                         max_migrations=args.max_migrations,
                         heartbeat_timeout_s=args.heartbeat_timeout,
                         affinity=args.affinity,
                         budget_ms=args.budget_ms,
                         max_pending=args.max_pending)
    fleet = rings > 1 or bool(args.chaos)
    if fleet:
        # seed each ring's straggler monitor with the analytic latency
        # model's step-time prior (LPU-FPGA point) so outlier detection
        # is armed from the first measured step
        prior = step_time_prior(cfg, max(tp, 1), LPU_FPGA,
                                kv_len=args.max_seq,
                                steps_per_sync=args.steps_per_sync)
        if mesh is not None:
            engine = MultiRingEngine(model, params, mesh, ring_size=tp,
                                     config=econf, step_prior_s=prior,
                                     draft_model=draft_model,
                                     draft_params=draft_params)
        else:
            engine = MultiRingEngine(model, params, None,
                                     rings=max(rings, 1), config=econf,
                                     step_prior_s=prior,
                                     draft_model=draft_model,
                                     draft_params=draft_params)
        first = engine.engines[0]
    else:
        engine = LPUEngine(model, params, econf, mesh=mesh,
                           draft_model=draft_model,
                           draft_params=draft_params)
        first = engine

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab_size,
                                size=rng.randint(2, 10)))
               for _ in range(args.requests)]
    sp = SamplingParams(args.temperature, args.top_k, args.top_p)

    def cb(rid, tok):
        pass  # streaming hook (stdout spam suppressed)

    if args.budget_ms > 0 or args.max_pending > 0 or args.tracker:
        outs = serve_async(engine, cfg, args, prompts, sp)
    else:
        outs = engine.generate(prompts, max_new_tokens=args.max_new,
                               params=sp, stream_cb=cb)
    mode = f"paged/{first.paged_kernel}" if first.paged else "dense"
    if fleet:
        print(f"[serve] {len(outs)} requests over {engine.n_rings} "
              f"sub-rings (tp={tp} each), routed {engine.router.routed}")
        for i, (eng, st) in enumerate(zip(engine.engines,
                                          engine.per_ring_stats())):
            print(f"[serve]   ring{i}: {st.tokens} tokens, "
                  f"{st.tokens_per_s:.1f} tok/s, occ {st.occupancy:.2f}, "
                  f"kv/rank {eng.per_rank_kv_bytes()} B")
        fc = engine.fleet_counters()
        print(f"[serve] ft: chaos={args.chaos or 'off'} "
              f"ring_failures={fc['ring_failures']} "
              f"migrated={fc['migrated_requests']} "
              f"retries={fc['retries']} "
              f"rejected={fc['rejected_requests']} "
              f"failed={fc['failed_requests']} "
              f"events={fc['events']}")
    else:
        st = first.stats
        print(f"[serve] {len(outs)} requests, {st.tokens} tokens, "
              f"{st.tokens_per_s:.1f} tok/s, occupancy {st.occupancy:.2f}, "
              f"{st.steps} decode steps, tp={tp}")
        print(f"[serve] kv={mode} dtype={first.kv_dtype} "
              f"w_dtype={first.w_dtype} bytes={first.kv_cache_bytes()} "
              f"(per-rank {first.per_rank_kv_bytes()}, "
              f"dense-equiv {first.dense_equiv_bytes()}), "
              f"kv_moved/step={first.kv_bytes_moved_per_step()}, "
              f"prefill traces={st.prefill_traces}, "
              f"preemptions={st.preemptions}")
        print(f"[serve] sampling={first.sampling} "
              f"steps_per_sync={first.steps_per_sync}: "
              f"{st.host_syncs} host syncs "
              f"({st.syncs_per_token:.2f}/token), "
              f"{st.bytes_to_host_per_token:.1f} B->host/token, "
              f"overrun={st.overrun_tokens}, "
              f"block_s={first.decode_block_s()} "
              f"(planned {first.planned_block_s()})")
        print(f"[serve] prefill_chunk={first.prefill_chunk}: "
              f"{st.prefill_chunks} chunks, "
              f"decode_stalls={st.decode_stalls}")
        print(f"[serve] prefix_cache={args.prefix_cache}: "
              f"hit_rate={st.prefix_hit_rate:.2f} "
              f"({st.prefix_hits}/{st.prefix_lookups}), "
              f"hit_blocks={st.prefix_hit_blocks}, "
              f"prefill_tokens_saved={st.prefill_tokens_saved}, "
              f"cow={st.cow_blocks}, evicted={st.evicted_blocks}")
        print(f"[serve] speculate={first.speculate} "
              f"draft_k={first.draft_k}: {st.spec_rounds} rounds, "
              f"accepted {st.accepted_tokens}/{st.draft_tokens} drafts "
              f"(rate {st.acceptance_rate:.2f}, "
              f"{st.accepted_per_window:.2f}/window)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12]}")


if __name__ == "__main__":
    main()
