"""Serving driver: load (or init) a model and run the LPU engine.

CLI (CPU-feasible defaults):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm --reduced \
      --requests 8 --max-new 16

Paged-KV knobs: ``--block-size`` (tokens per KV block), ``--num-blocks``
(pool size incl. the reserved null block; 0 = dense-equivalent capacity),
``--min-bucket`` (smallest power-of-two prefill bucket), ``--dense``
(force the contiguous per-slot cache).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine
from repro.serving.sampler import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot KV cache")
    ap.add_argument("--block-size", type=int, default=0,
                    help="tokens per KV block (0 = min(128, max_seq))")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="KV pool size incl. null block "
                         "(0 = dense-equivalent capacity)")
    ap.add_argument("--min-bucket", type=int, default=16,
                    help="smallest power-of-two prefill bucket")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = LPUEngine(model, params, slots=args.slots,
                       max_seq=args.max_seq,
                       paged=False if args.dense else None,
                       block_size=args.block_size,
                       num_blocks=args.num_blocks,
                       min_bucket=args.min_bucket)

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab_size,
                                size=rng.randint(2, 10)))
               for _ in range(args.requests)]
    sp = SamplingParams(args.temperature, args.top_k, args.top_p)

    def cb(rid, tok):
        pass  # streaming hook (stdout spam suppressed)

    outs = engine.generate(prompts, max_new_tokens=args.max_new,
                           params=sp, stream_cb=cb)
    st = engine.stats
    mode = "paged" if engine.paged else "dense"
    print(f"[serve] {len(outs)} requests, {st.tokens} tokens, "
          f"{st.tokens_per_s:.1f} tok/s, occupancy {st.occupancy:.2f}, "
          f"{st.steps} decode steps")
    print(f"[serve] kv={mode} bytes={engine.kv_cache_bytes()} "
          f"(dense-equiv {engine.dense_equiv_bytes()}), "
          f"prefill traces={st.prefill_traces}, "
          f"preemptions={st.preemptions}")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12]}")


if __name__ == "__main__":
    main()
