"""Serving driver: load (or init) a model and run the LPU engine.

CLI (CPU-feasible defaults):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.models.registry import build_model
from repro.serving.engine import LPUEngine
from repro.serving.sampler import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    plan = plan_model(cfg, None, (1,), "serve", esl_overlap=False,
                      remat="none", compute_dtype="float32",
                      param_dtype="float32")
    model = build_model(cfg, plan)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = LPUEngine(model, params, slots=args.slots,
                       max_seq=args.max_seq)

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab_size,
                                size=rng.randint(2, 10)))
               for _ in range(args.requests)]
    sp = SamplingParams(args.temperature, args.top_k, args.top_p)

    def cb(rid, tok):
        pass  # streaming hook (stdout spam suppressed)

    outs = engine.generate(prompts, max_new_tokens=args.max_new,
                           params=sp, stream_cb=cb)
    st = engine.stats
    print(f"[serve] {len(outs)} requests, {st.tokens} tokens, "
          f"{st.tokens_per_s:.1f} tok/s, occupancy {st.occupancy:.2f}, "
          f"{st.steps} decode steps")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o[:12]}")


if __name__ == "__main__":
    main()
