"""End-to-end training driver: data -> step -> checkpoint/restart.

``run_training`` is the production loop shape:
  * deterministic elastic data stream (count-invariant indexing),
  * jitted train_step (manual shard_map inside when a mesh is given),
  * periodic *atomic* checkpoints + crash auto-resume (restore latest),
  * straggler monitor + heartbeat events,
  * gradient-compression hook on the pod axis (optional),
  * resumable under a different dp width (elastic restart).

CLI (CPU-feasible defaults):
  PYTHONPATH=src python -m repro.launch.train --arch smollm --steps 50 \
      --batch 8 --seq 128 --reduced
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.compiler.mapper import plan_model
from repro.configs import get_config
from repro.core.steps import build_train_step
from repro.data.pipeline import SyntheticLM
from repro.launch.ft import FailureInjector, StragglerMonitor
from repro.models.registry import build_model
from repro.optim import AdamW, get_schedule


def run_training(*, cfg, steps: int, global_batch: int, seq_len: int,
                 mesh=None, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 20, lr: float = 3e-4,
                 schedule: str = "cosine", seed: int = 0,
                 injector: Optional[FailureInjector] = None,
                 esl_overlap: bool = False, log_every: int = 10,
                 param_dtype: str = "float32",
                 compute_dtype: str = "float32"):
    mesh_axes = tuple(mesh.axis_names) if mesh is not None else None
    mesh_shape = tuple(mesh.devices.shape) if mesh is not None else (1,)
    plan = plan_model(cfg, mesh_axes, mesh_shape, "train",
                      esl_overlap=esl_overlap, remat="none",
                      compute_dtype=compute_dtype, param_dtype=param_dtype)
    model = build_model(cfg, plan)
    opt = AdamW(lr=get_schedule(schedule, lr, max(steps // 20, 1), steps))
    step_fn, meta = build_train_step(model, opt, mesh, global_batch)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq_len,
                       seed=seed)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    monitor = StragglerMonitor()

    params, _ = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        s = mgr.latest_step()
        state = mgr.restore(s, {"params": params,
                                "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = int(mgr.manifest(s)["extra"]["next_step"])
        print(f"[train] resumed from checkpoint step {s} -> "
              f"continuing at data step {start}")

    losses = []
    for step in range(start, steps):
        if injector is not None:
            injector.maybe_fail(step)
        batch_np = data.batch(step, global_batch)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        ev = monitor.record(step, dt)
        if ev:
            print(f"[train][ft] straggler flagged: {ev.detail}")
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms")
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt_state},
                     extra={"next_step": step + 1, "loss": loss})
    if mgr is not None:
        mgr.save(steps - 1, {"params": params, "opt": opt_state},
                 extra={"next_step": steps, "loss": losses[-1]})
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale config (CPU-feasible)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--esl-overlap", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    _, _, losses = run_training(
        cfg=cfg, steps=args.steps, global_batch=args.batch,
        seq_len=args.seq, ckpt_dir=args.ckpt_dir, lr=args.lr,
        schedule=args.schedule, esl_overlap=args.esl_overlap)
    print(f"[train] done: first loss {losses[0]:.4f} -> "
          f"last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
