"""Fault-tolerance & straggler machinery for the training driver.

Designed for 1000+-node operation; on this single host the *policies*
are fully implemented and unit-tested, and the cluster actions they
would trigger are surfaced as structured events:

* :class:`StragglerMonitor` — EWMA/σ step-time outlier detection.  At
  pod scale the emitted ``rebalance`` event triggers hot-spare swap-in
  (the same checkpoint-restart path as failure recovery — TPU pods
  cannot shrink a mesh in place, so recovery == restart from the last
  atomic checkpoint on a respecced slice; see CheckpointManager).
* :class:`HeartbeatTracker` — per-worker liveness with configurable
  timeout; a missed heartbeat marks the worker failed and requests
  restart (simulated in tests by injecting silence).
* :class:`FailureInjector` — deterministic chaos hook used by the
  integration tests to kill a step and assert the driver resumes
  losslessly from the latest checkpoint.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Event:
    kind: str            # 'straggler' | 'worker_failed' | 'rebalance'
    step: int
    detail: dict


class StragglerMonitor:
    """EWMA + variance step-time tracking; flags > mu + k*sigma."""

    def __init__(self, alpha: float = 0.1, k_sigma: float = 3.0,
                 warmup: int = 5, cooldown: int = 20,
                 min_slack: float = 0.25):
        self.alpha = alpha
        self.k = k_sigma
        self.warmup = warmup
        self.cooldown = cooldown
        self.min_slack = min_slack     # never flag < (1+slack)*mu drift
        self.mu: Optional[float] = None
        self.var: float = 0.0
        self.n = 0
        self._last_flag = -10 ** 9
        self.events: List[Event] = []

    def record(self, step: int, dt: float) -> Optional[Event]:
        self.n += 1
        if self.mu is None:
            self.mu = dt
            return None
        thresh = max(self.mu + self.k * math.sqrt(self.var + 1e-12),
                     self.mu * (1.0 + self.min_slack))
        flagged = (self.n > self.warmup and dt > thresh
                   and step - self._last_flag >= self.cooldown)
        # EWMA update (skip outliers so one straggler doesn't poison mu)
        if not flagged:
            d = dt - self.mu
            self.mu += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        if flagged:
            self._last_flag = step
            ev = Event("straggler", step,
                       {"dt": dt, "mu": self.mu, "thresh": thresh})
            self.events.append(ev)
            return ev
        return None


class HeartbeatTracker:
    def __init__(self, n_workers: int, timeout_s: float = 60.0):
        self.timeout = timeout_s
        self.last: Dict[int, float] = {i: time.time()
                                       for i in range(n_workers)}
        self.failed: List[int] = []

    def beat(self, worker: int, now: Optional[float] = None):
        self.last[worker] = now if now is not None else time.time()

    def check(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        newly = [w for w, t in self.last.items()
                 if now - t > self.timeout and w not in self.failed]
        self.failed.extend(newly)
        return newly


class FailureInjector:
    """Deterministic chaos: raise at configured steps (tests/examples)."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"[chaos] injected failure at step {step}")
