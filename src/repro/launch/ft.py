"""Compatibility shim: fault-tolerance policies live in
:mod:`repro.serving.ft` now.

These classes began life next to the training driver but were always
generic step-telemetry policies; the serving fault-tolerance subsystem
(chaos injection, ring drain/rebuild, request migration — see
docs/serving.md "Fault tolerance & graceful degradation") is their real
consumer, so the implementation moved to ``repro.serving.ft``.  The
training driver and its tests keep importing from here unchanged.
"""
from repro.serving.ft import (Event, FailureInjector, HeartbeatTracker,  # noqa: F401
                              StragglerMonitor)

__all__ = ["Event", "FailureInjector", "HeartbeatTracker",
           "StragglerMonitor"]
