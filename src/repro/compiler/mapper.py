"""HyperDex-analog model & memory mapper (compilation layer).

``plan_model(cfg, mesh_axes, mesh_shape, mode, ...)`` -> PhysicalPlan
``partition_specs(plan, axes_by_path)``               -> PartitionSpec tree

The mapper is model-and-hardware aware: given the logical architecture and
the mesh, it chooses head-wise attention tiles, column-wise FFN tiles,
padding to lane width (128) and TP degree, expert-parallel factorization,
FSDP axes for training, and emits the PartitionSpec rule table the jitted
programs use.  It is deliberately *deterministic and auditable* — the plan
is a JSON artifact, mirroring the paper's compiled memory map.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compiler.plan import (AttnPlan, MoEPlan, PhysicalPlan, _ceil_to,
                                 plan_attention)
from repro.configs.base import ArchConfig

LANE = 128  # TPU lane width; MXU tile edge


def plan_model(cfg: ArchConfig,
               mesh_axes: Optional[Sequence[str]],
               mesh_shape: Sequence[int],
               mode: str,
               *,
               esl_overlap: bool = True,
               esl_chunks: int = 4,
               seq_shard_kv: bool = False,
               kv_seq_axis: Optional[str] = None,
               remat: str = "block",
               scan_unroll: bool = False,
               use_kernels: bool = False,
               compute_dtype: str = "bfloat16",
               param_dtype: Optional[str] = None) -> PhysicalPlan:
    """Derive the physical plan for (arch x mesh x mode)."""
    if mesh_axes is None:
        mesh_axes_t: Optional[Tuple[str, ...]] = None
        mesh_shape_t: Tuple[int, ...] = (1,)
        tp, tp_axis = 1, None
        dp_axes: Tuple[str, ...] = ()
        fsdp_axes: Tuple[str, ...] = ()
    else:
        mesh_axes_t = tuple(mesh_axes)
        mesh_shape_t = tuple(int(s) for s in mesh_shape)
        assert mesh_axes_t[-1] == "model", "model axis must be innermost (ICI ring)"
        sizes = dict(zip(mesh_axes_t, mesh_shape_t))
        tp, tp_axis = sizes["model"], "model"
        dp_axes = tuple(a for a in mesh_axes_t if a != "model")
        # ZeRO-3: shard params over every non-model axis during training
        fsdp_axes = dp_axes if mode == "train" else ()

    if param_dtype is None:
        param_dtype = "float32" if mode == "train" else "bfloat16"

    attn = (plan_attention(cfg.n_heads, cfg.n_kv_heads, cfg.d_head, tp)
            if cfg.n_heads > 0 and not cfg.attention_free else None)
    if cfg.family == "rwkv":
        # attention-free, but time-mix is head-structured: shard heads
        attn = plan_attention(cfg.n_heads, cfg.n_heads, cfg.rwkv.head_dim, tp)

    d_ff_padded = _ceil_to(cfg.d_ff, max(tp * 8, LANE))
    d_ff_shard = d_ff_padded // tp
    vocab_padded = _ceil_to(cfg.vocab_size, max(tp * LANE, LANE))

    moe_plan = None
    if cfg.moe is not None:
        e = cfg.moe.n_experts
        if mode == "serve" and cfg.total_params() * 2 > 12e9 * tp:
            # giant MoE serving (llama4-400B): expand EP over data x model so
            # weights fit; attention stays model-parallel, experts use both.
            expert_axes: Tuple[str, ...] = tuple(
                a for a in ("data", "model") if mesh_axes_t and a in mesh_axes_t)
        else:
            expert_axes = ("model",) if tp_axis else ()
        ep_width = 1
        for a in expert_axes:
            ep_width *= dict(zip(mesh_axes_t, mesh_shape_t))[a]
        ep = math.gcd(e, ep_width) if ep_width > 1 else 1
        ffn_split = ep_width // ep if ep_width > 1 else 1
        dffe = _ceil_to(cfg.moe.d_ff_expert, max(ffn_split * 8, 8))
        moe_plan = MoEPlan(
            n_experts=e, ep=ep, ffn_split=ffn_split,
            experts_per_rank=e // ep,
            d_ff_expert_shard=dffe // max(ffn_split, 1),
            expert_axes=expert_axes,
            capacity_factor=cfg.moe.capacity_factor)

    rules = _rule_table(tp_axis, dp_axes, fsdp_axes, moe_plan, mode)

    return PhysicalPlan(
        arch=cfg.name, mode=mode, mesh_axes=mesh_axes_t,
        mesh_shape=mesh_shape_t, tp=tp, tp_axis=tp_axis, dp_axes=dp_axes,
        fsdp_axes=fsdp_axes, attn=attn, d_ff_shard=d_ff_shard,
        d_ff_padded=d_ff_padded, vocab_padded=vocab_padded, moe=moe_plan,
        esl_overlap=esl_overlap, esl_chunks=esl_chunks,
        seq_shard_kv=seq_shard_kv, kv_seq_axis=kv_seq_axis,
        remat=remat, scan_unroll=scan_unroll, use_kernels=use_kernels,
        compute_dtype=compute_dtype, param_dtype=param_dtype, rules=rules)


def _rule_table(tp_axis, dp_axes, fsdp_axes, moe_plan, mode) -> Dict[str, Any]:
    """logical axis -> mesh axes (None = replicated along that dim)."""
    fsdp = tuple(fsdp_axes) if fsdp_axes else None
    rules: Dict[str, Any] = {
        "embed": fsdp,                 # FSDP shards the d_model dim in train
        "embed_scatter": tp_axis,      # d_model dims that live scattered
        "vec": tp_axis,                # rank-local vector params (norms etc.)
        "vocab_rep": fsdp,             # untied input-embedding rows
        "q_heads": tp_axis,
        "kv_heads": tp_axis,
        "head_dim": None,
        "ffn": tp_axis,
        "vocab": tp_axis,
        "layers": None,
        "pos": None,
        "conv": None,
        "state": None,
        "lora": None,
        "dt": None,
        "mamba_inner": tp_axis,        # mamba d_inner: column tiles
        "rwkv_heads": tp_axis,
        "patches": None,
        None: None,
    }
    if moe_plan is not None:
        rules["experts"] = tuple(moe_plan.expert_axes) or None
        rules["expert_ffn"] = None     # split factor folded into expert axes
    return rules


def partition_specs(plan: PhysicalPlan,
                    axes_by_path: Dict[str, Tuple[Optional[str], ...]],
                    params_tree) -> Any:
    """Build a PartitionSpec pytree matching ``params_tree``.

    ``axes_by_path`` comes from InitCtx; paths are '/'-joined key chains.
    """
    import jax

    rules = plan.rules

    def spec_for(path: str, leaf) -> P:
        ax = axes_by_path.get(path)
        if ax is None:
            raise KeyError(f"no recorded axes for param path {path!r}; "
                           f"known={sorted(axes_by_path)[:8]}...")
        ndim = len(leaf.shape) if hasattr(leaf, "shape") else 0
        if len(ax) != ndim:
            raise ValueError(f"{path}: axes {ax} vs shape rank {ndim}")
        entries = []
        for a in ax:
            r = rules.get(a, None)
            entries.append(r)
        # PartitionSpec entries may be str | tuple | None
        return P(*entries)

    flat = jax.tree_util.tree_flatten_with_path(params_tree)[0]

    def path_str(kp) -> str:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)

    spec_map = {path_str(kp): spec_for(path_str(kp), leaf)
                for kp, leaf in flat}

    def rebuild(tree):
        return jax.tree_util.tree_map_with_path(
            lambda kp, leaf: spec_map[path_str(kp)], tree)

    return rebuild(params_tree)


def summarize(plan: PhysicalPlan) -> Dict[str, Any]:
    """Human-readable mapper decisions (goes into EXPERIMENTS.md tables)."""
    out: Dict[str, Any] = {
        "arch": plan.arch, "mode": plan.mode, "tp": plan.tp,
        "d_ff_padded": plan.d_ff_padded, "vocab_padded": plan.vocab_padded,
        "esl_overlap": plan.esl_overlap,
    }
    if plan.attn:
        a = plan.attn
        out.update({
            "kv_shards": a.kv_shards, "dup": a.dup,
            "q_per_rank": a.q_per_rank, "kv_per_rank": a.kv_per_rank,
            "stored_q": a.hp, "stored_kv": a.gp,
            "q_pad_waste": round(a.waste_q, 3),
            "kv_storage_x": round(a.kv_storage_factor, 3),
        })
    if plan.moe:
        m = plan.moe
        out.update({"ep": m.ep, "ffn_split": m.ffn_split,
                    "experts_per_rank": m.experts_per_rank,
                    "expert_axes": m.expert_axes})
    return out
