"""Physical execution plan — the HyperDex *memory-mapper* output.

The paper's mapper "analyzes the given model architecture and parameters,
determining the most optimal memory allocation and alignment ... divides the
multi-head attention weights with head-wise tiles and the feed-forward
network weights with column-wise tiles ... dimensions dependent on the
hardware specification" and "considers number of devices and topology".

Our analog: :class:`PhysicalPlan` — padded head/FFN/vocab layout aligned to
the TPU lane width (128) and the tensor-parallel degree, the GQA head-group
placement (with explicit duplication where `n_kv < tp`), expert-parallel
factorization, and the mesh-axis rules mapping logical parameter axes to
``PartitionSpec``s.  It is JSON-serializable: the dry-run emits it as the
auditable "memory map" artifact.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class AttnPlan:
    """Stored (physical) GQA head layout for one tensor-parallel group.

    Two mapper cases (see DESIGN.md §4):

    * ``dup == 1`` (n_kv >= tp): kv heads padded to a multiple of tp and
      sharded; q heads follow their groups.
    * ``dup > 1``  (n_kv < tp): ``kv_shards = gcd(n_kv, tp)`` shards, each
      *duplicated* across ``dup = tp/kv_shards`` adjacent ranks; the shard's
      query heads are split across those ranks (padded to a multiple of dup).

    ``q_to_kv`` maps every stored query head to its stored KV head; by
    construction the mapping is rank-local (stored q head j on rank r maps
    to a stored kv head on rank r), so attention never communicates.
    """

    tp: int
    n_heads: int            # logical q heads
    n_kv_heads: int         # logical kv heads
    d_head: int
    kv_shards: int
    dup: int
    q_per_rank: int
    kv_per_rank: int
    hp: int                 # stored q heads  = q_per_rank * tp
    gp: int                 # stored kv heads = kv_per_rank * tp
    q_to_kv: Tuple[int, ...]        # len hp, stored-kv index per stored-q
    q_orig: Tuple[int, ...]         # len hp, original q head or -1 (padding)
    kv_orig: Tuple[int, ...]        # len gp, original kv head or -1

    @property
    def q_to_kv_local(self) -> np.ndarray:
        """(tp, q_per_rank) local kv index (within-rank) per local q head."""
        m = np.asarray(self.q_to_kv, np.int32).reshape(self.tp, self.q_per_rank)
        base = (np.arange(self.tp, dtype=np.int32) * self.kv_per_rank)[:, None]
        return m - base

    @property
    def block_regular(self) -> bool:
        """True when every rank's local q->kv map is ``i // gs`` with one
        uniform group size ``gs = q_per_rank // kv_per_rank`` — the layout
        the fused decode kernels assume (q heads reshape to (G, gs) with
        no per-head gather).  Holds for the sharded case (n_kv >= tp) and
        for duplicated shards with one kv head per rank; only dup > 1
        with multiple kv heads AND padding misalignment breaks it."""
        if self.q_per_rank % max(self.kv_per_rank, 1):
            return False
        gs = self.q_per_rank // self.kv_per_rank
        want = np.repeat(np.arange(self.kv_per_rank, dtype=np.int32), gs)
        return bool((self.q_to_kv_local == want[None, :]).all())

    @property
    def waste_q(self) -> float:
        real = sum(1 for o in self.q_orig if o >= 0)
        return self.hp / max(real, 1)

    @property
    def kv_storage_factor(self) -> float:
        """Stored kv heads / logical kv heads (padding + duplication)."""
        return self.gp / max(self.n_kv_heads, 1)


def plan_attention(n_heads: int, n_kv_heads: int, d_head: int,
                   tp: int) -> AttnPlan:
    g = n_kv_heads
    gs = max(1, n_heads // max(g, 1))
    if g >= tp:
        # pad kv to a multiple of tp; groups stay intact
        gp = _ceil_to(g, tp)
        hp = gp * gs
        kv_per_rank = gp // tp
        q_per_rank = hp // tp
        q_to_kv = [j // gs for j in range(hp)]
        q_orig = [j if (j // gs) < g else -1 for j in range(hp)]
        kv_orig = [c if c < g else -1 for c in range(gp)]
        return AttnPlan(tp, n_heads, n_kv_heads, d_head, tp, 1,
                        q_per_rank, kv_per_rank, hp, gp,
                        tuple(q_to_kv), tuple(q_orig), tuple(kv_orig))
    # n_kv < tp: shard what divides, duplicate the rest
    kv_shards = math.gcd(g, tp)
    dup = tp // kv_shards
    kv_per_shard = g // kv_shards
    qps = gs * kv_per_shard                      # real q heads per shard
    qps_pad = _ceil_to(qps, dup)
    q_per_rank = qps_pad // dup
    kv_per_rank = kv_per_shard
    hp = kv_shards * qps_pad
    gp = kv_per_rank * tp                        # includes dup copies
    q_to_kv, q_orig, kv_orig = [], [], []
    for r in range(tp):
        s, p = divmod(r, dup)
        for i in range(q_per_rank):
            m = p * q_per_rank + i               # index within the shard
            real = m < qps
            c = min(m // gs, kv_per_shard - 1)
            q_to_kv.append(r * kv_per_rank + c)
            q_orig.append(s * qps + m if real else -1)
        if True:
            for c in range(kv_per_rank):
                kv_orig.append(s * kv_per_shard + c)
    return AttnPlan(tp, n_heads, n_kv_heads, d_head, kv_shards, dup,
                    q_per_rank, kv_per_rank, hp, gp,
                    tuple(q_to_kv), tuple(q_orig), tuple(kv_orig))


@dataclass(frozen=True)
class MoEPlan:
    n_experts: int
    ep: int                  # expert-parallel degree
    ffn_split: int           # per-expert FFN split degree (ep*ffn_split = ep axis size)
    experts_per_rank: int
    d_ff_expert_shard: int
    # mesh axes the expert dim shards over ('model' or ('data','model'))
    expert_axes: Tuple[str, ...]
    capacity_factor: float


# itemsize table for storage dtypes numpy cannot name (jax fp8 types)
_STORE_ITEMSIZE = {"float8_e4m3fn": 1}


@dataclass(frozen=True)
class KVPrecision:
    """Resolved KV-pool storage precision (the engine's ``kv_dtype`` knob).

    ``auto`` stores at ``plan.cache_dtype`` (today's path, bit-identical);
    ``float16``/``bfloat16`` cast on store with no side arrays; ``int8``
    and ``fp8`` store quantized values with a per-(token-row, kv-head)
    absmax scale kept in a side array next to the pool — strictly
    per-block scales are impossible with the decode path's incremental
    row-at-a-time writes (rescaling a whole resident block per token
    would re-read what paging exists to avoid), so the scale granularity
    is one fp16 scalar per stored row per head.
    """

    requested: str                 # the knob value ("auto", "int8", ...)
    store_dtype: str               # pool leaf dtype name
    scale_dtype: Optional[str]     # side-array dtype; None = not quantized
    qmax: float                    # symmetric clip bound (0 = not quantized)

    @property
    def quantized(self) -> bool:
        return self.scale_dtype is not None

    @property
    def itemsize(self) -> int:
        return _STORE_ITEMSIZE.get(self.store_dtype,
                                   np.dtype(self.store_dtype).itemsize)

    @property
    def scale_itemsize(self) -> int:
        return np.dtype(self.scale_dtype).itemsize if self.quantized else 0

    def bytes_per_row_head(self, d_head: int) -> int:
        """Stored bytes of one token's one kv head (values + its scale)."""
        return d_head * self.itemsize + self.scale_itemsize


def resolve_kv_precision(kv_dtype: str, cache_dtype: str) -> KVPrecision:
    """Map the ``kv_dtype`` knob onto a :class:`KVPrecision`.

    ``fp8`` resolves to ``float8_e4m3fn``; availability under the
    session's jax pin is the caller's check (the serving layer gates on
    ``hasattr(jnp, "float8_e4m3fn")`` and falls back loudly).
    """
    kd = (kv_dtype or "auto").lower()
    if kd == "auto":
        return KVPrecision("auto", cache_dtype, None, 0.0)
    if kd in ("float16", "fp16"):
        return KVPrecision("float16", "float16", None, 0.0)
    if kd in ("bfloat16", "bf16"):
        return KVPrecision("bfloat16", "bfloat16", None, 0.0)
    if kd in ("float32", "fp32"):
        return KVPrecision("float32", "float32", None, 0.0)
    if kd == "int8":
        return KVPrecision("int8", "int8", "float16", 127.0)
    if kd in ("fp8", "float8_e4m3fn"):
        return KVPrecision("fp8", "float8_e4m3fn", "float16", 448.0)
    raise ValueError(f"unknown kv_dtype {kv_dtype!r} (expected auto, "
                     "float16, bfloat16, float32, int8 or fp8)")


@dataclass(frozen=True)
class PhysicalPlan:
    arch: str
    mode: str                        # 'train' | 'serve'
    mesh_axes: Optional[Tuple[str, ...]]  # None => single-device smoke mode
    mesh_shape: Tuple[int, ...]
    tp: int
    tp_axis: Optional[str]
    dp_axes: Tuple[str, ...]         # batch-sharding axes
    fsdp_axes: Tuple[str, ...]       # parameter/optimizer sharding (train)
    attn: Optional[AttnPlan]
    d_ff_shard: int                  # padded d_ff / tp
    d_ff_padded: int
    vocab_padded: int
    moe: Optional[MoEPlan]
    # ESL / variant switches
    esl_overlap: bool = True         # C2 on (ring-overlapped) vs blocking psum
    esl_chunks: int = 4              # column chunks per ring step batch
    seq_shard_kv: bool = False       # §Perf variant: shard KV seq across dup
    kv_seq_axis: Optional[str] = None  # long-context: shard KV seq over axis
    remat: str = "block"             # 'none' | 'block'
    scan_unroll: bool = False        # unroll layer scan (dry-run cost acctg)
    use_kernels: bool = False        # pallas(interpret) vs jnp ref path
    compute_dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # f32 by default: the CPU dry-run backend has no native bf16 dots and
    # otherwise inserts whole-cache convert/copy churn that exists on no
    # real TPU; the 2x cache-stream cost vs bf16 is called out in
    # EXPERIMENTS.md §Roofline (TPU-native would halve the KV term).
    cache_dtype: str = "float32"
    logits_fp32: bool = True
    # logical-axis -> mesh-axes rule table (filled by the mapper)
    rules: Dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------------

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2, default=lambda o: list(o)
                          if isinstance(o, (tuple, np.ndarray)) else str(o))

    @property
    def dp(self) -> int:
        if self.mesh_axes is None:
            return 1
        sizes = dict(zip(self.mesh_axes, self.mesh_shape))
        out = 1
        for a in self.dp_axes:
            out *= sizes[a]
        return out
